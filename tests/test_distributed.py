"""Distributed behaviour (8 host devices via subprocess so the main test
process keeps its single-device jax): the Spark-role claim — a pipeline fit on
a sharded mesh equals the single-device fit — plus int8-EF gradient
compression and dry-run machinery on a small mesh."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a fresh python with 8 emulated host devices; hosts
# that cannot spawn subprocesses deselect with -m "not subprocess"
pytestmark = pytest.mark.subprocess

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str, timeout=560) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={
            "PYTHONPATH": str(REPO / "src"),
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            # these children emulate CPU host devices by construction; the
            # pin stops jax probing for a TPU runtime on containers that
            # bake libtpu in (minutes of metadata retries per child)
            "JAX_PLATFORMS": "cpu",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_distributed_fit_matches_single_device():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import (Engine, KamaeSparkPipeline, StringIndexEstimator,
                                StandardScaleEstimator, LogTransformer)
        from repro.core import types as T
        from repro.launch.mesh import make_host_mesh, use_mesh

        rng = np.random.default_rng(0)
        n = 1024
        batch = {
            "MovieID": jnp.asarray(rng.integers(1, 300, n), jnp.int32),
            "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
        }
        mk = lambda: KamaeSparkPipeline(stages=[
            StringIndexEstimator(inputCol="MovieID", outputCol="mi", inputDtype="string"),
            LogTransformer(inputCol="Price", outputCol="pl", alpha=1.0),
            StandardScaleEstimator(inputCol="pl", outputCol="ps"),
        ])
        single = mk().fit(batch)

        mesh = make_host_mesh(data=8, model=1)
        eng = Engine(mesh)
        with use_mesh(mesh):
            sharded = eng.shard_batch(batch)
            dist = mk().fit(sharded, engine=eng)
            o_dist = dist.transform(batch)
        o_single = single.transform(batch)
        np.testing.assert_array_equal(np.asarray(o_dist["mi"]), np.asarray(o_single["mi"]))
        np.testing.assert_allclose(np.asarray(o_dist["ps"]), np.asarray(o_single["ps"]), rtol=1e-6)
        print("DIST_FIT_OK")
        """
    )
    assert "DIST_FIT_OK" in out


def test_compressed_dp_grads_close_to_exact():
    out = _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import make_compressed_dp_step, init_errors
        from repro.launch.mesh import make_host_mesh, use_mesh

        mesh = make_host_mesh(data=8, model=1)
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.normal(0, 0.1, (16, 8)), jnp.float32)

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        def update_fn(params, grads, opt):
            params = {"w": params["w"] - 0.1 * grads["w"]}
            return params, opt, {"gnorm": jnp.sqrt(jnp.sum(grads["w"]**2))}

        params = {"w": W}
        batch = {"x": jnp.asarray(rng.normal(0,1,(64,16)), jnp.float32),
                 "y": jnp.asarray(rng.normal(0,1,(64,8)), jnp.float32)}
        # exact
        g_exact = jax.grad(loss_fn)(params, batch)["w"]
        # compressed distributed
        state = {"params": params, "opt": {}, "errors": init_errors(params)}
        step = make_compressed_dp_step(loss_fn, update_fn, mesh)
        with use_mesh(mesh):
            new_state, metrics = step(state, batch)
        w_exact = W - 0.1 * g_exact
        err = float(jnp.max(jnp.abs(new_state["params"]["w"] - w_exact)))
        rel = err / float(jnp.max(jnp.abs(0.1 * g_exact)))
        assert rel < 0.05, rel  # int8 quantisation error bounded
        # error feedback buffers hold the residual
        assert float(jnp.max(jnp.abs(new_state["errors"]["w"]))) > 0
        print("COMPRESS_OK", rel)
        """
    )
    assert "COMPRESS_OK" in out


def test_dryrun_machinery_small_mesh():
    """lower+compile+analyse one small cell on an 8-device mesh exercises the
    exact dry-run path (the 512-device run is the launch script)."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import registry, common as C
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import train_state_abstract, train_state_pspecs
        from repro.launch.hloanalysis import analyse_hlo
        from repro.launch.mesh import use_mesh

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        C.set_batch_axes(("data",))
        cfg = dataclasses.replace(configs.get("codeqwen1_5_7b").smoke(), remat="full")
        model = registry.build(cfg)
        step = make_train_step(model, AdamWConfig())
        state = train_state_abstract(model)
        sspec = C.legalize_tree(state, train_state_pspecs(model), mesh)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec)
        ins = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
        in_sh = {k: NamedSharding(mesh, P("data", None)) for k in ins}
        with use_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(state_sh, in_sh),
                              out_shardings=None, donate_argnums=(0,)).lower(state, ins)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        res = analyse_hlo(compiled.as_text())
        assert res["flops"] > 0
        assert sum(res["coll_bytes"].values()) > 0  # sharded -> collectives exist
        print("DRYRUN_OK", res["flops"] > 0)
        """
    )
    assert "DRYRUN_OK" in out
