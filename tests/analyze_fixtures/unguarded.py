"""Golden fixture: exactly one lock-unguarded-mutation finding.

``items`` is mutated under ``_lock`` in one method and with no lock held
in another (constructors are exempt) — either the lock is unnecessary or
the bare site races.
"""
import threading


class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []

    def guarded_add(self, x):
        with self._lock:
            self.items.append(x)

    def racy_add(self, x):
        self.items.append(x)
