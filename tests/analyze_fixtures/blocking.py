"""Golden fixture: exactly one lock-blocking-call finding.

``time.sleep`` under a held lock stalls every thread queued on it.
"""
import threading
import time

state_lock = threading.Lock()


def slow_update():
    with state_lock:
        time.sleep(0.5)
        return True
