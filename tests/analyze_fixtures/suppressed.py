"""Golden fixture: one finding, legitimately suppressed with a reason.

The allow comment sits on the enclosing ``def`` line, covering the
blocking call inside; the finding stays in the report, marked suppressed.
"""
import threading
import time

quiet_lock = threading.Lock()


def deliberate_wait():  # analyze: allow(lock-blocking-call) fixture: the wait IS the feature under test
    with quiet_lock:
        time.sleep(0.01)
