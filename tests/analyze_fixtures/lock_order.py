"""Golden fixture: exactly one lock-order-inversion finding.

Two call paths take the same pair of locks in opposite orders — the
classic ABBA deadlock.  The analyzer reports the cycle once (on the
lexicographically-first direction's acquisition site).
"""
import threading

a_lock = threading.Lock()
b_lock = threading.Lock()


def path_one():
    with a_lock:
        with b_lock:
            return 1


def path_two():
    with b_lock:
        with a_lock:
            return 2
