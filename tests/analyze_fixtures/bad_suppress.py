"""Golden fixture: exactly one analyze-bad-suppression finding.

An allow() without a reason does not suppress anything — it becomes a
finding itself.  The comment below sits on a line with nothing to
suppress, so this file contributes only the bad-suppression error.
"""
import threading

idle_lock = threading.Lock()  # analyze: allow(lock-blocking-call)


def harmless():
    with idle_lock:
        return 0
