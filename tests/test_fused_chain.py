"""Chain fusion: fused plans are bit-identical to staged plans (LTR +
quickstart pipelines, export round-trip, kill switch), the Pallas megakernel
route matches too (interpret mode), and the tuned-config cache round-trips
through disk with zero sweeps on a warm start."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    StringIndexEstimator,
    StringToStringListTransformer,
)
from repro.core import types as T
from repro.core.plan import TransformPlan, _FusedNode


def _assert_bitwise(a, b):
    assert set(a.keys()) == set(b.keys())
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), err_msg=k)


@pytest.fixture(scope="module")
def ltr():
    from repro.apps.ltr_pipeline import build_ltr_pipeline
    from repro.data import ltr_rows

    train = ltr_rows(96, seed=0)
    fitted, cols = build_ltr_pipeline(train)
    batch = {k: v[:48] for k, v in ltr_rows(48, seed=5).items()}
    return fitted, cols, batch


@pytest.fixture(scope="module")
def quickstart():
    rng = np.random.default_rng(1)
    n = 128
    batch = {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(rng.choice(["Action|Comedy", "Drama"], n), 32)
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000,
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=4, defaultValue="PADDED",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
        ]
    )
    return pipe.fit(batch), batch


@pytest.fixture()
def hash_chain():
    """Synthetic pipeline whose whole body fuses into one hash-bearing chain
    (string hash -> scale -> bucketize -> clip), exercising the rows-mode
    kernel layout."""
    from repro.core.transformers.math import (
        BucketizeTransformer,
        ClipTransformer,
        ScaleTransformer,
    )

    n = 96
    batch = {
        "city": jnp.asarray(
            T.encode_strings([f"city_{i % 37}" for i in range(n)], 32)
        )
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(inputCol="city", outputCol="h", numBins=97, seed=3),
            ScaleTransformer(inputCol="h", outputCol="s", multiplier=0.25, offset=1.0),
            BucketizeTransformer(inputCol="s", outputCol="b", splits=[2.0, 5.0, 11.0]),
            ClipTransformer(inputCol="b", outputCol="c", minValue=1, maxValue=2),
        ]
    )
    return pipe.fit(batch), batch


def test_ltr_fused_plan_bitwise_equal(ltr):
    fitted, _, batch = ltr
    plan_fused = TransformPlan(fitted.stages, fuse=True)
    plan_staged = TransformPlan(fitted.stages, fuse=False)
    assert plan_fused.fused_chain_count >= 3
    assert plan_fused.fusion_stats["fused_stages"] >= 10
    assert plan_staged.fused_chain_count == 0
    _assert_bitwise(plan_staged(batch), plan_fused(batch))


def test_ltr_fused_eager_and_pruned(ltr):
    fitted, cols, batch = ltr
    plan = TransformPlan(fitted.stages, outputs=cols, fuse=True)
    out = plan.eager(batch)  # eager path drives run_fused + liveness drops
    assert set(out.keys()) == set(cols)
    # compare eager-vs-eager: jit and eager already differ by one ulp on a
    # few float32 columns with fusion OFF (XLA kernel fusion), so the jitted
    # staged plan is not a bitwise reference for an eager run
    ref = TransformPlan(fitted.stages, outputs=cols, fuse=False).eager(batch)
    _assert_bitwise(ref, out)


def test_quickstart_fused_plan_bitwise_equal(quickstart):
    fitted, batch = quickstart
    plan_fused = TransformPlan(fitted.stages, fuse=True)
    plan_staged = TransformPlan(fitted.stages, fuse=False)
    _assert_bitwise(plan_staged(batch), plan_fused(batch))
    _assert_bitwise(fitted.transform(batch), plan_fused(batch))


def test_fuse_kill_switch_env(monkeypatch, ltr):
    fitted, _, batch = ltr
    monkeypatch.setenv("REPRO_FUSE_CHAINS", "0")
    plan = TransformPlan(fitted.stages)
    assert plan.fused_chain_count == 0
    monkeypatch.delenv("REPRO_FUSE_CHAINS")
    plan_on = TransformPlan(fitted.stages)
    assert plan_on.fused_chain_count >= 3  # fusion is the default
    _assert_bitwise(plan(batch), plan_on(batch))


def test_schedule_round_trip_preserves_fused_nodes(ltr):
    fitted, _, batch = ltr
    plan = TransformPlan(fitted.stages, fuse=True)
    rebuilt = TransformPlan.from_schedule(fitted.stages, plan.schedule())
    assert rebuilt.fused_chain_count == plan.fused_chain_count
    _assert_bitwise(plan(batch), rebuilt(batch))


def test_loaded_schedule_respects_kill_switch(monkeypatch, ltr):
    fitted, _, batch = ltr
    plan = TransformPlan(fitted.stages, fuse=True)
    sched = plan.schedule()
    monkeypatch.setenv("REPRO_FUSE_CHAINS", "0")
    expanded = TransformPlan.from_schedule(fitted.stages, sched)
    assert expanded.fused_chain_count == 0  # fused nodes expanded to members
    _assert_bitwise(plan(batch), expanded(batch))


def test_export_round_trip_with_fused_schedule(ltr):
    from repro.core.export import PreprocessModel

    fitted, cols, batch = ltr
    model = fitted.export(outputs=cols)
    model2 = PreprocessModel.load_bytes(model.save_bytes())
    assert model2.plan().fused_chain_count == model.plan().fused_chain_count
    assert model2.plan().fused_chain_count > 0
    _assert_bitwise(model.plan()(batch), model2.plan()(batch))
    _assert_bitwise(model2.plan(fuse=False)(batch), model2.plan()(batch))


def test_hash_chain_fuses_and_matches(hash_chain):
    fitted, batch = hash_chain
    plan = fitted.plan(fuse=True)
    assert plan.fused_chain_count == 1
    (node,) = [n for n in plan._nodes if isinstance(n, _FusedNode)]
    assert "hash_index" in node.program.kinds
    assert node.program.kernel_ok
    _assert_bitwise(fitted.plan(fuse=False)(batch), plan(batch))


def test_runner_stream_with_fused_plan(ltr):
    from repro.core.runner import PlanRunner

    fitted, cols, batch = ltr
    host_batches = [
        {k: np.asarray(v) for k, v in batch.items()} for _ in range(3)
    ]
    plan = TransformPlan(fitted.stages, outputs=cols, fuse=True)
    runner = PlanRunner(plan, donate=False, pack=2, prefetch=0, workers=1)
    outs = list(runner.run(iter(host_batches)))
    assert len(outs) == 3
    assert runner.stats["fused_chains"] == plan.fused_chain_count > 0
    ref = TransformPlan(fitted.stages, outputs=cols, fuse=False)(batch)
    for out in outs:
        _assert_bitwise(ref, out)


# ---------------------------------------------------------------------------
# megakernel route (interpret mode) + autotuner cache
# ---------------------------------------------------------------------------


@pytest.mark.kernel
def test_kernel_route_bitwise_equal_ltr(monkeypatch, tmp_path, ltr):
    from repro.kernels.fused_transform import tune

    fitted, _, batch = ltr
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
    monkeypatch.setenv("REPRO_TUNE_BUDGET", "2")
    tune.reload()
    try:
        plan = TransformPlan(fitted.stages, fuse=True)
        plan.warm_fused(batch)
        out_k = plan(batch)
    finally:
        tune.reload()  # drop tmp-cache entries from the in-memory store
    _assert_bitwise(TransformPlan(fitted.stages, fuse=False)(batch), out_k)


@pytest.mark.kernel
def test_kernel_route_bitwise_equal_hash_chain(monkeypatch, tmp_path, hash_chain):
    from repro.kernels.fused_transform import tune

    fitted, batch = hash_chain
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
    monkeypatch.setenv("REPRO_TUNE_BUDGET", "2")
    tune.reload()
    try:
        out_k = fitted.plan(fuse=True)(batch)
    finally:
        tune.reload()
    _assert_bitwise(fitted.plan(fuse=False)(batch), out_k)


@pytest.mark.kernel
def test_tuned_config_cache_round_trip(monkeypatch, tmp_path, hash_chain):
    """Second warmup performs ZERO tuning sweeps: winners persisted to the
    JSON store by the first warmup are re-read from disk (the in-memory store
    is dropped in between, so the hit is genuinely a disk round-trip)."""
    from repro.kernels.fused_transform import tune

    fitted, batch = hash_chain
    cache = tmp_path / "tuned.json"
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    monkeypatch.setenv("REPRO_TUNE_BUDGET", "2")
    tune.reload()
    tune.reset_stats()
    try:
        plan = fitted.plan(fuse=True)
        st1 = plan.warm_fused(batch)
        assert st1["sweeps"] > 0
        assert cache.exists()

        tune.reload()
        tune.reset_stats()
        st2 = plan.warm_fused(batch)
        assert st2["sweeps"] == 0
        assert st2["hits"] >= 1
    finally:
        tune.reload()
        tune.reset_stats()


@pytest.mark.kernel
def test_registry_warmup_tunes_before_precompile(monkeypatch, tmp_path, hash_chain):
    """registry.warmup loads/persists tuned configs before the AOT bucket
    sweep; a second registry warming the same servable hits the persisted
    store with zero sweeps."""
    from repro.kernels.fused_transform import tune
    from repro.serve.gateway.registry import ModelRegistry

    fitted, batch = hash_chain
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tuned.json"))
    monkeypatch.setenv("REPRO_TUNE_BUDGET", "2")
    tune.reload()
    tune.reset_stats()
    try:
        example = {k: np.asarray(v[0]) for k, v in batch.items()}
        model = fitted.export()

        reg = ModelRegistry()
        entry = reg.register("pre", model, example, buckets=(4, 8), max_batch=8)
        reg.warmup()
        assert entry.tuned is not None and entry.tuned["sweeps"] > 0

        tune.reload()
        tune.reset_stats()
        reg2 = ModelRegistry()
        entry2 = reg2.register("pre", model, example, buckets=(4, 8), max_batch=8)
        reg2.warmup()
        assert entry2.tuned is not None and entry2.tuned["sweeps"] == 0
    finally:
        tune.reload()
        tune.reset_stats()
