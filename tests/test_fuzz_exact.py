"""Deterministic seeded fuzz: scan/gather/kernel string+hash primitives vs
pure-numpy/Python references.

``tests/test_scan_exact.py`` proves the scan rewrites equal the SEED's jnp
loops — a regression guard, but both sides share jnp semantics, so a bug in
the shared op semantics (or an XLA miscompile on an odd shape) would pass
unnoticed.  This file is the independent exactness backstop: references are
written in plain Python integers / IEEE-double arithmetic / ``str.split``,
sharing NOTHING with the jnp implementations, and every op is driven with
hundreds of randomized cases per configuration — adversarial padding, signs,
fractions, interior junk, multi-byte separators, and every seed class the
pipelines use.  The Pallas ``bloom_hash`` kernel is covered in interpret
mode (``REPRO_HASH_KERNEL=1``) against the same numpy references.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import hashing, strops
from repro.core import types as T

RNG = np.random.default_rng(0xF0221)

_M64 = (1 << 64) - 1
_FNV_OFFSET = 14695981039346656037
_FNV_PRIME = 1099511628211


# ---------------------------------------------------------------------------
# pure-Python / numpy references (no jnp anywhere)
# ---------------------------------------------------------------------------


def ref_avalanche(h: int) -> int:
    h ^= h >> 33
    h = (h * 0xFF51AFD7ED558CCD) & _M64
    h ^= h >> 33
    h = (h * 0xC4CEB9FE1A85EC53) & _M64
    h ^= h >> 33
    return h


def ref_fnv1a64(strings: np.ndarray, seed: int = 0) -> np.ndarray:
    """Python-int FNV-1a-64 + avalanche over the trailing byte axis."""
    flat = strings.reshape(-1, strings.shape[-1])
    out = []
    for row in flat:
        h = _FNV_OFFSET ^ seed
        for b in row:
            if b != 0:
                h = ((h ^ int(b)) * _FNV_PRIME) & _M64
        out.append(ref_avalanche(h))
    return np.array(out, np.uint64).reshape(strings.shape[:-1])


def ref_fold32(h: np.ndarray) -> np.ndarray:
    return np.array(
        [(int(x) ^ (int(x) >> 32)) & 0xFFFFFFFF for x in h.reshape(-1)], np.uint32
    ).reshape(h.shape)


def ref_hash_to_bins(strings, num_bins, seed=0):
    return (ref_fold32(ref_fnv1a64(strings, seed)) % np.uint32(num_bins)).astype(
        np.int64
    )


def ref_string_to_number(strings: np.ndarray, dtype: str) -> np.ndarray:
    """Byte-for-byte replica of the parser state machine in IEEE doubles
    (Python floats), shared with neither jnp nor the scan."""
    flat = strings.reshape(-1, strings.shape[-1])
    out = []
    for row in flat:
        val, scale = 0.0, 1.0
        seen_dot = seen_digit = invalid = neg = False
        for i, c in enumerate(int(b) for b in row):
            is_nul = c == 0
            is_digit = 48 <= c <= 57
            is_dot = c == 46
            is_sign = c in (43, 45) and i == 0
            d = float(c - 48)
            if is_digit and not seen_dot:
                val = val * 10.0 + d
            if is_digit and seen_dot:
                scale = scale * 0.1
                val = val + d * scale
            seen_digit = seen_digit or is_digit
            invalid = (
                invalid
                or not (is_nul or is_digit or is_dot or is_sign)
                or (is_dot and seen_dot)
            )
            seen_dot = seen_dot or is_dot
            if is_sign and c == 45:
                neg = True
        invalid = invalid or not seen_digit
        v = -val if neg else val
        if np.issubdtype(np.dtype(dtype), np.floating):
            out.append(np.nan if invalid else v)
        else:
            out.append(0 if invalid else v)
    arr = np.array(out, np.float64).reshape(strings.shape[:-1])
    return arr.astype(dtype)


def ref_concat(parts, separator: str, max_len: int) -> np.ndarray:
    """Sequential-write reference: each piece's non-zero bytes land at
    (running offset + position-in-piece) when inside [0, max_len); the
    offset advances by the piece's non-zero byte count."""
    n = parts[0].shape[0]
    pieces = []
    sep = T.encode_strings([separator], max(len(separator), 1))[0][: len(separator)]
    for i, p in enumerate(parts):
        if i > 0 and separator:
            pieces.append(np.tile(sep, (n, 1)))
        pieces.append(np.asarray(p))
    out = np.zeros((n, max_len), np.uint8)
    for r in range(n):
        off = 0
        for p in pieces:
            row = p[r]
            for j, c in enumerate(row):
                pos = off + j
                if c != 0 and pos < max_len:
                    out[r, pos] = c
            off += int(np.count_nonzero(row))
    return out


def ref_split(words, sep: str, list_length: int, default: str, out_max_len: int):
    """``str.split`` reference for delimiter splitting."""
    rows = []
    for w in words:
        want = [p[:out_max_len] for p in w.split(sep)][:list_length]
        want = [p if p else default for p in want]
        if w == "":
            want = []
        want += [default] * (list_length - len(want))
        rows.append(want)
    return rows


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def gen_strings(n, max_len, kind, rng=RNG):
    if kind == "bytes":  # arbitrary non-NUL bytes, random zero padding
        arr = rng.integers(1, 256, (n, max_len)).astype(np.uint8)
        lens = rng.integers(0, max_len + 1, n)
        for i, l in enumerate(lens):
            arr[i, l:] = 0
        return arr
    words = []
    for _ in range(n):
        if kind == "numeric":
            sign = rng.choice(["", "-", "+"])
            ip = str(rng.integers(0, 10**9))
            frac = "" if rng.random() < 0.5 else "." + str(rng.integers(0, 10**6))
            w = sign + ip + frac
            if rng.random() < 0.25:  # corrupt some rows
                w = w.replace(w[rng.integers(0, len(w))], "z", 1)
            if rng.random() < 0.1:
                w = w + "."  # trailing dot
        else:
            alpha = "aZ0.9+-| <>_#"
            w = "".join(rng.choice(list(alpha), rng.integers(0, max_len)))
        words.append(w)
    return T.encode_strings(words, max_len)


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["text", "numeric", "bytes"])
@pytest.mark.parametrize("max_len", [8, 32])
def test_fuzz_fnv1a64_vs_python_ints(kind, max_len):
    s = gen_strings(200, max_len, kind)
    for seed in (0, 1, 7, 2**31, 2**32 - 1):
        got = np.asarray(hashing.fnv1a64(jnp.asarray(s), seed))
        np.testing.assert_array_equal(got, ref_fnv1a64(s, seed))


def test_fuzz_fold32_and_bins_vs_python_ints():
    s = gen_strings(300, 16, "bytes")
    h = np.asarray(hashing.fnv1a64(jnp.asarray(s)))
    np.testing.assert_array_equal(np.asarray(hashing.fold32(jnp.asarray(h))), ref_fold32(h))
    for bins in (97, 4096, 1 << 20):
        np.testing.assert_array_equal(
            np.asarray(hashing.hash_to_bins(jnp.asarray(s), bins, seed=3)),
            ref_hash_to_bins(s, bins, seed=3),
        )


@pytest.mark.parametrize("max_len", [8, 16])
def test_fuzz_bloom_kernel_interpret_vs_python_ints(monkeypatch, max_len):
    """The Pallas bloom_hash kernel (interpret mode on CPU) against the
    Python-int reference: raw 64-bit hashes, seeded bins, bloom stacks."""
    monkeypatch.setenv("REPRO_HASH_KERNEL", "1")
    from repro.kernels.bloom_hash import ops

    s = gen_strings(130, max_len, "bytes")
    js = jnp.asarray(s)
    for seed in (0, 5, 2**31):
        np.testing.assert_array_equal(
            np.asarray(ops.fnv1a64_raw(js, seed)), ref_fnv1a64(s, seed)
        )
        np.testing.assert_array_equal(
            np.asarray(ops.hash_indices_seeded(js, 4096, seed)),
            ref_hash_to_bins(s, 4096, seed),
        )
    got = np.asarray(ops.bloom_indices(js, 512, 3))
    want = np.stack([ref_hash_to_bins(s, 512, k) for k in range(3)], axis=-1)
    np.testing.assert_array_equal(got, want)
    # routing honours the override (the kernel really ran above)
    assert hashing.kernel_active()


# ---------------------------------------------------------------------------
# string_to_number
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["numeric", "text", "bytes"])
@pytest.mark.parametrize("dtype", ["float64", "float32", "int64", "int32"])
def test_fuzz_string_to_number_vs_python_floats(kind, dtype):
    s = gen_strings(300, 24, kind)
    got = np.asarray(strops.string_to_number(jnp.asarray(s), dtype))
    want = ref_string_to_number(s, dtype)
    np.testing.assert_array_equal(got, want)


def test_fuzz_string_to_number_edges():
    words = ["", "-", "+", ".", "-.", "0", "-0", "00.100", "+.5", "1..2",
             "9" * 15, "1.0000001", ".".join(["1", "2", "3"]), " 1", "1 "]
    s = T.encode_strings(words * 20, 20)
    for dtype in ("float64", "int64"):
        np.testing.assert_array_equal(
            np.asarray(strops.string_to_number(jnp.asarray(s), dtype)),
            ref_string_to_number(s, dtype),
        )


# ---------------------------------------------------------------------------
# concat
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sep", ["", "-", "||"])
@pytest.mark.parametrize("max_len", [12, 40])
def test_fuzz_concat_vs_python_writes(sep, max_len):
    parts = [
        gen_strings(200, w, kind)
        for w, kind in [(6, "text"), (10, "bytes"), (5, "numeric"), (13, "text")]
    ]
    got = np.asarray(strops.concat([jnp.asarray(p) for p in parts], sep, max_len))
    np.testing.assert_array_equal(got, ref_concat(parts, sep, max_len))


def test_fuzz_concat_truncation_boundary():
    # total width intentionally straddles max_len so truncation is exercised
    # on most rows
    parts = [gen_strings(250, 7, "bytes") for _ in range(3)]
    got = np.asarray(strops.concat([jnp.asarray(p) for p in parts], "+", 16))
    np.testing.assert_array_equal(got, ref_concat(parts, "+", 16))


# ---------------------------------------------------------------------------
# split_to_list
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sep", ["|", "<>", ",,", "aba"])
def test_fuzz_split_vs_python_split(sep):
    pieces = ["", "a", "ab", "a" * 11, sep, sep + sep, "x" + sep, sep + "y",
              "0.5", "end"]
    words = [
        sep.join(RNG.choice(pieces, RNG.integers(0, 6)).tolist())
        for _ in range(300)
    ]
    s = jnp.asarray(T.encode_strings(words, 48))
    out = T.decode_strings(np.asarray(strops.split_to_list(s, sep, 5, "D", 10)))
    want = ref_split(words, sep, 5, "D", 10)
    for row, w in zip(out, want):
        assert list(row) == w


def test_fuzz_split_single_byte_fast_path():
    # d == 1 takes the no-scan fast path; drive it with separator-dense rows
    words = ["|".join(RNG.choice(["", "q", "zz"], RNG.integers(0, 9)).tolist()) for _ in range(400)]
    s = jnp.asarray(T.encode_strings(words, 32))
    out = T.decode_strings(np.asarray(strops.split_to_list(s, "|", 7, "P", 6)))
    want = ref_split(words, "|", 7, "P", 6)
    for row, w in zip(out, want):
        assert list(row) == w

# ---------------------------------------------------------------------------
# fused transform chains
# ---------------------------------------------------------------------------


def _gen_chain_case(rng, with_hash):
    """One random fused chain over exact ops only (hash/bucketize/affine/
    clip/abs/round/std_score — no transcendentals, so numpy IS bit-exact
    ground truth) plus its input column."""
    from repro.core.fusion import ChainOp, ChainProgram

    n = int(rng.integers(5, 70))
    slot = [0]

    def new_slot():
        slot[0] += 1
        return f"v{slot[0]}"

    ops = []
    if with_hash:
        max_len = int(rng.choice([8, 16]))
        x = gen_strings(n, max_len, "bytes", rng=rng)
        params = (
            int(rng.integers(2, 5000)),
            int(rng.integers(0, 2**32)),
            int(rng.integers(0, 3)),
        )
        cur = new_slot()
        ops.append(ChainOp("hash_index", ("s",), cur, params))
        inputs, state = ["s"], "int"
    else:
        shape = (n,) if rng.random() < 0.5 else (n, int(rng.integers(2, 9)))
        x = rng.standard_normal(shape) * 10.0
        hole = rng.random(shape) < 0.1  # NaN/inf exercise bucketize + clip
        x[hole] = rng.choice([np.nan, np.inf, -np.inf], int(hole.sum()))
        cur, inputs, state = "x", ["x"], "float"

    prev_float_affine = False  # XLA folds ADJACENT constant affines into
    for _ in range(int(rng.integers(1, 5))):  # one (different rounding), so
        kinds = ["clip", "abs", "bucketize"]  # never stack two in a row
        if state == "int" or not prev_float_affine:
            kinds.append("scale")
        if state == "float":
            kinds.append("round")
            if not prev_float_affine:
                kinds.append("std_score")
        kind = str(rng.choice(kinds))
        prev_float_affine = kind in ("scale", "std_score") and state == "float"
        if kind == "scale":
            # float multipliers are powers of two: XLA may contract the
            # mul+add into an FMA inside a fused computation, which only
            # matches numpy's two-step rounding when the multiply is exact
            params = (
                (int(rng.integers(-3, 4)), int(rng.integers(-5, 6)))
                if state == "int"
                else (
                    float(rng.choice([-2.0, -0.5, 0.25, 0.5, 1.0, 2.0, 4.0])),
                    float(rng.integers(-8, 9)) / 2,
                )
            )
        elif kind == "clip":
            lo, hi = int(rng.integers(-20, 0)), int(rng.integers(0, 20))
            params = (lo, hi) if state == "int" else (float(lo), float(hi))
        elif kind == "round":
            params = (str(rng.choice(["round", "floor", "ceil"])),)
        elif kind == "std_score":
            # power-of-two stds only: XLA rewrites division by a constant
            # into multiply-by-reciprocal inside fused computations, which
            # is inexact (one ulp) for non-power-of-two divisors
            params = (
                float(rng.integers(-4, 5)) / 2,
                float(rng.choice([0.5, 2.0, 4.0])),
            )
        elif kind == "bucketize":
            edges = np.unique(rng.standard_normal(int(rng.integers(1, 5))) * 5.0)
            params = tuple(float(e) for e in edges)
            state = "int"
        else:
            params = ()
        out = new_slot()
        ops.append(ChainOp(kind, (cur,), out, params))
        cur = out

    outputs = [cur]
    if len(ops) > 1 and rng.random() < 0.3:
        outputs = [ops[0].output, cur]  # also emit an early intermediate
    return ChainProgram(ops, inputs, outputs), [x]


@pytest.mark.parametrize("with_hash", [False, True])
def test_fuzz_chain_xla_executor_vs_numpy(with_hash):
    """The XLA chain executor (the fused plan's default route) bit-exact
    against the numpy chain reference on random op programs."""
    from repro.kernels.fused_transform import ops as fused_ops
    from repro.kernels.fused_transform import ref as fused_ref

    rng = np.random.default_rng(0xC5A1 + with_hash)
    for _ in range(30):
        program, np_inputs = _gen_chain_case(rng, with_hash)
        got = fused_ops.execute_chain_xla(
            program, [jnp.asarray(v) for v in np_inputs]
        )
        want = fused_ref.ref_chain(program, np_inputs)
        assert len(got) == len(want)
        for g, w, name in zip(got, want, program.outputs):
            np.testing.assert_array_equal(
                np.asarray(g), w, err_msg=f"{program.signature()}:{name}"
            )


@pytest.mark.kernel
@pytest.mark.parametrize("with_hash", [False, True])
def test_fuzz_chain_megakernel_interpret_vs_numpy(monkeypatch, with_hash):
    """The Pallas megakernel (interpret mode) bit-exact against the numpy
    chain reference — covers both layouts: rows mode (string hash feeding
    the chain) and flat mode (numeric columns tiled over a 2D grid)."""
    monkeypatch.setenv("REPRO_FUSED_KERNEL", "1")
    from repro.kernels.fused_transform import ops as fused_ops
    from repro.kernels.fused_transform import ref as fused_ref
    from repro.kernels.fused_transform import tune as fused_tune

    fused_tune.reload()
    rng = np.random.default_rng(0xFE17 + with_hash)
    try:
        for _ in range(10):
            program, np_inputs = _gen_chain_case(rng, with_hash)
            jx = [jnp.asarray(v) for v in np_inputs]
            assert program.kernel_ok
            assert fused_ops.kernel_plan(program, jx) is not None  # kernel ran
            got = fused_ops.execute_chain(program, jx)
            want = fused_ref.ref_chain(program, np_inputs)
            for g, w, name in zip(got, want, program.outputs):
                np.testing.assert_array_equal(
                    np.asarray(g), w, err_msg=f"{program.signature()}:{name}"
                )
    finally:
        fused_tune.reload()
