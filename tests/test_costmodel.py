"""Cost-aware, finish-time-feasible gateway scheduling.

Covers the ExecuteCostModel (quantile estimates, fallback chain, prior),
feasibility shedding at batch formation and cheaper-bucket trimming under
overload (both on an injectable clock, no real execution), drain-based door
shedding, the retry-path telemetry/deadline fixes, the admission-slot
accounting invariant, quantile-label collisions, and the end-to-end load
test showing deadline-hit-rate strictly improves over the launch-time-only
baseline at the same offered load.
"""
import threading
import time

import numpy as np
import pytest

from repro.serve import (
    DeadlineExceededError,
    ExecuteCostModel,
    InfeasibleDeadlineError,
    ServingGateway,
)
from repro.serve.gateway import AdmissionController, BatchScheduler, Request
from repro.serve.gateway.telemetry import LatencySketch, quantile_label


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _req(model, x, priority=0, deadline=None, t=0.0, seq=1):
    return Request(model, {"x": np.float32(x)}, priority, deadline, t, seq)


# ---------------------------------------------------------------------------
# ExecuteCostModel
# ---------------------------------------------------------------------------


def test_costmodel_estimates_quantile_with_safety():
    cm = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=0.0)
    assert cm.estimate("m", 4) is None  # no data, no prior: unknown
    for ms in range(1, 11):
        cm.observe("m", 4, ms / 1e3)
    est = cm.estimate("m", 4)
    assert est == pytest.approx(5e-3, rel=0.10)  # p50 of 1..10ms, ~4% sketch

    cm2 = ExecuteCostModel(quantile=0.5, safety=2.0)
    for ms in range(1, 11):
        cm2.observe("m", 4, ms / 1e3)
    assert cm2.estimate("m", 4) == pytest.approx(2 * est, rel=1e-6)


def test_costmodel_fallback_chain():
    # fit=False isolates the nearest-bucket leg of the chain (with the fit
    # enabled, two known buckets answer unseen ones by inter/extrapolation —
    # covered by the fit tests below)
    cm = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=0.0, fit=False)
    for _ in range(3):
        cm.observe("m", 8, 0.050)
    est8 = cm.estimate("m", 8)
    # unknown buckets borrow the nearest known one (smaller preferred)
    assert cm.estimate("m", 16) == est8
    assert cm.estimate("m", 4) == est8
    cm.observe("m", 2, 0.010)
    assert cm.estimate("m", 4) == pytest.approx(0.010, rel=0.10)  # nearest smaller wins
    # unknown model: None without a prior, the prior with one
    assert cm.estimate("other", 4) is None
    cm_prior = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=7.0)
    assert cm_prior.estimate("other", 4) == pytest.approx(7e-3)


def test_costmodel_linear_fit_interpolates_and_extrapolates():
    cm = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=0.0)
    for _ in range(4):
        cm.observe("m", 2, 0.010)
        cm.observe("m", 8, 0.040)
    # line through (2, 10ms) and (8, 40ms): 5 ms/row, zero intercept
    # (within the sketch's ~4% relative quantile error)
    assert cm.estimate("m", 4) == pytest.approx(0.020, rel=0.15)  # interpolate
    assert cm.estimate("m", 16) == pytest.approx(0.080, rel=0.15)  # extrapolate up
    assert cm.estimate("m", 1) == pytest.approx(0.005, rel=0.30)  # extrapolate down
    # observed buckets still answer from their own histograms, not the line
    assert cm.estimate("m", 2) == pytest.approx(0.010, rel=0.10)
    fit = cm.snapshot()["m"]["fit"]
    assert fit["buckets_fit"] == 2
    assert fit["slope_ms_per_row"] == pytest.approx(5.0, rel=0.15)


def test_costmodel_fit_never_negative_and_never_invents():
    cm = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=0.0)
    # decreasing-cost anomaly: a negative-slope extrapolation clamps at 0
    # (callers treat 0 as "don't shed"), never goes negative
    for _ in range(3):
        cm.observe("m", 2, 0.050)
        cm.observe("m", 8, 0.010)
    assert cm.estimate("m", 64) == 0.0
    # never-shed-on-ignorance survives the fit: no data at all -> unknown
    assert cm.estimate("fresh", 4) is None
    # a single observed bucket cannot fit a line -> nearest-bucket answer
    cm2 = ExecuteCostModel(quantile=0.5, safety=1.0, prior_ms=0.0)
    for _ in range(3):
        cm2.observe("m", 4, 0.030)
    assert cm2.estimate("m", 16) == pytest.approx(0.030, rel=0.10)
    assert cm2.snapshot()["m"]["fit"]["slope_ms_per_row"] is None


def test_costmodel_fit_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_GW_COST_FIT", "0")
    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    assert cm.fit is False
    for _ in range(3):
        cm.observe("m", 2, 0.010)
        cm.observe("m", 8, 0.040)
    # nearest smaller, not the fitted line
    assert cm.estimate("m", 4) == pytest.approx(0.010, rel=0.10)
    monkeypatch.delenv("REPRO_GW_COST_FIT")
    assert ExecuteCostModel().fit is True


def test_scheduler_uses_fitted_estimate_for_unseen_bucket_fake_clock():
    """An unseen bucket's fitted estimate drives batch formation: padding 3
    requests up to the never-observed bucket 4 would blow their deadlines
    (fit: ~40ms), so the scheduler trims to bucket 2 (~20ms) and re-queues
    the overflow — all on a fake clock, no execution."""
    fc = FakeClock(100.0)
    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    for _ in range(4):
        cm.observe("m", 1, 0.010)
        cm.observe("m", 2, 0.020)
    assert cm.estimate("m", 4) == pytest.approx(0.040, rel=0.15)  # fitted
    sched = BatchScheduler(clock=fc, max_wait_ms=0.0, cost_model=cm)
    sched.set_limit("m", 4, buckets=(1, 2, 4))
    for i in range(3):
        sched.put(_req("m", float(i), deadline=fc() + 0.030, t=fc(), seq=i + 1))
    key, batch, shed = sched.next_batch(timeout=0.05)
    assert not shed
    assert len(batch) == 2  # trimmed to the feasible bucket
    assert sched.depth == 1  # overflow re-queued, not shed


def test_costmodel_min_samples():
    cm = ExecuteCostModel(quantile=0.5, safety=1.0, min_samples=3)
    cm.observe("m", 4, 0.500)  # 1 sample < min_samples: not trusted
    for _ in range(3):
        cm.observe("m", 8, 0.020)
    assert cm.estimate("m", 4) == pytest.approx(0.020, rel=0.10)
    assert cm.estimate("nodata", 4) is None  # unknown model: callers serve


# ---------------------------------------------------------------------------
# Scheduler: feasibility shedding + bucket trim (injectable clock)
# ---------------------------------------------------------------------------


def test_scheduler_sheds_infeasible_at_formation_fake_clock():
    fc = FakeClock(100.0)
    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    for _ in range(4):
        cm.observe("m", 1, 0.100)
    sched = BatchScheduler(clock=fc, max_wait_ms=0.0, cost_model=cm)
    sched.set_limit("m", 4, buckets=(1, 2, 4))

    doomed = _req("m", 1.0, deadline=fc() + 0.050, t=fc(), seq=1)  # est 100ms > 50ms
    expired = _req("m", 2.0, deadline=fc() - 1.0, t=fc(), seq=2)
    fine = _req("m", 3.0, deadline=fc() + 10.0, t=fc(), seq=3)
    for r in (doomed, expired, fine):
        sched.put(r)

    key, batch, shed = sched.next_batch(timeout=0.0)
    assert [r.seq for r in batch] == [3]
    by_req = {r.seq: err for r, err in shed}
    assert isinstance(by_req[1], InfeasibleDeadlineError)  # finish-time shed
    assert isinstance(by_req[1], DeadlineExceededError)  # distinct SUBCLASS
    assert isinstance(by_req[2], DeadlineExceededError)
    assert not isinstance(by_req[2], InfeasibleDeadlineError)  # plain expiry


def test_scheduler_trims_to_cheaper_bucket_under_overload():
    fc = FakeClock(50.0)
    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    for _ in range(4):
        cm.observe("m", 1, 0.005)
        cm.observe("m", 2, 0.008)
        cm.observe("m", 4, 0.010)
        cm.observe("m", 8, 0.200)  # padding 5 -> 8 costs 20x bucket 4
    sched = BatchScheduler(clock=fc, max_wait_ms=0.0, cost_model=cm)
    sched.set_limit("m", 8, buckets=(1, 2, 4, 8))

    urgent = _req("m", 0.0, deadline=fc() + 0.100, t=fc(), seq=1)
    sched.put(urgent)
    for i in range(4):
        sched.put(_req("m", float(i + 1), t=fc(), seq=i + 2))

    key, batch, shed = sched.next_batch(timeout=0.0)
    # padding up to bucket 8 (est 200ms) would blow the 100ms deadline, so
    # the 4 most urgent launch at bucket 4 (est 10ms) and one is re-queued
    assert shed == []
    assert [r.seq for r in batch] == [1, 2, 3, 4]
    assert sched.depth == 1  # the overflow request waits for the next batch

    key2, batch2, shed2 = sched.next_batch(timeout=0.0)
    assert [r.seq for r in batch2] == [5] and shed2 == []


def test_scheduler_readiness_launches_early_enough_to_finish():
    fc = FakeClock(10.0)
    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    for _ in range(4):
        cm.observe("m", 1, 0.040)
    sched = BatchScheduler(clock=fc, max_wait_ms=1000.0, cost_model=cm)
    sched.set_limit("m", 4, buckets=(1, 2, 4))
    sched.put(_req("m", 1.0, deadline=fc() + 0.100, t=fc(), seq=1))

    (key,) = sched._groups
    due = sched._ready_at(key, sched._groups[key], fc())
    est = cm.estimate("m", 1)
    # launch at deadline - est (so the batch FINISHES by the deadline), not
    # at the deadline itself
    assert due == pytest.approx(10.0 + 0.100 - est)
    assert due < 10.0 + 0.100 - 0.030

    # without a cost model the old launch-at-deadline behaviour remains
    sched_nocost = BatchScheduler(clock=fc, max_wait_ms=1000.0)
    sched_nocost.set_limit("m", 4)
    sched_nocost.put(_req("m", 1.0, deadline=fc() + 0.100, t=fc(), seq=1))
    (k2,) = sched_nocost._groups
    assert sched_nocost._ready_at(k2, sched_nocost._groups[k2], fc()) == pytest.approx(10.100)


# ---------------------------------------------------------------------------
# Admission: drain-based door shedding (injectable clock)
# ---------------------------------------------------------------------------


def test_depth_ahead_is_urgency_aware():
    """Formation is urgency-ordered, so the door drain estimate must count
    only queued work that would actually launch before the new request — a
    high-priority or tight-deadline request jumps deadline-less traffic."""
    sched = BatchScheduler(clock=FakeClock(0.0))
    sched.set_limit("m", 1)
    for i in range(5):
        sched.put(_req("m", float(i), priority=0, t=0.0, seq=i + 1))
    assert sched.depth_for("m") == 5
    assert sched.depth_ahead("m", priority=0, deadline=None) == 5  # FIFO peer
    assert sched.depth_ahead("m", priority=1, deadline=None) == 0  # jumps all
    assert sched.depth_ahead("m", priority=0, deadline=1.0) == 0  # jumps all
    assert sched.depth_ahead("other", priority=0, deadline=None) == 0


def test_admission_sheds_at_door_on_drain_estimate():
    fc = FakeClock(5.0)
    ac = AdmissionController(
        max_pending=4, clock=fc, drain_estimator=lambda m, p, d: 0.5
    )
    with pytest.raises(InfeasibleDeadlineError):
        ac.admit(deadline=fc() + 0.100, model="m")  # 100ms budget < 500ms drain
    assert ac.pending == 0  # no slot was taken for the shed request
    assert ac.stats["shed_infeasible_door"] == 1
    ac.admit(deadline=fc() + 1.0, model="m")  # enough budget: admitted
    ac.admit(deadline=None, model="m")  # no deadline: drain is irrelevant
    assert ac.pending == 2
    assert ac.stats["admitted"] == 2
    # an already-expired deadline still sheds with the plain error
    with pytest.raises(DeadlineExceededError) as ei:
        ac.admit(deadline=fc() - 0.001, model="m")
    assert not isinstance(ei.value, InfeasibleDeadlineError)


# ---------------------------------------------------------------------------
# Retry path: batch accounting, sample tagging, deadline re-check
# ---------------------------------------------------------------------------


def _poisonable(calls=None):
    def fn(batch):
        x = np.asarray(batch["x"])
        if calls is not None:
            calls.append(x.tolist())
        if (x < 0).any():
            raise ValueError("poisoned feature")
        return {"y": x * 2.0}

    return fn


def test_retry_sweep_counts_one_batch_and_tags_samples():
    gw = ServingGateway(max_pending=16, max_wait_ms=30.0, workers=1, cost_model=False)
    gw.register("p", _poisonable(), example={"x": np.float32(1.0)}, buckets=(1, 2, 4), max_batch=4)
    gw.warmup()

    reqs = [
        gw.submit_async("p", {"x": np.float32(1.0)}),
        gw.submit_async("p", {"x": np.float32(-1.0)}),  # poisons the batch
        gw.submit_async("p", {"x": np.float32(3.0)}),
    ]
    for r in reqs:
        assert r.event.wait(10)
    assert reqs[0].error is None and float(reqs[0].result["y"]) == 2.0
    assert isinstance(reqs[1].error, ValueError)
    assert reqs[2].error is None and float(reqs[2].result["y"]) == 6.0

    snap = gw.snapshot()
    # the whole rerun sweep is ONE batch, not one per rerun
    assert snap["stats"]["batches"] == 1
    assert snap["stats"]["completed"] == 2 and snap["stats"]["failed"] == 1
    # the failed batch attempt recorded nothing; reruns are tagged apart
    assert snap["models"]["p"]["execute"]["count"] == 0
    assert snap["models"]["p"]["execute_retry"]["count"] == 2
    gw.close()


def test_retry_resheds_expired_deadline_instead_of_rerunning():
    calls = []

    def slow_poisonable(batch):
        x = np.asarray(batch["x"])
        time.sleep(0.15)
        calls.append(x.tolist())
        if (x < 0).any():
            raise ValueError("poisoned feature")
        return {"y": x * 2.0}

    gw = ServingGateway(max_pending=16, max_wait_ms=30.0, workers=1, cost_model=False)
    gw.register("s", slow_poisonable, example={"x": np.float32(1.0)}, buckets=(1, 2, 4), max_batch=4)
    gw.warmup()
    calls.clear()

    poisoned = gw.submit_async("s", {"x": np.float32(-1.0)})
    dated = gw.submit_async("s", {"x": np.float32(1.0)}, deadline_ms=100.0)
    plain = gw.submit_async("s", {"x": np.float32(3.0)})
    for r in (poisoned, dated, plain):
        assert r.event.wait(10)

    # the batch failed after 150ms; by then `dated`'s 100ms deadline had
    # expired — it must be re-SHED, not silently re-executed
    assert isinstance(dated.error, DeadlineExceededError)
    assert isinstance(poisoned.error, ValueError)
    assert plain.error is None and float(plain.result["y"]) == 6.0
    assert [1.0] not in calls  # the expired request never ran solo
    assert gw.snapshot()["stats"]["shed_queued"] == 1
    gw.close()


def test_retry_sheds_infeasible_deadline_before_rerunning():
    """A healthy batch member whose deadline has NOT expired when the batch
    fails, but whose remaining budget cannot cover a solo rerun, is shed
    with InfeasibleDeadlineError instead of being served late."""
    calls = []

    def slow_poisonable(batch):
        x = np.asarray(batch["x"])
        time.sleep(0.15)
        calls.append(x.tolist())
        if (x < 0).any():
            raise ValueError("poisoned feature")
        return {"y": x * 2.0}

    gw = ServingGateway(max_pending=16, max_wait_ms=30.0, workers=1)  # cost ON
    gw.register("s", slow_poisonable, example={"x": np.float32(1.0)}, buckets=(1, 2, 4), max_batch=4)
    gw.warmup()  # seeds est ≈ 150ms per bucket
    calls.clear()

    poisoned = gw.submit_async("s", {"x": np.float32(-1.0)})
    # feasible at formation (~30ms, 220ms budget > 150ms est) but by the
    # failed attempt's end (~180ms) only ~70ms remain — below the est
    dated = gw.submit_async("s", {"x": np.float32(1.0)}, deadline_ms=250.0)
    plain = gw.submit_async("s", {"x": np.float32(3.0)})
    for r in (poisoned, dated, plain):
        assert r.event.wait(10)

    assert isinstance(dated.error, InfeasibleDeadlineError)
    assert isinstance(poisoned.error, ValueError)
    assert plain.error is None and float(plain.result["y"]) == 6.0
    assert [1.0] not in calls  # the infeasible request never ran solo
    assert gw.snapshot()["stats"]["shed_infeasible"] == 1
    gw.close()


# ---------------------------------------------------------------------------
# Admission-slot accounting: _pending returns to 0 in every outcome
# ---------------------------------------------------------------------------


def _slow_model(delay_s):
    def fn(batch):
        time.sleep(delay_s)
        return {"y": np.asarray(batch["x"]) * 2.0}

    return fn


def test_slots_released_after_client_timeout_with_late_completion():
    gw = ServingGateway(max_pending=8, max_wait_ms=1.0, workers=1, cost_model=False)
    gw.register("s", _slow_model(0.12), example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()
    with pytest.raises(TimeoutError):
        gw.submit("s", {"x": np.float32(1.0)}, timeout=0.01)  # client gives up
    deadline = time.perf_counter() + 5.0
    while gw.admission.pending and time.perf_counter() < deadline:
        time.sleep(0.01)  # the batch still completes and releases the slot
    assert gw.admission.pending == 0
    gw.close()


def test_slots_released_after_formation_shed():
    gw = ServingGateway(max_pending=8, max_wait_ms=1.0, workers=1, cost_model=False)
    gw.register("s", _slow_model(0.12), example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()
    blocker = gw.submit_async("s", {"x": np.float32(1.0)})
    time.sleep(0.03)
    doomed = gw.submit_async("s", {"x": np.float32(2.0)}, deadline_ms=30.0)
    assert blocker.event.wait(5) and doomed.event.wait(5)
    assert isinstance(doomed.error, DeadlineExceededError)
    assert gw.admission.pending == 0
    gw.close()


def test_slots_released_after_batch_failure_with_rerun():
    gw = ServingGateway(max_pending=8, max_wait_ms=30.0, workers=1, cost_model=False)
    gw.register("p", _poisonable(), example={"x": np.float32(1.0)}, buckets=(1, 2, 4), max_batch=4)
    gw.warmup()
    reqs = [
        gw.submit_async("p", {"x": np.float32(v)}) for v in (1.0, -1.0, 3.0)
    ]
    for r in reqs:
        assert r.event.wait(10)
    assert gw.admission.pending == 0
    gw.close()


def test_slots_released_after_close_with_queued_requests():
    gw = ServingGateway(max_pending=8, max_wait_ms=1.0, workers=1, cost_model=False)
    gw.register("s", _slow_model(0.15), example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()
    running = gw.submit_async("s", {"x": np.float32(1.0)})
    time.sleep(0.03)
    queued = [gw.submit_async("s", {"x": np.float32(float(i))}) for i in (2, 3, 4)]
    gw.close()
    assert running.event.wait(2)
    for q in queued:
        assert q.event.is_set() and q.error is not None
    assert gw.admission.pending == 0


# ---------------------------------------------------------------------------
# Telemetry labels
# ---------------------------------------------------------------------------


def test_quantile_labels_do_not_collide():
    assert quantile_label(0.5) == "p50_us"
    assert quantile_label(0.99) == "p99_us"
    assert quantile_label(0.999) == "p99_9_us"
    assert quantile_label(0.9999) == "p99_99_us"
    sk = LatencySketch()
    for i in range(1, 201):
        sk.record(i * 1e-4)
    snap = sk.snapshot_us(qs=(0.99, 0.999))
    assert "p99_us" in snap and "p99_9_us" in snap  # both survive
    assert snap["p99_9_us"] >= snap["p99_us"] > 0


# ---------------------------------------------------------------------------
# Gateway integration: warmup seeding, defaults, snapshot surface
# ---------------------------------------------------------------------------


def test_warmup_seeds_cost_model_and_snapshot_surfaces_it():
    gw = ServingGateway(max_pending=8, max_wait_ms=1.0, workers=1)  # cost on by default
    gw.register(
        "m",
        lambda b: {"y": np.asarray(b["x"]) * 2.0},
        example={"x": np.float32(0.0)},
        buckets=(1, 2, 4),
        max_batch=4,
    )
    gw.warmup()
    assert gw.cost is not None
    assert gw.cost.observed["warmup"] == 3  # one timed probe per bucket
    for b in (1, 2, 4):
        est = gw.cost.estimate("m", b)
        assert est is not None and est > 0
    snap = gw.snapshot()
    assert set(snap["models"]["m"]["cost"]) == {"1", "2", "4", "fit"}
    for b in ("1", "2", "4"):
        rec = snap["models"]["m"]["cost"][b]
        assert rec["count"] == 1 and rec["est_ms"] > 0
    assert snap["models"]["m"]["cost"]["fit"]["buckets_fit"] == 3
    assert snap["stats"]["shed_infeasible"] == 0
    assert snap["stats"]["shed_infeasible_door"] == 0
    gw.close()


def test_cost_model_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_GW_COST_MODEL", "0")
    gw = ServingGateway()
    assert gw.cost is None
    gw.close()
    monkeypatch.delenv("REPRO_GW_COST_MODEL")
    gw2 = ServingGateway()
    assert gw2.cost is not None
    gw2.close()


# ---------------------------------------------------------------------------
# End-to-end: deadline-hit-rate strictly improves over the launch-time-only
# baseline at the same offered load
# ---------------------------------------------------------------------------

_EXEC_S = 0.2  # known synthetic execute time: exact feasibility ground truth


def _offer_load(cost_enabled):
    """One offered load, two scheduling policies.

    3 doomed requests first (80ms budget < 200ms execute — they can NEVER
    finish), then after 90ms two feasible requests (450ms budget — serial
    capacity is exactly enough IF no slot is wasted on a doomed request).

    Launch-time-only baseline: a doomed request is launched inside its 80ms
    window, burns a 200ms slot, finishes far past its deadline, and pushes
    the second feasible request past ITS budget (miss at ~600ms vs 540ms).

    Cost model: warmup seeds est≈200ms, so every doomed request is shed at
    admission (drain estimate) or formation (execute estimate) and both
    feasible requests finish in time (~290/490ms vs 540ms).
    """
    gw = ServingGateway(
        max_pending=32, max_wait_ms=1.0, workers=1, cost_model=cost_enabled
    )
    gw.register(
        "m", _slow_model(_EXEC_S), example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1
    )
    gw.warmup()
    try:
        doomed = []
        for i in range(3):
            try:
                r = gw.submit_async("m", {"x": np.float32(10 + i)}, deadline_ms=80.0)
                doomed.append((None, r))
            except DeadlineExceededError as e:  # shed synchronously at the door
                doomed.append((e, None))
        time.sleep(0.09)  # doomed requests are now shed (cost) / running (base)
        feasible = []
        for i in range(2):
            t_sub = time.perf_counter()
            r = gw.submit_async("m", {"x": np.float32(i)}, deadline_ms=450.0)
            feasible.append((t_sub, r))

        recs = [{} for _ in feasible]

        def watch(req, rec):
            req.event.wait(10.0)
            rec["t_done"] = time.perf_counter()

        watchers = [
            threading.Thread(target=watch, args=(r, rec))
            for (_, r), rec in zip(feasible, recs)
        ]
        for w in watchers:
            w.start()
        for w in watchers:
            w.join()
        for _, r in doomed:
            if r is not None:
                assert r.event.wait(10.0)

        hits = sum(
            1
            for (t_sub, r), rec in zip(feasible, recs)
            if r.error is None and rec["t_done"] - t_sub <= 0.450
        )
        doomed_errors = [e if e is not None else r.error for e, r in doomed]
        results = [
            None if r.error is not None else float(np.asarray(r.result["y"]))
            for _, r in feasible
        ]
        snap = gw.snapshot()
    finally:
        gw.close()
    return hits, doomed_errors, results, snap


def test_e2e_deadline_hit_rate_improves_with_cost_model():
    base_hits, base_doomed, base_results, _ = _offer_load(cost_enabled=False)
    cost_hits, cost_doomed, cost_results, cost_snap = _offer_load(cost_enabled=True)

    # finish-time-feasible scheduling serves every feasible request inside
    # its budget; the launch-time-only baseline loses at least one to the
    # slot wasted on a doomed request
    assert cost_hits == 2, (cost_hits, cost_snap["stats"])
    assert cost_hits > base_hits, (cost_hits, base_hits)

    # every doomed request was shed (never served late) under the cost model,
    # and at least one carries the DISTINCT finish-time-infeasible error;
    # the baseline served at least one of them late (error is None)
    assert all(isinstance(e, DeadlineExceededError) for e in cost_doomed)
    assert any(isinstance(e, InfeasibleDeadlineError) for e in cost_doomed)
    stats = cost_snap["stats"]
    assert stats["shed_infeasible"] + stats["shed_infeasible_door"] >= 1
    assert any(e is None for e in base_doomed)

    # shed-precision ground truth: every shed request truly could not finish
    # (80ms budget < 200ms execute), so precision is exactly 1.0 here — and
    # served requests are bit-neutral: identical results with and without
    # feasibility shedding
    assert cost_results == [0.0, 2.0]
    for b, c in zip(base_results, cost_results):
        if b is not None:
            assert b == c
