"""TransformPlan: planner-vs-interpreter equivalence on the quickstart
(MovieLens) and LTR pipelines, output pruning + liveness, persistent jit
cache (no retrace per call), coercion/hash CSE, and the fit-peek economy."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    StringIndexEstimator,
    StringToStringListTransformer,
    TransformPlan,
)
from repro.core import types as T


def _assert_batch_equal(a, b, rtol=1e-6):
    assert set(a.keys()) == set(b.keys())
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        assert x.shape == y.shape, k
        if np.issubdtype(x.dtype, np.floating):
            np.testing.assert_allclose(x, y, rtol=rtol, atol=1e-6, err_msg=k)
        else:
            np.testing.assert_array_equal(x, y, err_msg=k)


@pytest.fixture(scope="module")
def movielens():
    rng = np.random.default_rng(1)
    n = 256
    batch = {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "MovieID": jnp.asarray(rng.integers(1, 200, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(
                rng.choice(["Action|Comedy", "Drama", "Action|Drama|Thriller"], n), 32
            )
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000,
            ),
            # UserID also vocab-indexed: seed-0 hash shared with nothing (the
            # hash indexer hashes the stringified id too -> CSE opportunity)
            StringIndexEstimator(
                inputCol="UserID", outputCol="UserID_vocab",
                inputDtype="string", numOOVIndices=1,
            ),
            StringIndexEstimator(
                inputCol="MovieID", outputCol="MovieID_indexed",
                inputDtype="string", numOOVIndices=1,
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=4, defaultValue="PADDED",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
        ]
    )
    fitted = pipe.fit(batch)
    return fitted, batch


def test_plan_matches_interpreter_quickstart(movielens):
    fitted, batch = movielens
    _assert_batch_equal(fitted.transform(batch), fitted.plan()(batch))


def test_plan_matches_interpreter_ltr():
    from repro.apps.ltr_pipeline import build_ltr_pipeline
    from repro.data import ltr_rows

    train = ltr_rows(96, seed=0)
    fitted, cols = build_ltr_pipeline(train)
    batch = {k: v[:24] for k, v in ltr_rows(48, seed=5).items()}
    ref = fitted.transform(batch)
    out = fitted.plan()(batch)
    _assert_batch_equal(ref, out)
    # constrained-output plan agrees column-by-column and prunes stages
    plan = fitted.plan(outputs=cols)
    sub = plan(batch)
    assert set(sub.keys()) == set(cols)
    for k in cols:
        np.testing.assert_allclose(
            np.asarray(ref[k]), np.asarray(sub[k]), rtol=1e-6, atol=1e-6, err_msg=k
        )
    assert plan.stats["n_stages"] < len(fitted.stages)


def test_plan_matches_naive_jit_bitwise(movielens):
    """Planned graph == whole-pipeline jit BIT-exactly (same XLA program
    modulo CSE — both compiled, so no eager-vs-fused float drift)."""
    fitted, batch = movielens
    ref = jax.jit(fitted.transform)(batch)
    out = fitted.plan()(batch)
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(out[k]), err_msg=k
        )


def test_plan_jit_cache_no_retrace(movielens):
    fitted, batch = movielens
    plan = TransformPlan(fitted.stages)
    plan(batch)
    plan(batch)
    plan(batch)
    assert plan.stats["trace_count"] == 1
    assert plan.stats["signatures_seen"] == 1
    # a new batch size retraces exactly once more
    half = {k: v[:128] for k, v in batch.items()}
    plan(half)
    plan(half)
    assert plan.stats["trace_count"] == 2
    assert plan.stats["signatures_seen"] == 2


def test_plan_hash_cse_shared(movielens):
    """Two stages hashing the same column share one fnv1a64 evaluation."""
    fitted, batch = movielens
    plan = fitted.plan()
    # UserID is consumed by both the hash indexer and the vocab indexer with
    # seed 0 -> at least one shared hash in the static estimate
    assert plan.cse_stats["hash_shared"] >= 1
    # and the shared-coercion count sees the duplicated string coercion
    assert plan.cse_stats["coerce_shared"] >= 1
    out = plan(batch)
    _assert_batch_equal(fitted.transform(batch), out)


def test_plan_liveness_drops_intermediates(movielens):
    fitted, batch = movielens
    plan = fitted.plan(outputs=["Genres_indexed"])
    out = plan.eager(batch)  # eager path exercises the dead_after drops
    assert set(out.keys()) == {"Genres_indexed"}
    np.testing.assert_array_equal(
        np.asarray(out["Genres_indexed"]),
        np.asarray(fitted.transform(batch)["Genres_indexed"]),
    )
    # some column must die before the end of the schedule
    assert any(n.dead_after for n in plan._nodes)


def test_transform_jit_cached_on_instance(movielens):
    fitted, batch = movielens
    out1 = fitted.transform_jit(batch)
    out2 = fitted.transform_jit(batch)
    _assert_batch_equal(out1, out2)
    assert fitted.plan().stats["trace_count"] == 1


def test_preprocess_model_jit_is_planned(movielens):
    fitted, batch = movielens
    model = fitted.export()
    out = model.jit()(batch)
    _assert_batch_equal(model(batch), out)
    assert model.jit() is model.jit()  # cached, not rebuilt


def test_export_serialisation_round_trip_stdlib_codecs(movielens, tmp_path):
    """save/load works without zstandard/msgpack (stdlib fallback format)."""
    fitted, batch = movielens
    model = fitted.export()
    blob = model.save_bytes()
    assert blob[:4] == b"RPP1"
    from repro.core.export import PreprocessModel

    model2 = PreprocessModel.load_bytes(blob)
    _assert_batch_equal(model(batch), model2(batch))
    p = tmp_path / "bundle.rpp"
    model.save(str(p))
    model3 = PreprocessModel.load(str(p))
    _assert_batch_equal(model(batch), model3(batch))


def test_fit_consumes_factory_once_per_pass():
    """The single cached peek is chained back into the first streaming pass:
    a one-epoch factory fully fits a single-pass pipeline."""
    rng = np.random.default_rng(2)
    batches = [
        {"x": jnp.asarray(T.encode_strings([f"w{rng.integers(0, 9)}" for _ in range(16)], 8))}
        for _ in range(3)
    ]
    calls = {"n": 0}

    def one_epoch_factory():
        calls["n"] += 1
        if calls["n"] > 1:
            raise AssertionError("factory re-instantiated for a 1-pass fit")
        return iter(batches)

    pipe = KamaeSparkPipeline(
        stages=[StringIndexEstimator(inputCol="x", outputCol="y", numOOVIndices=1)]
    )
    fitted = pipe.fit(one_epoch_factory)
    assert fitted.n_passes == 1
    # all 3 batches were seen: every word must be in-vocab (no OOV index)
    out = fitted.transform(batches[0])
    assert int(np.asarray(out["y"]).min()) >= 1
