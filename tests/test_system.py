"""End-to-end behaviour tests: the paper's core guarantee — a pipeline fit by
the (distributed) engine and the exported inference graph produce IDENTICAL
preprocessing — plus export pruning, serialisation, and fusion."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Engine,
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    OneHotEncodeEstimator,
    PreprocessModel,
    StandardScaleEstimator,
    StringIndexEstimator,
    StringToStringListTransformer,
    VectorAssembleTransformer,
    VectorDisassembleTransformer,
)
from repro.core import types as T


@pytest.fixture(scope="module")
def movielens_batch():
    rng = np.random.default_rng(0)
    n = 512
    return {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "MovieID": jnp.asarray(rng.integers(1, 200, n), jnp.int32),
        "Occupation": jnp.asarray(rng.integers(0, 21, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(
                rng.choice(
                    ["Action|Comedy", "Drama", "Action|Drama|Thriller", "Comedy"], n
                ),
                32,
            )
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }


@pytest.fixture(scope="module")
def fitted(movielens_batch):
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000, layerName="user_hash",
            ),
            StringIndexEstimator(
                inputCol="MovieID", outputCol="MovieID_indexed",
                inputDtype="string", stringOrderType="frequencyDesc",
                numOOVIndices=1, layerName="movie_idx",
            ),
            OneHotEncodeEstimator(
                inputCol="Occupation", outputCol="Occupation_indexed",
                inputDtype="string", numOOVIndices=1, dropUnseen=True,
                layerName="occ_onehot",
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=6, defaultValue="PADDED", layerName="genres_split",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED", layerName="genres_idx",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
            StandardScaleEstimator(inputCol="Price_log", outputCol="Price_scaled"),
        ]
    )
    return pipe.fit(movielens_batch)


def test_single_pass_fit(fitted):
    # all estimators depend only on transformers -> one streaming pass
    assert fitted.n_passes == 1


def test_engine_vs_export_parity(fitted, movielens_batch):
    """THE paper property: offline transform == exported online graph."""
    offline = fitted.transform(movielens_batch)
    model = fitted.build_keras_model()
    online = model(movielens_batch)
    for k in offline:
        np.testing.assert_allclose(
            np.asarray(offline[k]), np.asarray(online[k]), err_msg=k, rtol=1e-6
        )


def test_export_is_jittable_single_program(fitted, movielens_batch):
    model = fitted.build_keras_model()
    out = model.jit()(movielens_batch)
    ref = model(movielens_batch)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]), rtol=1e-6)


def test_serialisation_round_trip(fitted, movielens_batch):
    model = fitted.build_keras_model()
    blob = model.save_bytes()
    model2 = PreprocessModel.load_bytes(blob)
    a, b = model(movielens_batch), model2(movielens_batch)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), err_msg=k)


def test_dead_column_elimination(fitted, movielens_batch):
    model = fitted.export(outputs=["Price_scaled"])
    names = [n["op"] for n in model.nodes]
    assert "StringIndexEstimator" not in names  # genre/movie stages pruned
    out = model(movielens_batch)
    full = fitted.transform(movielens_batch)
    np.testing.assert_allclose(
        np.asarray(out["Price_scaled"]), np.asarray(full["Price_scaled"]), rtol=1e-6
    )


def test_frequency_ordering(fitted, movielens_batch):
    """frequencyDesc: most frequent genre gets the smallest vocab index."""
    out = fitted.transform(movielens_batch)
    idx = np.asarray(out["Genres_indexed"])
    # mask token occupies 0; indices >= 2 are vocab (1 OOV bucket at 1)
    assert idx.min() >= 0
    flat = idx[idx >= 2]
    counts = {i: int((flat == i).sum()) for i in np.unique(flat)}
    assert counts[min(counts)] >= counts[max(counts)]


def test_assemble_scale_disassemble(movielens_batch):
    """Paper §3 LTR pattern: assemble -> standard-scale -> disassemble."""
    pipe = KamaeSparkPipeline(
        stages=[
            VectorAssembleTransformer(inputCols=["Price", "Price"], outputCol="vec"),
            StandardScaleEstimator(outputCol="vec_s", inputCol="vec", featureSize=2),
            VectorDisassembleTransformer(inputCol="vec_s", outputCols=["p1", "p2"]),
        ]
    )
    fitted2 = pipe.fit(movielens_batch)
    out = fitted2.transform(movielens_batch)
    assert abs(float(out["p1"].mean())) < 1e-5
    assert abs(float(out["p1"].std()) - 1.0) < 1e-3
    np.testing.assert_allclose(np.asarray(out["p1"]), np.asarray(out["p2"]))


def test_streaming_fit_multiple_batches(movielens_batch):
    """Streaming over 4 batches == fitting the concatenation."""
    b = movielens_batch
    quarters = [
        {k: v[i * 128 : (i + 1) * 128] for k, v in b.items()} for i in range(4)
    ]
    mk = lambda: KamaeSparkPipeline(
        stages=[
            StandardScaleEstimator(inputCol="Price", outputCol="Price_s"),
            StringIndexEstimator(
                inputCol="MovieID", outputCol="MovieID_i", inputDtype="string"
            ),
        ]
    )
    f_stream = mk().fit(lambda: iter(quarters))
    f_full = mk().fit(b)
    o1, o2 = f_stream.transform(b), f_full.transform(b)
    np.testing.assert_allclose(
        np.asarray(o1["Price_s"]), np.asarray(o2["Price_s"]), rtol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(o1["MovieID_i"]), np.asarray(o2["MovieID_i"])
    )


def test_estimator_chain_needs_two_passes(movielens_batch):
    """An estimator consuming another estimator's output forces a 2nd pass."""
    pipe = KamaeSparkPipeline(
        stages=[
            StandardScaleEstimator(inputCol="Price", outputCol="Price_s"),
            StandardScaleEstimator(inputCol="Price_s", outputCol="Price_ss"),
        ]
    )
    fitted2 = pipe.fit(movielens_batch)
    assert fitted2.n_passes == 2
    out = fitted2.transform(movielens_batch)
    assert abs(float(out["Price_ss"].mean())) < 1e-5


def test_fused_model_matches_unfused(fitted, movielens_batch):
    from repro.serve import FusedModel

    w = jnp.asarray(np.random.default_rng(1).normal(0, 0.1, (21, 4)), jnp.float32)

    def model_fn(params, feats):
        return feats["Occupation_indexed"] @ params

    fm = FusedModel(fitted.export(outputs=["Occupation_indexed"]), model_fn, w)
    assert fm.donate  # serve-path default: request buffers are donated
    want = np.asarray(fm.call_unfused(movielens_batch))
    # the fused call consumes its request buffers (donation), so hand it a
    # private copy rather than the shared module fixture
    req = {k: jnp.array(v) for k, v in movielens_batch.items()}
    np.testing.assert_allclose(np.asarray(fm(req)), want, rtol=1e-6)
