"""Training substrate: loss goes down, grad-accum equivalence, checkpointing
(atomic, async, elastic), supervisor crash-restart, straggler detection."""
import pathlib
import subprocess
import sys
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.checkpoint import latest_step
from repro.data import lm_token_batches
from repro.ft import StragglerMonitor, Supervisor
from repro.models import registry
from repro.train import AdamWConfig, make_train_step
from repro.train.step import train_state_init

REPO = pathlib.Path(__file__).resolve().parents[1]


def _small_model():
    cfg = dataclasses.replace(configs.get("mamba2_780m").smoke(), n_layers=2)
    return cfg, registry.build(cfg)


def test_loss_decreases():
    cfg, model = _small_model()
    state = train_state_init(model, 0)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)))
    losses = []
    for batch in lm_token_batches(8, 64, cfg.vocab, 30, seed=0):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    # clear monotone-ish improvement over 30 steps of a 2-layer model
    assert losses[-1] < losses[0] - 0.05, losses[::10]
    assert min(losses[-5:]) < min(losses[:5]), losses[::10]


def test_grad_accum_equivalent():
    cfg, model = _small_model()
    ocfg = AdamWConfig(lr=1e-3)
    b = next(iter(lm_token_batches(8, 32, cfg.vocab, 1, seed=1)))
    s0 = train_state_init(model, 0)
    s1, m1 = jax.jit(make_train_step(model, ocfg, accum=1))(s0, b)
    s0 = train_state_init(model, 0)
    s2, m2 = jax.jit(make_train_step(model, ocfg, accum=4))(s0, b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for k in s1["params"]:
        np.testing.assert_allclose(
            np.asarray(s1["params"][k]), np.asarray(s2["params"][k]), atol=1e-5, err_msg=k
        )


def test_checkpoint_round_trip_and_retention(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 4))}}
    for step in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), step, tree, keep=2)
    assert latest_step(str(tmp_path)) == 4
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert dirs == ["step_00000003", "step_00000004"]  # retention
    back = load_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    leaf = next(pathlib.Path(path).glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] = 999
    np.save(leaf, arr)
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), tree)


def test_async_checkpoint_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((64, 64))}
    mgr.save_async(10, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 10
    back = mgr.restore_latest(tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones((64, 64)))


def test_supervisor_restarts_after_crash(tmp_path):
    """Failure injection: trainer crashes at step 6; supervisor restarts it;
    run resumes from the checkpoint and completes."""
    ckpt = tmp_path / "ck"
    hb = tmp_path / "hb.json"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "mamba2-780m", "--smoke", "--steps", "12", "--batch", "2",
        "--seq", "32", "--ckpt-dir", str(ckpt), "--ckpt-every", "4",
        "--heartbeat", str(hb), "--crash-at-step", "6",
    ]
    env = {
        "PYTHONPATH": str(REPO / "src"),
        "CRASH_SENTINEL": str(tmp_path / "crashed.sentinel"),
    }
    sup = Supervisor(cmd, str(hb), timeout_s=600, max_restarts=3, env=env)
    rc = sup.run(poll_s=0.3)
    assert rc == 0, sup.log
    assert sup.restarts == 1  # exactly one injected failure
    assert latest_step(str(ckpt)) == 12  # resumed from 4 and completed


def test_supervisor_completes_without_injection(tmp_path):
    ckpt = tmp_path / "ck"
    hb = tmp_path / "hb.json"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "mamba2-780m", "--smoke", "--steps", "8", "--batch", "2",
        "--seq", "32", "--ckpt-dir", str(ckpt), "--ckpt-every", "4",
        "--heartbeat", str(hb),
    ]
    env = {"PYTHONPATH": str(REPO / "src")}
    sup = Supervisor(cmd, str(hb), timeout_s=600, max_restarts=1, env=env)
    assert sup.run(poll_s=0.3) == 0
    assert latest_step(str(ckpt)) == 8


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(alpha=0.5, threshold=1.4, warmup_steps=3)
    for step in range(10):
        for rank in ("r0", "r1", "r2", "r3"):
            t = 1.0 if rank != "r2" else 2.5
            mon.report(rank, t + 0.01 * step)
    s = mon.summary()
    assert "r2" in s["flagged"] and len(s["flagged"]) == 1


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoint written unsharded restores under explicit new shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = load_checkpoint(str(tmp_path), tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    assert back["w"].sharding == sh["w"]
