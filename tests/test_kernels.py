"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import types as T

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# bloom_hash: bit-exactness of the 32-bit-limb FNV against the uint64 oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_bins,num_hashes,max_len", [
    (1000, 3, 16), (1 << 16, 5, 24), (7, 1, 8), (2**31 - 1, 2, 32),
])
def test_bloom_hash_bit_exact(num_bins, num_hashes, max_len):
    from repro.kernels.bloom_hash import ops, ref

    words = ["".join(RNG.choice(list("abcdefgh XYZ123!@"), RNG.integers(0, max_len)))
             for _ in range(300)]
    s = jnp.asarray(T.encode_strings(words, max_len))
    got = np.asarray(ops.bloom_indices(s, num_bins, num_hashes))
    want = np.asarray(ref.bloom_indices(s, num_bins, num_hashes))
    np.testing.assert_array_equal(got, want)


def test_bloom_hash_nested_shape():
    from repro.kernels.bloom_hash import ops

    s = jnp.asarray(T.encode_strings([["a", "b"], ["c", "d"]], 8))
    out = ops.bloom_indices(s, 100, 3)
    assert out.shape == (2, 2, 3)


@pytest.mark.parametrize("max_len", [8, 64, 256])
def test_bloom_hash_chunked_grid_matches_unrolled(max_len):
    """The byte-chunk grid (state carried in scratch across the minor grid
    dim) is bit-exact with the single-shot unrolled kernel — long strings no
    longer unroll max_len into the traced program."""
    from repro.kernels.bloom_hash.bloom_hash import (
        bloom_hash_kernel,
        bloom_hash_kernel_raw,
    )

    words = ["".join(RNG.choice(list("abcdefgh XYZ123!@"), RNG.integers(0, max_len)))
             for _ in range(200)]
    s = jnp.asarray(T.encode_strings(words, max_len)).astype(jnp.int32)
    ref = bloom_hash_kernel(s, 4096, 3, block_n=64, interpret=True, chunk_len=0)
    for chunk in (8, 32, 64):
        got = bloom_hash_kernel(s, 4096, 3, block_n=64, interpret=True, chunk_len=chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref), err_msg=f"chunk={chunk}")
    hi0, lo0 = bloom_hash_kernel_raw(s, 2, block_n=64, interpret=True, chunk_len=0)
    hi1, lo1 = bloom_hash_kernel_raw(s, 2, block_n=64, interpret=True, chunk_len=32)
    np.testing.assert_array_equal(np.asarray(hi0), np.asarray(hi1))
    np.testing.assert_array_equal(np.asarray(lo0), np.asarray(lo1))


def test_bloom_hash_chunk_env_override(monkeypatch):
    from repro.core import hashing
    from repro.kernels.bloom_hash import ops

    s = jnp.asarray(T.encode_strings(
        ["".join(RNG.choice(list("abcdef"), RNG.integers(0, 100))) for _ in range(50)], 128
    ))
    want = np.asarray(hashing.fnv1a64(s, 3))
    for chunk in ("16", "0", ""):
        if chunk:
            monkeypatch.setenv("REPRO_HASH_CHUNK", chunk)
        else:
            monkeypatch.delenv("REPRO_HASH_CHUNK", raising=False)
        np.testing.assert_array_equal(np.asarray(ops.fnv1a64_raw(s, 3)), want)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KV,hd,window,dtype", [
    (1, 128, 4, 2, 64, None, jnp.float32),
    (2, 256, 4, 4, 32, 64, jnp.float32),
    (1, 100, 2, 1, 64, None, jnp.float32),
    (1, 128, 4, 2, 64, None, jnp.bfloat16),
    (1, 64, 8, 8, 128, None, jnp.float32),
])
def test_flash_attention_kernel(B, S, H, KV, hd, window, dtype):
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.flash_attention import flash_attention_fwd

    q = jnp.asarray(RNG.normal(0, 1, (B, H, S, hd)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, KV, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, KV, S, hd)), dtype)
    scale = 1 / np.sqrt(hd)
    got = flash_attention_fwd(q, k, v, scale, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.attention(q, k, v, scale, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_kernel_grad_matches_sdpa():
    from repro.kernels.flash_attention import ops
    from repro.models.attention import _sdpa
    from repro.models import common as C

    q = jnp.asarray(RNG.normal(0, 1, (2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (2, 64, 2, 32)), jnp.float32)
    mask = C.causal_mask(64, 64)[None, None, None]
    g1 = jax.grad(lambda *a: jnp.sum(jnp.sin(ops.flash_attention(*a, 0.17))), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(jnp.sin(_sdpa(*a, mask, 0.17))), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 8, 16), (1, 96, 2, 32, 1, 16, 32), (1, 128, 8, 8, 8, 4, 64),
])
def test_ssd_kernel_vs_sequential(B, S, H, P, G, N, chunk):
    from repro.kernels.ssd_scan import ops, ref

    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), jnp.float32)
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_ssd_jnp_chunked_matches_sequential():
    from repro.kernels.ssd_scan import ref
    from repro.models.ssm import ssd_chunked

    B, S, H, P, G, N = 2, 64, 4, 16, 2, 8
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, G, N)), jnp.float32)
    got = ssd_chunked(x, dt, A, Bm, Cm, 16)
    want = ref.ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,R,chunk", [(2, 96, 64, 32), (1, 64, 128, 64), (1, 40, 32, 16)])
def test_rglru_kernel_vs_sequential(B, S, R, chunk):
    from repro.kernels.rglru_scan import ops, ref

    a = jnp.asarray(RNG.uniform(0.3, 0.999, (B, S, R)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (B, S, R)), jnp.float32)
    got = ops.rglru(a, x, chunk=chunk)
    want = ref.rglru_sequential(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,hd,W", [(2, 8, 2, 32, 200), (1, 4, 4, 64, 64), (1, 16, 1, 128, 512)])
def test_decode_attention_kernel(B, H, KV, hd, W):
    from repro.kernels.decode_attention import ops, ref

    q = jnp.asarray(RNG.normal(0, 1, (B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, W, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, W, KV, hd)), jnp.float32)
    valid = jnp.asarray(RNG.random(W) < 0.7)
    valid = valid.at[0].set(True)  # at least one valid slot
    got = ops.decode_attention(q, k, v, valid, 0.2)
    want = ref.decode_attention(
        q[:, 0], jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), valid, 0.2
    )[:, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
