"""Deterministic stand-in for the subset of ``hypothesis`` this suite uses.

Loaded by the root ``conftest.py`` only when the real package is missing.
``@given(...)`` turns a property test into a plain pytest function that runs
``max_examples`` times over pseudo-random draws; the RNG seed is derived from
the test's qualified name so failures reproduce run-to-run.  No shrinking —
the first failing example is reported as-is.
"""
from __future__ import annotations

import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def _as_strategy(obj) -> _Strategy:
    if isinstance(obj, _Strategy):
        return obj
    if isinstance(obj, (str, list, tuple)):
        seq = list(obj)
        return _Strategy(lambda rng: rng.choice(seq))
    raise TypeError(f"cannot coerce {obj!r} to a strategy")


def _integers(min_value=-(2**63), max_value=2**63 - 1):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value=-1e9, max_value=1e9, allow_nan=False, allow_infinity=False):
    def draw(rng):
        # mix uniform and edge draws so bounds get exercised
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return rng.uniform(min_value, max_value)

    return _Strategy(draw)


def _characters(min_codepoint=32, max_codepoint=126, **_ignored):
    return _Strategy(lambda rng: chr(rng.randint(min_codepoint, max_codepoint)))


def _text(alphabet=None, min_size=0, max_size=20):
    if alphabet is None:
        alphabet = _characters()
    alpha = _as_strategy(alphabet)

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(alpha.example(rng) for _ in range(n))

    return _Strategy(draw)


def _lists(elements, min_size=0, max_size=10):
    elem = _as_strategy(elements)

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elem.example(rng) for _ in range(n)]

    return _Strategy(draw)


def _sampled_from(seq):
    return _as_strategy(seq)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.text = _text
strategies.characters = _characters
strategies.lists = _lists
strategies.sampled_from = _sampled_from


def given(*strats):
    strats = tuple(_as_strategy(s) for s in strats)

    def decorate(fn):
        # NB: deliberately not functools.wraps — copying __wrapped__ /
        # the signature would make pytest treat property args as fixtures
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for i in range(n):
                vals = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property failed on example {i}: {vals!r}"
                    ) from e

        # honour @settings applied below @given (decorator order varies)
        runner._stub_max_examples = getattr(
            fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES
        )
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        # mirror the real attribute shape (pytest plugins peek at inner_test)
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner

    return decorate


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate
