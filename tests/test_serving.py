"""Serving layer: micro-batcher correctness under concurrency, greedy decode,
preprocessing tuner."""
import concurrent.futures as cf

import numpy as np
import jax
import jax.numpy as jnp

from repro.serve.batcher import MicroBatcher
from repro.serve import greedy_decode


def test_microbatcher_matches_direct():
    calls = []

    @jax.jit
    def model(feats):
        return feats["x"] * 2.0 + feats["y"][:, None]

    def model_fn(feats):
        calls.append(int(feats["x"].shape[0]))
        return model(feats)

    b = MicroBatcher(model_fn, max_batch=8, max_wait_ms=20.0)
    rng = np.random.default_rng(0)
    xs = rng.normal(0, 1, (40, 3)).astype(np.float32)
    ys = rng.normal(0, 1, (40,)).astype(np.float32)

    def one(i):
        return np.asarray(b.submit({"x": xs[i], "y": ys[i]}))

    with cf.ThreadPoolExecutor(max_workers=12) as ex:
        outs = list(ex.map(one, range(40)))
    for i, o in enumerate(outs):
        np.testing.assert_allclose(o, xs[i] * 2 + ys[i], rtol=1e-6)
    assert b.rows_served == 40
    assert b.batches_run < 40  # actually batched
    # padded batch sizes come from the bucket list
    assert all(c in (1, 2, 4, 8) for c in calls)
    b.close()


def test_microbatcher_propagates_errors():
    def bad(feats):
        raise ValueError("boom")

    b = MicroBatcher(bad, max_batch=4, max_wait_ms=1.0)
    import pytest

    with pytest.raises(ValueError):
        b.submit({"x": np.zeros(2, np.float32)})
    b.close()


def test_microbatcher_isolates_poisoned_request():
    """A batch whose model call raises is re-run request-by-request: the
    poisoned request gets ITS error, the rest still get results."""
    def picky(feats):
        x = np.asarray(feats["x"])
        if (x < 0).any():
            raise ValueError("poisoned feature")
        return jnp.asarray(x) * 2.0

    # long window so concurrent submits land in ONE batch
    b = MicroBatcher(picky, max_batch=8, max_wait_ms=50.0)
    xs = [1.0, -1.0, 3.0, 4.0]
    outs = [None] * len(xs)
    errs = [None] * len(xs)

    def one(i):
        try:
            outs[i] = b.submit({"x": np.float32(xs[i])}, timeout=10.0)
        except BaseException as e:
            errs[i] = e

    with cf.ThreadPoolExecutor(max_workers=4) as ex:
        list(ex.map(one, range(len(xs))))
    assert isinstance(errs[1], ValueError)
    for i in (0, 2, 3):
        assert errs[i] is None
        np.testing.assert_allclose(np.asarray(outs[i]), xs[i] * 2.0)
    assert b.rows_served == 3  # only successful rows counted
    b.close()


def test_microbatcher_close_drains_pending():
    """close() fails queued requests fast with BatcherClosedError instead of
    leaving their submitters blocked until timeout."""
    import time

    from repro.serve import BatcherClosedError

    def slow(feats):
        time.sleep(0.15)
        return jnp.asarray(feats["x"]) * 2.0

    b = MicroBatcher(slow, max_batch=1, max_wait_ms=1.0, buckets=(1,))
    results, errors = {}, {}

    def one(i):
        try:
            results[i] = b.submit({"x": np.float32(i)}, timeout=30.0)
        except BaseException as e:
            errors[i] = e

    with cf.ThreadPoolExecutor(max_workers=6) as ex:
        futs = [ex.submit(one, i) for i in range(6)]
        time.sleep(0.05)  # first request is mid-execution, rest are queued
        t0 = time.perf_counter()
        b.close()
        closed_in = time.perf_counter() - t0
        for f in futs:
            f.result(timeout=10)

    assert closed_in < 6.0
    assert len(results) + len(errors) == 6
    assert len(errors) >= 1  # queued requests drained...
    assert all(isinstance(e, BatcherClosedError) for e in errors.values())
    assert len(results) >= 1  # ...while in-flight work finished normally
    import pytest

    with pytest.raises(BatcherClosedError):
        b.submit({"x": np.float32(9.0)})


def test_greedy_decode_deterministic():
    from repro import configs
    from repro.models import registry

    cfg = configs.get("stablelm_3b").smoke()
    model = registry.build(cfg)
    params = model.init(0)
    prompts = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    out1 = greedy_decode(model, params, prompts, steps=6, max_len=32)
    out2 = greedy_decode(model, params, prompts, steps=6, max_len=32)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)
    assert (np.asarray(out1) >= 0).all() and (np.asarray(out1) < cfg.vocab).all()


def test_preprocessing_tuner_finds_better_bins():
    """Tuner (paper §2 Keras-Tuner analogue): searching numBins should find
    that more bins -> fewer collisions on a high-cardinality id column."""
    from repro.core import HashIndexTransformer, KamaeSparkPipeline
    from repro.core.tuning import Choice, PreprocessingTuner

    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 5000, 2048), jnp.int64)
    batch = {"id": ids}

    def build(hp):
        return KamaeSparkPipeline(
            stages=[
                HashIndexTransformer(
                    inputCol="id", outputCol="b", inputDtype="string",
                    numBins=hp["numBins"],
                )
            ]
        )

    def evaluate(fitted, hp):
        out = fitted.transform(batch)["b"]
        # collision rate proxy: distinct buckets vs distinct ids
        n_ids = len(np.unique(np.asarray(ids)))
        n_buckets = len(np.unique(np.asarray(out)))
        return 1.0 - n_buckets / n_ids

    tuner = PreprocessingTuner(
        build, evaluate, space=[Choice("numBins", [64, 1024, 65536])],
        mode="grid", max_trials=3,
    )
    best = tuner.search(batch)
    assert best.params["numBins"] == 65536
    assert len(tuner.trials) == 3
    assert best.score <= min(t.score for t in tuner.trials)


def test_prefetch_pipeline():
    from repro.data import BatchPipeline, prefetch

    src = [{"x": jnp.ones((4,)) * i} for i in range(5)]
    got = [float(b["x"][0]) for b in prefetch(iter(src), depth=2)]
    assert got == [0, 1, 2, 3, 4]

    bp = BatchPipeline(lambda: iter(src), engine=None, prefetch_depth=2)
    assert [float(b["x"][0]) for b in bp] == [0, 1, 2, 3, 4]
    assert [float(b["x"][0]) for b in bp] == [0, 1, 2, 3, 4]  # re-iterable

    def boom():
        yield {"x": jnp.zeros(1)}
        raise RuntimeError("producer died")

    import pytest

    with pytest.raises(RuntimeError):
        list(prefetch(boom(), depth=1))
