"""Shard-frame transport: unit coverage plus shm differential legs.

The fast tier (no markers) exercises the pieces in-process: the slot
ring's generation-stamped lifecycle (acquire/commit/read/release, FIFO
reuse, desync detection, exhaustion/oversize → inline fallback), the
numpy frame codec (flatten/unflatten, 64-byte leaf alignment, the
``ascontiguous`` no-copy identity the dispatch path relies on), a full
same-process create/attach shm round trip, the death reclaimer, and the
executor-side transport plumbing that needs no workers (warm-wire cache
and its ``set_example`` invalidation, zero-row part elision in
``_concat_outputs``).

The differential legs (``multihost``/``subprocess`` markers) rerun the
real multi-host streams with ``REPRO_MH_TRANSPORT=shm`` injected into
every child and assert BIT-IDENTITY against the pickle transport and the
1-process reference — the same contract tests/test_multihost.py pins for
pickle.  The ``chaos`` legs kill and drop+rejoin a worker mid-stream
under shm: recovery must hold AND no ``/dev/shm`` segment may outlive
the job (the reclaimer owns death-time unlink).
"""
import os
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from multihost import launch  # noqa: E402

from repro.transport import (  # noqa: E402
    FrameTooLargeError,
    PickleTransport,
    SharedMemoryTransport,
    SlotRing,
    TransportDesyncError,
    ascontiguous,
    flatten_payload,
    transport_kind,
    unflatten_payload,
)
from repro.transport.frames import read_leaves, write_leaves  # noqa: E402

SHM_ENV = {"REPRO_MH_TRANSPORT": "shm"}


def _shm_segments():
    try:
        return {f for f in os.listdir("/dev/shm") if f.startswith("repro_mh_")}
    except OSError:  # /dev/shm-less host: leak checks degrade to no-ops
        return set()


# ---------------------------------------------------------------------------
# slot ring
# ---------------------------------------------------------------------------


def test_slot_ring_lifecycle_and_fifo_reuse():
    buf = memoryview(bytearray(SlotRing.region_bytes(2, 256)))
    ring = SlotRing(buf, 0, 2, 256)
    idx, gen, payload = ring.acquire(10)
    payload[:10] = b"0123456789"
    ring.commit(idx, gen, 10)
    assert bytes(ring.read(idx, gen)) == b"0123456789"
    assert ring.in_flight == 1
    idx2, gen2, _ = ring.acquire(1)
    assert idx2 != idx
    ring.release(idx)
    ring.release(idx)  # idempotent
    ring.release(idx2)
    assert ring.in_flight == 0
    # FIFO free list: the first-released slot is handed out first
    idx3, gen3, _ = ring.acquire(1)
    assert idx3 == idx and gen3 > gen  # generation advanced on reuse
    ring.release(idx3)


def test_slot_ring_generation_desync_detected():
    buf = memoryview(bytearray(SlotRing.region_bytes(1, 128)))
    ring = SlotRing(buf, 0, 1, 128)
    idx, gen, _ = ring.acquire(4)
    ring.commit(idx, gen, 4)
    ring.release(idx)
    idx2, gen2, _ = ring.acquire(4)
    ring.commit(idx2, gen2, 4)
    with pytest.raises(TransportDesyncError):
        ring.read(idx, gen)  # stale generation: the slot moved on
    ring.release(idx2)


def test_slot_ring_oversize_exhaustion_and_reclaim():
    buf = memoryview(bytearray(SlotRing.region_bytes(1, 64)))
    ring = SlotRing(buf, 0, 1, 64)
    with pytest.raises(FrameTooLargeError):
        ring.acquire(65)  # larger than any slot
    idx, gen, _ = ring.acquire(8)
    ring.commit(idx, gen, 8)
    with pytest.raises(FrameTooLargeError):
        ring.acquire(8)  # ring exhausted
    assert ring.reclaim() == 1  # death path frees the stuck slot
    assert ring.in_flight == 0
    idx2, _, _ = ring.acquire(8)  # usable again
    ring.release(idx2)


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------


def test_flatten_unflatten_round_trip_nested():
    payload = {
        "items": np.arange(12, dtype=np.float32).reshape(3, 4),
        "ids": np.array(["a", "bb", "ccc"]),
        "nested": {"t": (np.int64(7), [np.ones(2), "tag"]), "none": None},
    }
    leaves, spec = flatten_payload(payload)
    assert all(isinstance(a, np.ndarray) for a in leaves)
    back = unflatten_payload(spec, leaves)
    np.testing.assert_array_equal(back["items"], payload["items"])
    np.testing.assert_array_equal(back["ids"], payload["ids"])
    assert back["nested"]["t"][0] == 7
    np.testing.assert_array_equal(back["nested"]["t"][1][0], np.ones(2))
    assert back["nested"]["t"][1][1] == "tag"
    assert back["nested"]["none"] is None


def test_write_read_leaves_aligned_and_exact():
    leaves = [
        np.arange(5, dtype=np.int32),
        np.random.default_rng(0).normal(size=(3, 7)).astype(np.float32),
    ]
    buf = memoryview(bytearray(4096))
    entries = write_leaves(buf, leaves)
    for _, _, off in entries:
        assert off % 64 == 0  # jax-cpu-friendly leaf alignment
    out = read_leaves(buf, entries, copy=True)
    for a, b in zip(leaves, out):
        np.testing.assert_array_equal(a, b)
    # copy=False views alias the buffer (the worker-side zero-copy read)
    views = read_leaves(buf, entries, copy=False)
    buf[entries[0][2]] = 0xFF
    assert views[0][0] != leaves[0][0]


def test_ascontiguous_identity_no_copy_when_contiguous():
    a = np.arange(24, dtype=np.float32).reshape(6, 4)
    assert ascontiguous(a) is a  # the dispatch fast path: NO copy
    rows = a[1:3]  # contiguous row-block view (the block-slicing shape)
    assert ascontiguous(rows) is rows  # still no copy
    col = a[:, :2]  # non-contiguous column view: must normalise
    fixed = ascontiguous(col)
    assert fixed is not col and fixed.flags["C_CONTIGUOUS"]
    np.testing.assert_array_equal(fixed, col)


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


def test_transport_kind_env_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_MH_TRANSPORT", raising=False)
    assert transport_kind() == "pickle"
    monkeypatch.setenv("REPRO_MH_TRANSPORT", "shm")
    assert transport_kind() == "shm"
    assert transport_kind("pickle") == "pickle"  # explicit override wins
    with pytest.raises(ValueError):
        transport_kind("carrier-pigeon")


def test_pickle_transport_is_identity():
    t = PickleTransport()
    payload = {"x": np.ones(3)}
    wire, token = t.encode_request(payload)
    assert wire is payload and token is None
    assert t.decode_request(wire) is payload
    out = {"y": np.zeros(2)}
    reply = t.encode_reply(out, None)
    assert reply is out  # no spans: nothing to wrap
    got, spans = t.decode_reply(reply)
    assert got is out and spans is None
    got, spans = t.decode_reply(t.encode_reply(out, [{"name": "s"}]))
    assert got is out and spans == [{"name": "s"}]
    assert t.stats()["kind"] == "pickle"
    t.release(None)
    t.close(unlink=True)


def test_shm_transport_same_process_round_trip():
    before = _shm_segments()
    coord = SharedMemoryTransport.create(nslots=2, slot_bytes=1 << 16)
    worker = SharedMemoryTransport.attach(**coord.handshake())
    try:
        payload = {
            "items": np.random.default_rng(1).normal(size=(8, 32)).astype(np.float32),
            "q": np.arange(8, dtype=np.float32),
        }
        frame, token = coord.encode_request(payload)
        assert token is not None and frame.inline is None  # rode the ring
        block = worker.decode_request(frame)
        for k in payload:
            np.testing.assert_array_equal(block[k], payload[k])
        reply = worker.encode_reply(
            {"score": block["q"] * 2}, spans=[{"name": "execute"}]
        )
        out, spans = coord.decode_reply(reply)
        coord.release(token)
        np.testing.assert_array_equal(out["score"], payload["q"] * 2)
        assert spans == [{"name": "execute"}]
        worker.note_incoming()  # next control frame frees the reply slot
        stats = coord.stats()
        assert stats["kind"] == "shm" and stats["frames"] >= 1
        assert stats["inline"] == 0 and stats["in_flight"] == 0
        assert stats["segment"] in _shm_segments() - before
    finally:
        worker.close()
        coord.close(unlink=True)
    assert _shm_segments() <= before  # no leaked segment


def test_shm_transport_oversize_falls_back_inline():
    coord = SharedMemoryTransport.create(nslots=1, slot_bytes=4096)
    worker = SharedMemoryTransport.attach(**coord.handshake())
    try:
        big = {"wide": np.zeros((64, 64), np.float64)}  # 32 KiB > one slot
        frame, token = coord.encode_request(big)
        assert token is None and frame.inline is not None
        out = worker.decode_request(frame)
        np.testing.assert_array_equal(out["wide"], big["wide"])
        coord.release(token)
        assert coord.stats()["inline"] == 1
    finally:
        worker.close()
        coord.close(unlink=True)


def test_death_reclaimer_pops_before_running_and_contains_errors():
    from repro.ft import DeathReclaimer

    calls = []
    r = DeathReclaimer()
    r.register(1, lambda: calls.append("a") or 2)
    r.register(1, lambda: calls.append("b") or 3)  # re-register replaces
    assert r.reclaim(1) == 3 and calls == ["b"]
    assert r.reclaim(1) is None  # popped: a second death path is a no-op
    r.register(2, lambda: 1 / 0)
    r.register(3, lambda: 1)
    assert r.reclaim(2) is None  # error contained, not raised
    assert r.reclaim_all() == 1  # only key 3 remained
    snap = r.snapshot()
    assert snap["reclaims"] >= 2 and snap["errors"] == 1
    r.register(4, lambda: calls.append("x"))
    r.forget(4)
    r.reclaim_all()
    assert "x" not in calls


# ---------------------------------------------------------------------------
# executor-side plumbing (no workers needed)
# ---------------------------------------------------------------------------


def test_concat_outputs_elides_zero_row_parts():
    from repro.serve.gateway.multihost import _concat_outputs

    parts = [
        {"s": np.arange(3, dtype=np.float32)},
        {"s": np.zeros((0,), np.float32)},
        {"s": np.arange(2, dtype=np.float32)},
    ]
    out = _concat_outputs(parts)
    np.testing.assert_array_equal(out["s"], [0, 1, 2, 0, 1])
    # all-empty: the first part is the canonical empty output
    empty = _concat_outputs([{"s": np.zeros((0,), np.float32)}] * 2)
    assert empty["s"].shape == (0,)


def test_warm_wire_frame_cached_and_invalidated_by_set_example():
    from repro.launch.mesh import ProcessMesh
    from repro.serve.gateway.multihost import MultiHostExecutor

    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), hedge=False)
    try:
        example = {"items": np.ones(4, np.float32)}
        ex.set_example("m", example, buckets=(2, 4))
        w1 = ex._warm_wire_frame("m", 1)
        w2 = ex._warm_wire_frame("m", 1)
        assert isinstance(w1, bytes) and w1 is w2  # re-pickle elided
        assert ex._warm_block("m", 1) is ex._warm_block("m", 1)
        ex.set_example("m", {"items": np.zeros(4, np.float32)}, buckets=(2, 4))
        w3 = ex._warm_wire_frame("m", 1)
        assert w3 is not w1  # new example → cache invalidated
        assert ex._warm_wire_frame("nope", 1) is None  # no example: no warm
    finally:
        ex.close(timeout_s=0.5)


# ---------------------------------------------------------------------------
# differential legs: the multi-host streams under REPRO_MH_TRANSPORT=shm
# ---------------------------------------------------------------------------


@pytest.mark.multihost
@pytest.mark.subprocess
def test_transport_roundtrip_shm_bit_identical_to_pickle_and_local():
    """The wide row-local model through the routed executor: shm outputs ==
    pickle outputs == the 1-process in-process outputs, bit for bit; the
    shm pair really negotiated (frames flowed through the ring, not the
    inline fallback) and no segment survived executor close."""
    payload = {"rows": 64, "width": 256, "iters": 4, "seed": 2}
    before = _shm_segments()
    ref = launch("transport_roundtrip", 1, payload)[0]
    pickle2 = launch(
        "transport_roundtrip", 2, payload,
        extra_env={"REPRO_MH_TRANSPORT": "pickle"},
    )[0]
    shm2 = launch("transport_roundtrip", 2, payload, extra_env=SHM_ENV)[0]
    for k in ref["outputs"]:
        np.testing.assert_array_equal(pickle2["outputs"][k], ref["outputs"][k])
        np.testing.assert_array_equal(shm2["outputs"][k], ref["outputs"][k])
    wt = shm2["ft"]["workers"]["process1"]["transport"]
    assert wt["kind"] == "shm"
    assert wt["frames"] > 0 and wt["in_flight"] == 0
    assert shm2["ft"]["transport"]["configured"] == "shm"
    assert pickle2["ft"]["workers"]["process1"]["transport"]["kind"] == "pickle"
    assert shm2["leaked_shm"] == []  # measured in-coordinator after close
    assert _shm_segments() <= before


@pytest.mark.parametrize("transport", ["pickle", "shm"])
@pytest.mark.multihost
@pytest.mark.subprocess
def test_zero_row_blocks_route_and_concat(transport):
    """rows < processes: a worker owns an EMPTY row block.  Dispatch must
    skip the zero-row execute (regression: it used to ship a 0-row block
    and concat a 0-row part) and outputs stay bit-identical to 1-process."""
    payload = {"rows": 2, "width": 16, "iters": 2, "seed": 4}
    ref = launch("transport_roundtrip", 1, payload)[0]
    got = launch(
        "transport_roundtrip", 3, payload,
        extra_env={"REPRO_MH_TRANSPORT": transport},
    )[0]
    for k in ref["outputs"]:
        assert got["outputs"][k].shape == ref["outputs"][k].shape
        np.testing.assert_array_equal(got["outputs"][k], ref["outputs"][k])


@pytest.mark.multihost
@pytest.mark.subprocess
def test_stream_shm_bit_identical():
    """The full plan stream of test_multihost.py, rerun on the shm data
    plane: per-process row blocks concat bit-identically to 1-process."""
    payload = {"seed": 3, "sizes": [16, 16, 12, 16, 8, 13], "pack": 2}
    before = _shm_segments()
    ref = launch("stream_plan", 1, payload)[0]
    parts = launch("stream_plan", 2, payload, extra_env=SHM_ENV)
    for i, ref_out in enumerate(ref["outputs"]):
        for k in ref_out:
            joined = np.concatenate(
                [p["outputs"][i][k] for p in parts], axis=0
            )
            np.testing.assert_array_equal(ref_out[k], joined, err_msg=f"batch {i} col {k}")
    assert _shm_segments() <= before


@pytest.mark.multihost
@pytest.mark.subprocess
def test_gateway_replay_shm_bit_identical():
    """The replayed gateway matrix over shm: every request's reply matches
    the 1-process reference bit for bit and the worker genuinely served."""
    payload = {"seed": 5, "requests": 48, "buckets": (2, 4, 8), "max_batch": 8}
    before = _shm_segments()
    ref = launch("gateway_replay", 1, payload)[0]
    got = launch("gateway_replay", 2, payload, extra_env=SHM_ENV)
    coord, worker = got[0], got[1]
    assert worker["batches"] > 0
    assert coord["stats"]["completed"] == payload["requests"]
    for i, (a, b) in enumerate(zip(ref["results"], coord["results"])):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    assert _shm_segments() <= before


# ---------------------------------------------------------------------------
# chaos under shm: death reclaim + rejoin renegotiation
# ---------------------------------------------------------------------------

_CHAOS_BASE = {
    "seed": 11,
    "requests": 40,
    "buckets": (2, 4, 8),
    "max_batch": 8,
    "heartbeat_s": 0.5,
    "cost_model": False,
    "traffic": "stream",
    "clients": 3,
}


@pytest.mark.chaos
@pytest.mark.multihost
@pytest.mark.subprocess
def test_chaos_kill_under_shm_reclaims_and_stays_bit_identical():
    """kill -9 mid-stream with the pair on shm: the reclaimer frees the
    dead worker's in-flight slots and unlinks its segment, survivors absorb
    the rows, results match the 1-process run, and /dev/shm is clean."""
    payload = dict(
        _CHAOS_BASE,
        faults=[{"process": 1, "type": "kill", "after_batches": 4}],
    )
    before = _shm_segments()
    ref_payload = dict(payload)
    ref_payload.pop("faults")
    ref = launch("gateway_chaos", 1, ref_payload, devices_per_proc=1)[0]
    coord = launch(
        "gateway_chaos", 2, payload, devices_per_proc=1,
        expendable=[1], extra_env=SHM_ENV,
    )[0]
    assert coord["worker_failed"] == 0, coord["errors"]
    assert coord["completed"] == payload["requests"]
    for i, (got, want) in enumerate(zip(coord["results"], ref["results"])):
        np.testing.assert_array_equal(got, want, err_msg=f"request {i}")
    ft = coord["ft"]
    assert ft["worker_deaths"] >= 1 and 1 in ft["dead"]
    assert ft["transport"]["configured"] == "shm"
    assert ft["transport"]["reclaimer"]["reclaims"] >= 1  # death freed the pair
    assert _shm_segments() <= before


@pytest.mark.chaos
@pytest.mark.multihost
@pytest.mark.subprocess
def test_chaos_rejoin_renegotiates_shm_bit_identical():
    """Drop + rejoin under shm: the first life's segment is reclaimed on
    death, the rejoined worker is warmed over pickle then renegotiates a
    FRESH shm pair, serves real traffic through it, and nothing leaks."""
    payload = dict(
        _CHAOS_BASE,
        requests=64,
        clients=2,
        gap_s=0.02,
        waves=2,
        wave_gap_s=0.8,
        rejoin_delay_s=0.2,
        faults=[{"process": 1, "type": "drop", "after_batches": 4, "rejoin": True}],
    )
    before = _shm_segments()
    ref_payload = dict(payload)
    ref_payload.pop("faults")
    ref = launch("gateway_chaos", 1, ref_payload, devices_per_proc=1)[0]
    parts = launch(
        "gateway_chaos", 2, payload, devices_per_proc=1, extra_env=SHM_ENV
    )
    coord, worker = parts[0], parts[1]
    assert coord["worker_failed"] == 0, coord["errors"]
    assert coord["completed"] == payload["requests"]
    for i, (got, want) in enumerate(zip(coord["results"], ref["results"])):
        np.testing.assert_array_equal(got, want, err_msg=f"request {i}")
    ft = coord["ft"]
    assert ft.get("worker_rejoins", 0) >= 1
    assert ft["dead"] == []  # back in rotation at shutdown
    assert worker["serves"] == 2 and worker["batches"] > 5
    # the second life renegotiated shm (a fresh segment, since the first
    # life's pair was reclaimed and unlinked on death)
    assert ft["workers"]["process1"]["transport"]["kind"] == "shm"
    assert ft["transport"]["reclaimer"]["reclaims"] >= 1
    assert _shm_segments() <= before
