"""Unified observability layer: span recorder, metrics registry, flight
recorder, exporters, env-knob registry, and the distributed stitching path.

Unit tests drive the recorder with a FAKE clock (deterministic ring
wraparound / sampling / flight-trigger assertions — no sleeps); the
``multihost``-marked test replays gateway traffic at N=2 and asserts the
coordinator ring holds ONE stitched tree per request (coordinator + worker
spans, clock-aligned, surviving a Chrome-export round trip); the ``chaos``
test kills a worker mid-stream and asserts the flight recorder froze the
reshard into a dump.  The static check at the bottom fails the suite when a
``REPRO_*`` knob lands in src/ without an ``envknobs`` registration and a
README mention.
"""
import gc
import json
import pathlib
import sys

import pytest

from repro.obs import envknobs, export, flight, metrics, report
from repro.obs import log as obs_log
from repro.obs import trace as obs_trace

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

REPO = pathlib.Path(__file__).resolve().parents[1]

pytestmark = pytest.mark.obs


class FakeClock:
    def __init__(self, t=100.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt
        return self.t


def _rec(**kw):
    kw.setdefault("capacity", 64)
    kw.setdefault("enabled", True)
    kw.setdefault("sample", 1.0)
    kw.setdefault("clock", FakeClock())
    return obs_trace.TraceRecorder(**kw)


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------


def test_span_nesting_and_parenting():
    rec = _rec()
    with rec.span("root", component="gw") as root:
        with rec.span("child") as child:
            assert rec.current() is child
            grand = rec.span("grand")
            grand.end()
    assert rec.current() is None
    assert child.trace_id == root.trace_id == grand.trace_id
    assert child.parent_id == root.span_id
    assert grand.parent_id == child.span_id
    assert root.parent_id == 0
    names = [s.name for s in rec.spans()]
    assert names == ["grand", "child", "root"]  # recorded at END time


def test_ring_wraparound_keeps_newest():
    clock = FakeClock()
    rec = _rec(capacity=4, clock=clock)
    for i in range(7):
        clock.tick()
        rec.span(f"s{i}").end()
    assert rec.recorded == 7
    assert [s.name for s in rec.spans()] == ["s3", "s4", "s5", "s6"]
    clock.tick()
    rec.span("s7").end()
    assert [s.name for s in rec.spans()] == ["s4", "s5", "s6", "s7"]


def test_disabled_and_unsampled_spans_are_null():
    off = _rec(enabled=False)
    assert off.span("x") is obs_trace.NULL
    assert off.recorded == 0

    never = _rec(sample=0.0)
    root = never.span("root")
    assert root is obs_trace.NULL
    # children of an unsampled root are null too (whole-trace decision)
    assert never.span("child", parent=root) is obs_trace.NULL

    always = _rec(sample=1.0)
    assert always.span("root").sampled
    # NULL is inert: mutators are no-ops and attrs never leak
    obs_trace.NULL.set("k", "v")
    assert obs_trace.NULL.attrs == {}
    obs_trace.NULL.end()


def test_head_sampling_is_per_trace():
    rec = _rec(sample=0.5)
    kept = dropped = 0
    for _ in range(200):
        root = rec.span("r", parent=None)
        child = rec.span("c", parent=root)
        # a trace is complete or absent, never partial
        assert child.sampled == root.sampled
        if root.sampled:
            kept += 1
            child.end()
            root.end()
        else:
            dropped += 1
    assert kept > 0 and dropped > 0


def test_end_is_idempotent_and_clamps_negative_durations():
    clock = FakeClock()
    rec = _rec(clock=clock)
    sp = rec.span("x")
    clock.tick(-5.0)  # clock anomaly: end before start
    sp.end()
    assert sp.t_end == sp.t_start  # clamped, duration 0
    t_end = sp.t_end
    clock.tick(50.0)
    sp.end()  # second end: no-op
    assert sp.t_end == t_end
    assert rec.recorded == 1


def test_error_capture_via_context_manager():
    rec = _rec()
    with pytest.raises(ValueError):
        with rec.span("boom"):
            raise ValueError("bad input")
    (sp,) = rec.spans()
    assert sp.attrs["error"] == "ValueError: bad input"


def test_ctx_parenting_and_ingest_offset():
    """The cross-host path: a (trace_id, span_id) ctx rides the wire, the
    worker records against it, and ingest() re-bases worker clocks."""
    coord = _rec(clock=FakeClock(100.0))
    worker = _rec(clock=FakeClock(5.0), process=1)

    shard = coord.span("mh.shard", component="mh")
    ctx = (shard.trace_id, shard.span_id)

    wsp = worker.span("shard.execute", component="shard", ctx=ctx)
    worker.clock.t += 0.25
    wsp.end()
    shard.end()

    offset = 100.0 - 5.0  # what the RTT-midpoint probe would estimate
    ingested = coord.ingest([wsp.as_tuple()], offset=offset)
    (w,) = ingested
    assert w.trace_id == shard.trace_id
    assert w.parent_id == shard.span_id
    assert w.process == 1
    assert w.t_start == pytest.approx(100.0)
    assert w.t_end - w.t_start == pytest.approx(0.25)  # offset-invariant
    tids = {s.trace_id for s in coord.spans()}
    assert tids == {shard.trace_id}  # one stitched trace


def test_capture_collects_this_threads_finished_spans():
    rec = _rec()
    rec.span("before").end()
    with rec.capture() as cap:
        with rec.span("a"):
            rec.span("b").end()
    assert [s.name for s in cap.spans] == ["b", "a"]


def test_event_is_instant():
    rec = _rec()
    ev = rec.event("mh.worker_death", component="mh", attrs={"process": 2})
    assert ev.t_end == ev.t_start
    assert rec.spans()[0].attrs["process"] == 2


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_instruments_typed_get_or_create():
    reg = metrics.MetricsRegistry()
    c = reg.counter("requests")
    c.inc()
    c.inc(2)
    assert reg.counter("requests") is c
    with pytest.raises(TypeError):
        reg.gauge("requests")
    g = reg.gauge("depth")
    g.set(7)
    h = reg.histogram("lat")
    for v in (0.001, 0.002, 0.004):
        h.record(v)
    h.record(float("nan"))  # dropped, not raised
    snap = reg.snapshot()
    assert snap["metrics"]["requests"] == 3
    assert snap["metrics"]["depth"] == 7
    assert snap["metrics"]["lat"]["count"] == 3
    # DDSketch quantile error bound (~4% relative) on p50
    assert snap["metrics"]["lat"]["p50"] == pytest.approx(0.002, rel=0.05)


def test_metrics_sources_are_weakly_held():
    reg = metrics.MetricsRegistry()

    class Owner:
        def snap(self):
            return {"alive": 1}

    o = Owner()
    reg.register_source("owner", o.snap)
    assert reg.snapshot()["sources"]["owner"] == {"alive": 1}
    del o
    gc.collect()
    assert "owner" not in reg.snapshot()["sources"]


def test_metrics_source_last_registration_wins_and_owner_checked_unregister():
    reg = metrics.MetricsRegistry()

    class Owner:
        def __init__(self, tag):
            self.tag = tag

        def snap(self):
            return {"tag": self.tag}

    a, b = Owner("a"), Owner("b")
    reg.register_source("gw", a.snap)
    reg.register_source("gw", b.snap)  # replaces a
    assert reg.snapshot()["sources"]["gw"] == {"tag": "b"}
    reg.unregister_source("gw", obj=a)  # a no longer owns the name: no-op
    assert reg.snapshot()["sources"]["gw"] == {"tag": "b"}
    reg.unregister_source("gw", obj=b)
    assert "gw" not in reg.snapshot()["sources"]


def test_metrics_failing_source_does_not_poison_the_poll():
    reg = metrics.MetricsRegistry()
    reg.register_source("sick", lambda: 1 / 0)
    reg.counter("ok").inc()
    snap = reg.snapshot()
    assert snap["metrics"]["ok"] == 1
    assert "ZeroDivisionError" in snap["sources"]["sick"]["error"]


def test_render_text_flattens_sorted_lines():
    reg = metrics.MetricsRegistry()
    reg.counter("b").inc(2)
    reg.counter("a").inc()
    text = metrics.render_text(reg.snapshot())
    lines = text.splitlines()
    assert "metrics.a 1.0" in lines
    assert "metrics.b 2.0" in lines
    assert lines == sorted(lines)
    json.loads(metrics.render_json(reg.snapshot()))  # valid JSON


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_trigger_freezes_last_n_and_cooldown(tmp_path):
    clock = FakeClock()
    rec = _rec(clock=clock)
    reg = metrics.MetricsRegistry()
    reg.counter("deaths").inc()
    fl = flight.FlightRecorder(
        recorder=rec, registry=reg, last_n=3, out_dir=str(tmp_path),
        enabled=True, cooldown_s=1.0, clock=clock,
    )
    for i in range(5):
        rec.span(f"s{i}").end()
    dump = fl.trigger("worker_failed", component="mh", attrs={"process": 2})
    assert dump is not None
    assert [s[3] for s in dump["spans"]] == ["s2", "s3", "s4"]  # last 3
    assert dump["metrics"]["metrics"]["deaths"] == 1
    assert dump["attrs"] == {"process": 2}
    # within the cooldown window: suppressed (per reason)
    assert fl.trigger("worker_failed") is None
    assert fl.trigger("reshard") is not None  # different reason fires
    clock.tick(2.0)
    assert fl.trigger("worker_failed") is not None
    assert fl.dumps == 3
    assert [d["reason"] for d in fl.history] == [
        "worker_failed", "reshard", "worker_failed",
    ]
    # dumps landed on disk and render through the terminal viewer
    files = sorted(tmp_path.glob("flight-*.json"))
    assert len(files) == 3
    text = report.render_file(str(files[0]))
    assert "worker_failed" in text and "s4" in text


def test_flight_disabled_never_dumps():
    fl = flight.FlightRecorder(recorder=_rec(), enabled=False)
    assert fl.trigger("worker_failed") is None
    assert fl.dumps == 0


# ---------------------------------------------------------------------------
# export / report
# ---------------------------------------------------------------------------


def test_chrome_export_round_trips():
    rec = _rec()
    with rec.span("request", component="gw", attrs={"model": "m"}) as root:
        rec.span("queue").end()
        rec.event("plan.trace")
    tuples = [s.as_tuple() for s in rec.spans()]
    doc = export.to_chrome(tuples)
    assert all(ev["ph"] in ("X", "i") for ev in doc["traceEvents"])
    back = export.from_chrome(doc)
    # identity, structure and timing are exact; attrs come back stringified
    assert [(b[0], b[1], b[2], b[3], b[4], b[7]) for b in back] == [
        (t[0], t[1], t[2], t[3], t[4], t[7]) for t in tuples
    ]
    for b, t in zip(back, tuples):
        assert b[5] == pytest.approx(t[5], abs=0)
        assert b[6] == pytest.approx(t[6], abs=0)
    assert back[2][3] == "request"
    assert back[2][8]["model"] == "m"
    assert root.trace_id == back[0][0]


def test_chrome_export_file_round_trip(tmp_path):
    rec = _rec()
    rec.span("a").end()
    path = export.write_chrome_trace(str(tmp_path / "t.json"), rec.spans())
    assert export.load_chrome_trace(path)[0][3] == "a"
    text = report.render_file(path)
    assert "a" in text


def test_report_tree_indents_children():
    rec = _rec()
    with rec.span("request", component="gw"):
        rec.span("queue").end()
    text = report.format_trace_tree([s.as_tuple() for s in rec.spans()])
    lines = text.splitlines()
    assert lines[0].startswith("trace ")
    req = next(line for line in lines if "request" in line)
    q = next(line for line in lines if "queue" in line)
    assert len(q) - len(q.lstrip()) > len(req) - len(req.lstrip())


# ---------------------------------------------------------------------------
# structured log
# ---------------------------------------------------------------------------


def test_log_level_floor_and_component_debug_flag(monkeypatch, capsys):
    monkeypatch.delenv("REPRO_OBS_LOG", raising=False)
    monkeypatch.delenv("REPRO_FT_DEBUG", raising=False)
    assert not obs_log.enabled_for("debug", "ft")
    assert obs_log.enabled_for("info", "ft")
    monkeypatch.setenv("REPRO_FT_DEBUG", "1")
    assert obs_log.enabled_for("debug", "ft")  # historical flag still works
    assert not obs_log.enabled_for("debug", "gw")  # only the ft component
    monkeypatch.setenv("REPRO_OBS_LOG", "error")
    monkeypatch.setenv("REPRO_FT_DEBUG", "off")  # PR-7 truthiness: off = off
    assert not obs_log.enabled_for("warn", "gw")
    obs_log.warn("gw", "suppressed")
    obs_log.error("gw", "shown", code=7)
    err = capsys.readouterr().err
    assert "suppressed" not in err
    assert "ERROR gw: shown code=7" in err


# ---------------------------------------------------------------------------
# env knob registry: the static check
# ---------------------------------------------------------------------------


def test_every_src_knob_is_registered_and_documented():
    """The former inline regex scan, promoted to an analyzer rule (PR 9):
    one implementation in ``repro.analyze.knobcheck``, asserted here via
    its API so the obs suite still guards the knob discipline."""
    from repro.analyze import knobcheck

    refs = knobcheck.knob_refs(REPO / "src")
    assert refs, "no REPRO_* references found under src/ — scanner broken?"
    rep = knobcheck.check(REPO / "src", REPO / "README.md")
    assert rep.ok(), "\n" + rep.format_text()


def test_every_registered_knob_is_documented_in_readme():
    from repro.analyze import knobcheck

    # registered-but-undocumented knobs surface as env-knob-undocumented
    # even when nothing in src/ references them (registry drift)
    rep = knobcheck.check(REPO / "src", REPO / "README.md")
    assert not rep.by_rule(knobcheck.KNOB_UNDOCUMENTED), (
        "\n" + rep.format_text()
    )
    # and the rule does fire on drift: a knob registered but absent from
    # the README is an error
    rep2 = knobcheck.check(
        REPO / "src", REPO / "README.md",
        knobs={**envknobs.KNOBS, "REPRO_NOT_IN_README": object()},
    )
    drift = rep2.by_rule(knobcheck.KNOB_UNDOCUMENTED)
    assert drift and "REPRO_NOT_IN_README" in drift[0].message


def test_env_parsers_truthiness_and_fallbacks(monkeypatch):
    for falsy in ("0", "false", "No", " OFF ", ""):
        monkeypatch.setenv("REPRO_X", falsy)
        assert envknobs.env_flag("REPRO_X", True) is False
        assert envknobs.env_tristate("REPRO_X") is False
    monkeypatch.setenv("REPRO_X", "1")
    assert envknobs.env_flag("REPRO_X", False) is True
    monkeypatch.delenv("REPRO_X")
    assert envknobs.env_flag("REPRO_X", True) is True
    assert envknobs.env_tristate("REPRO_X") is None
    monkeypatch.setenv("REPRO_Y", "not-a-number")
    assert envknobs.env_float("REPRO_Y", 2.5) == 2.5
    assert envknobs.env_int("REPRO_Y", 3) == 3
    monkeypatch.setenv("REPRO_Y", "7")
    assert envknobs.env_int("REPRO_Y", 3) == 7


# ---------------------------------------------------------------------------
# LatencySketch snapshot memoization (satellite)
# ---------------------------------------------------------------------------


def test_latency_sketch_snapshot_memoized_by_update_count():
    from repro.serve.gateway.telemetry import LatencySketch

    sk = LatencySketch()
    for v in (0.001, 0.002, 0.003):
        sk.record(v)
    first = sk.snapshot_us()
    assert sk.recomputes == 1
    # nothing recorded since: cached, no recompute, equal content
    again = sk.snapshot_us()
    assert sk.recomputes == 1
    assert again == first
    # the cached snapshot is a COPY: caller mutation cannot poison the cache
    again["count"] = 999
    assert sk.snapshot_us()["count"] == 3
    # different quantile tuple = different cache key
    sk.snapshot_us(qs=(0.5,))
    assert sk.recomputes == 2
    sk.record(0.004)
    fresh = sk.snapshot_us()
    assert sk.recomputes == 3
    assert fresh["count"] == 4


# ---------------------------------------------------------------------------
# obs.snapshot integration
# ---------------------------------------------------------------------------


def test_obs_snapshot_folds_sources_trace_flight_and_env(monkeypatch):
    import repro.obs as obs

    reg = metrics.MetricsRegistry()
    rec = _rec()
    monkeypatch.setattr(metrics, "_default", reg)
    monkeypatch.setattr(obs_trace, "_default", rec)
    reg.register_source("gateway", lambda: {"stats": {"completed": 5}})
    rec.span("request").end()
    monkeypatch.setenv("REPRO_OBS_SAMPLE", "0.25")
    snap = obs.snapshot()
    assert snap["sources"]["gateway"]["stats"]["completed"] == 5
    assert snap["trace"]["recorded"] == 1
    assert snap["trace"]["in_ring"] == 1
    assert "dumps" in snap["flight"]
    assert snap["env"]["REPRO_OBS_SAMPLE"] == "0.25"


# ---------------------------------------------------------------------------
# gateway trace integration (single process, real jax)
# ---------------------------------------------------------------------------


def test_gateway_request_emits_one_spanned_trace(monkeypatch):
    from repro.serve import ServingGateway
    import numpy as np

    # real clock: the gateway stamps span times with its own perf_counter
    rec = obs_trace.TraceRecorder(capacity=1024, enabled=True, sample=1.0)
    monkeypatch.setattr(obs_trace, "_default", rec)

    gw = ServingGateway(max_pending=32, max_wait_ms=1.0, workers=1,
                        cost_model=False)
    gw.register(
        "double",
        lambda b: {"y": np.asarray(b["x"]) * 2.0},
        example={"x": np.float32(1.0)},
        buckets=(1, 2),
        max_batch=2,
    )
    gw.warmup()
    out = gw.submit("double", {"x": np.float32(3.0)}, timeout=30.0)
    assert float(np.asarray(out["y"])) == 6.0
    gw.close()

    roots = [s for s in rec.spans() if s.name == "request"]
    assert roots, "no request root span recorded"
    root = roots[-1]
    tree = rec.trace(root.trace_id)
    names = {s.name for s in tree}
    assert {"request", "admission", "queue", "sched.form", "execute"} <= names
    by_id = {s.span_id: s for s in tree}
    for s in tree:
        assert s.t_end >= s.t_start
        if s.parent_id:
            assert s.parent_id in by_id, f"{s.name} parent missing from trace"
    # the root's duration covers the whole request
    exe = next(s for s in tree if s.name == "execute")
    assert root.t_start <= exe.t_start and exe.t_end <= root.t_end + 1e-6


def test_gateway_shed_requests_end_their_root_span_with_error(monkeypatch):
    import time

    from repro.serve import QueueFullError, ServingGateway
    import numpy as np

    rec = obs_trace.TraceRecorder(capacity=1024, enabled=True, sample=1.0)
    monkeypatch.setattr(obs_trace, "_default", rec)

    def slow(batch):
        time.sleep(0.1)
        return {"y": np.asarray(batch["x"]) * 2.0}

    gw = ServingGateway(max_pending=2, max_wait_ms=1.0, workers=1,
                        cost_model=False)
    gw.register("slow", slow, example={"x": np.float32(0.0)},
                buckets=(1,), max_batch=1)
    gw.warmup()
    admitted, rejected = [], 0
    for i in range(8):
        try:
            admitted.append(gw.submit_async("slow", {"x": np.float32(i)}))
        except QueueFullError:
            rejected += 1
    assert rejected >= 1
    for r in admitted:
        r.event.wait(5)
    gw.close()
    errored = [
        s for s in rec.spans()
        if s.name == "request" and "QueueFullError" in s.attrs.get("error", "")
    ]
    assert len(errored) == rejected, (
        "each door-shed request must end its root span with the error"
    )


# ---------------------------------------------------------------------------
# distributed stitching + chaos flight (subprocess tiers)
# ---------------------------------------------------------------------------


@pytest.mark.multihost
@pytest.mark.subprocess
def test_multihost_trace_stitches_one_tree_across_processes():
    from multihost import launch

    coord = launch("gateway_obs", 2, {"requests": 8}, devices_per_proc=1)[0]
    assert coord["completed"] == 8
    spans = coord["spans"]
    assert spans, "coordinator ring is empty"
    by_trace = {}
    for s in spans:
        by_trace.setdefault(s[0], []).append(s)
    stitched = [
        tid for tid, ss in by_trace.items()
        if any(x[3] == "request" for x in ss) and any(x[7] != 0 for x in ss)
    ]
    assert stitched, "no request trace contains worker-process spans"
    ss = by_trace[stitched[0]]
    names = {x[3] for x in ss}
    assert {"request", "execute", "mh.shard", "shard.execute"} <= names
    ids = {x[1] for x in ss}
    for x in ss:
        assert x[6] - x[5] >= 0, f"negative duration after clock alignment: {x}"
        if x[2]:
            assert x[2] in ids, f"span {x[3]} parent missing from its trace"
    # worker spans hang off coordinator mh.shard spans
    shard_ids = {x[1] for x in ss if x[3] == "mh.shard"}
    wspans = [x for x in ss if x[7] != 0]
    assert wspans and any(x[2] in shard_ids for x in wspans)
    # N=2 stitched trace survives the Chrome exporter round trip
    back = export.from_chrome(export.to_chrome(ss))
    assert {(b[0], b[1], b[2], b[3], b[7]) for b in back} == {
        (s[0], s[1], s[2], s[3], s[7]) for s in ss
    }


@pytest.mark.chaos
@pytest.mark.multihost
@pytest.mark.subprocess
def test_chaos_worker_kill_freezes_reshard_into_flight_dump():
    from multihost import launch

    payload = {
        "seed": 11,
        "requests": 40,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "heartbeat_s": 0.5,
        "cost_model": False,
        "traffic": "stream",
        "clients": 3,
        "faults": [{"process": 1, "type": "kill", "after_batches": 4}],
    }
    coord = launch("gateway_chaos", 2, payload, devices_per_proc=1,
                   expendable=[1])[0]
    assert coord["completed"] == payload["requests"]
    flights = coord["flights"]
    assert flights, "worker kill produced no flight dumps"
    reasons = {f["reason"] for f in flights}
    assert "reshard" in reasons or "worker_failed" in reasons
    reshard_dumps = [f for f in flights if f["reason"] == "reshard"]
    assert reshard_dumps, f"no reshard flight dump (got {sorted(reasons)})"
    assert any(
        "mh.reshard" in f["span_names"] for f in reshard_dumps
    ), "reshard flight dump does not contain the mh.reshard span"
