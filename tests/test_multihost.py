"""Multi-host streaming and serving, differentially tested.

The launcher (``tests/multihost.py``) spawns N subprocesses with a shared
coordinator address over fake CPU devices; these tests assert the headline
contract: the SAME TransformPlan stream and the SAME replayed gateway
traffic produce BIT-IDENTICAL results on 1-process and N-process meshes.

Bit-identity is asserted on hash/vocab-index/affine stages — ops XLA CPU
computes identically at any shard width.  Transcendental stages (log) are
only ulp-close across widths (vectorised libm), which is a property of the
compiler, not of the multi-host machinery under test here.

Topology arithmetic (no subprocesses, no extra devices) is tested at the
bottom; everything spawning processes carries ``multihost`` (and
``subprocess``) markers so constrained hosts can deselect.
"""
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from multihost import launch  # noqa: E402


def _join_outputs(per_proc, batch_idx, keys):
    """Concatenate one batch's per-process blocks in process order."""
    return {
        k: np.concatenate(
            [p["outputs"][batch_idx][k] for p in per_proc], axis=0
        )
        for k in keys
    }


@pytest.mark.parametrize("nproc", [2, 3])
@pytest.mark.multihost
@pytest.mark.subprocess
def test_stream_differential_bit_identical(nproc):
    """The same plan stream: 1-process output == concat of N per-process
    row blocks, bit-for-bit, including uneven batch sizes and leftovers."""
    payload = {"seed": 3, "sizes": [16, 16, 12, 16, 8, 13], "pack": 2}
    ref = launch("stream_plan", 1, payload)[0]
    parts = launch("stream_plan", nproc, payload)
    assert len({tuple(p["fingerprint"]) for p in parts}) == 1  # one job identity
    total_local = sum(p["stats"]["local_rows"] for p in parts)
    assert total_local == sum(payload["sizes"])  # every row fed exactly once
    for i, ref_out in enumerate(ref["outputs"]):
        keys = set(ref_out)
        joined = _join_outputs(parts, i, keys)
        for k in keys:
            np.testing.assert_array_equal(ref_out[k], joined[k], err_msg=f"batch {i} col {k}")


@pytest.mark.multihost
@pytest.mark.subprocess
def test_gateway_differential_replay_bit_identical():
    """The same replayed traffic through a 1-process gateway and through the
    2-process routed gateway (coordinator + shard worker): every request's
    reply is bit-identical, no post-warmup traces anywhere in the job, and
    the worker actually executed batches."""
    payload = {"seed": 5, "requests": 48, "buckets": (2, 4, 8), "max_batch": 8}
    ref = launch("gateway_replay", 1, payload)[0]
    got = launch("gateway_replay", 2, payload)
    coord, worker = got[0], got[1]
    assert coord["shards"] == 2
    assert coord["traces_since_warmup"] == 0
    assert worker["batches"] > 0  # routing genuinely crossed processes
    assert coord["stats"]["completed"] == payload["requests"]
    assert len(ref["results"]) == len(coord["results"])
    for i, (a, b) in enumerate(zip(ref["results"], coord["results"])):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


@pytest.mark.multihost
@pytest.mark.subprocess
def test_gateway_replay_with_cost_model_routes_and_completes():
    """Cost model on: warmup seeds per-(model, bucket) estimates from the
    coordinator's measured routed wall times and traffic still completes
    bit-identically to the launch-time-only configuration."""
    base = {"seed": 9, "requests": 24, "buckets": (2, 4), "max_batch": 4}
    ref = launch("gateway_replay", 2, dict(base, cost_model=False))[0]
    got = launch("gateway_replay", 2, dict(base, cost_model=True))[0]
    assert got["stats"]["completed"] == base["requests"]
    for a, b in zip(ref["results"], got["results"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.multihost
@pytest.mark.subprocess
def test_jax_distributed_topology_and_global_staging():
    """REAL jax.distributed over fake devices: every process derives the
    same job topology from the runtime, and global batch assembly places
    exactly the addressable rows on each process."""
    n = 16
    res = launch("jaxdist_topology", 2, {"rows": n})
    p0, p1 = res
    for p in res:
        assert p["num_processes"] == 2
        assert p["global_devices"] == 4 and p["local_devices"] == 2
        assert not p["fully_addressable"]
        assert p["staged_shape"] == (n,)
    # one topology, agreed upon by every process
    assert p0["shard_process"] == p1["shard_process"]
    assert p0["fingerprint"] == p1["fingerprint"]
    # the fingerprint records the process topology
    assert p0["num_processes"] in p0["fingerprint"]
    # row blocks partition the batch in process order
    assert p0["row_block"] == (0, n // 2)
    assert p1["row_block"] == (n // 2, n)
    # each process staged exactly its own rows, per addressable shard
    rows = np.arange(n, dtype=np.float32) * 2.0
    for p in res:
        for start, data in p["staged_shards"]:
            np.testing.assert_array_equal(data, rows[start : start + len(data)])
        # gather_addressable (the materialize="host" path's multi-host-safe
        # readback) returns exactly this process's addressable row block of
        # the non-fully-addressable global array
        s, e = p["addressable_block"]
        np.testing.assert_array_equal(p["gathered"], rows[s:e])


# ---------------------------------------------------------------------------
# topology arithmetic (in-process, no devices beyond the default one)
# ---------------------------------------------------------------------------


def test_process_mesh_row_blocks_and_fingerprints():
    from repro.launch.mesh import ProcessMesh

    pm0 = ProcessMesh.emulated(4, 0)
    pm3 = ProcessMesh.emulated(4, 3)
    shards = pm0.num_data_shards
    assert pm0.shard_process == pm3.shard_process
    assert pm0.fingerprint() == pm3.fingerprint()
    assert pm0.local_fingerprint() != pm3.local_fingerprint()
    # blocks partition [0, n) in process order, covering every row once
    for n in (7, 8, 64, 129):
        blocks = [
            ProcessMesh.emulated(4, p).row_block(n) for p in range(4)
        ]
        assert blocks[0][0] == 0 and blocks[-1][1] == n
        for (a, b), (c, d) in zip(blocks, blocks[1:]):
            assert b == c
    # uneven split follows array_split: leading shards one row longer
    sizes = [b - a for a, b in pm0.shard_row_blocks(shards + 1)]
    assert sizes[0] == 2 and set(sizes[1:]) == {1}


def test_process_mesh_rejects_bad_topologies():
    import jax

    from repro.launch.mesh import ProcessMesh

    with pytest.raises(ValueError):
        ProcessMesh.emulated(2, 2)  # process_id out of range
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        # 1 shard cannot partition over 2 virtual hosts
        ProcessMesh.virtual(mesh, 2)
    with pytest.raises(ValueError):
        ProcessMesh(
            process_id=0,
            num_processes=2,
            shard_process=(0, 1, 0, 1),  # non-contiguous ownership
            local_mesh=mesh,
        )


def test_runner_rejects_engine_and_process_mesh_together():
    from repro.core import PlanRunner
    from repro.core.engine import Engine
    from repro.launch.mesh import ProcessMesh

    class _Plan:  # never executed: the constructor must raise first
        def jit_for(self, **kw):
            return lambda b: b

        def required_inputs(self):
            return None

    with pytest.raises(ValueError):
        PlanRunner(_Plan(), engine=Engine(None), process_mesh=ProcessMesh.emulated(1, 0))
    with pytest.raises(ValueError):
        PlanRunner(_Plan(), process_mesh=ProcessMesh.emulated(1, 0), shard_mode="bogus")


def test_registry_filters_sub_shard_buckets():
    """A routed servable never gets a bucket smaller than its process count
    (that would ship zero-row blocks); with no feasible bucket, registration
    fails loudly."""
    from repro.serve.gateway.registry import ModelRegistry

    class FakeServable:
        self_staging = True
        num_processes = 2

        def __call__(self, cols):
            return cols

        def trace_count(self):
            return 0

    reg = ModelRegistry()
    e = reg.register(
        "m", FakeServable(), example={"x": np.float32(0)}, buckets=(1, 2, 4), max_batch=4
    )
    assert e.buckets == (2, 4)
    assert e.shards == 2 and not e.stage_inputs
    with pytest.raises(ValueError):
        reg.register(
            "m2", FakeServable(), example={"x": np.float32(0)}, buckets=(1,), max_batch=1
        )


def test_stage_clamps_block_entirely_inside_global_padding():
    """Global mode, tiny final batch: a process whose addressable block lies
    wholly in the divisibility-pad region must stage exactly its block size
    of zero rows (regression: fill went negative, corrupting pad arithmetic
    and stats)."""
    from repro.core import PlanRunner

    class _StubPM:
        num_data_shards = 8
        my_shards = (5, 6)
        global_mesh = object()

        def global_batch_sharding(self):
            return None

        def addressable_row_block(self, n):
            blocks = np.array_split(np.arange(n), 8)
            return (int(blocks[5][0]), int(blocks[5][-1]) + 1)

        def row_block(self, n):
            return self.addressable_row_block(n)

        def stage_global(self, host, n):
            self.staged = (dict(host), n)
            return host

    class _StubPlan:
        def jit_for(self, **kw):
            return lambda b: b

        def required_inputs(self):
            return None

    for staging in (False, True):
        pm = _StubPM()
        r = PlanRunner(
            _StubPlan(), process_mesh=pm, shard_mode="global",
            staging=staging, prefetch=0, workers=1,
        )
        # n=3 rows pad to n_global=8; shard 5 covers row 5 — pure padding
        host = r._stage([{"x": np.arange(3.0, dtype=np.float32)}], 0)
        assert pm.staged[1] == 8
        assert host["x"].shape == (1,)
        np.testing.assert_array_equal(np.asarray(host["x"]), [0.0])
        assert r.stats["local_rows"] == 0
        # partial overlap: n=6 -> shard 5 covers row 5 (real), no padding
        host = r._stage([{"x": np.arange(6.0, dtype=np.float32)}], 1)
        assert pm.staged[1] == 8
        np.testing.assert_array_equal(np.asarray(host["x"]), [5.0])
        assert r.stats["local_rows"] == 1


def test_executor_releases_locks_after_worker_failure():
    """A failed routed batch (worker reports an error) must not leave the
    per-connection lock held — the next batch on the same connection has to
    route normally (regression: error paths leaked acquired locks and every
    later batch deadlocked)."""
    import threading
    from multiprocessing import Pipe

    from repro.launch.mesh import ProcessMesh
    from repro.serve import MultiHostExecutor, ShardServer, WorkerFailedError

    def touchy(batch):
        x = np.asarray(batch["x"])
        if x.size and x[0] < 0:
            raise RuntimeError("poisoned block")
        return {"y": x * 2.0}

    ca, cb = Pipe()
    server = ShardServer(ProcessMesh.emulated(2, 1), {"m": touchy})
    t = threading.Thread(target=server.serve, args=(cb,), daemon=True)
    t.start()
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0))
    servable = ex.add_model("m", touchy)
    ex.attach(1, ca)
    with pytest.raises(ValueError):
        ex.attach(1, ca)  # duplicate process id fails fast
    # rows split (1, 1): row 0 runs on the coordinator, row 1 on the worker
    with pytest.raises(WorkerFailedError):
        servable({"x": np.asarray([1.0, -1.0], np.float32)})  # worker fails
    with pytest.raises(RuntimeError, match="poisoned"):
        servable({"x": np.asarray([-1.0, 1.0], np.float32)})  # local fails
    # the connection lock must be free again: a healthy batch still routes
    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    ex.close()
    t.join(timeout=5)
