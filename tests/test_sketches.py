"""Sketch invariants (hypothesis): vocab-table exactness under capacity,
merge associativity/commutativity, DDSketch relative error, moments merge."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import hashing, sketches
from repro.core import types as T


def _table_from(words, cap=64, max_len=16):
    t = sketches.vocab_init(cap, max_len)
    enc = jnp.asarray(T.encode_strings(words, max_len))
    h = hashing.fnv1a64(enc)
    return sketches.vocab_update(t, h, enc)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from("abcdefgh"), min_size=1, max_size=200))
def test_vocab_exact_counts_under_capacity(letters):
    """<= capacity distinct values => counts are EXACT."""
    t = _table_from(letters)
    keys = np.asarray(t["keys"])
    counts = np.asarray(t["counts"])
    valid = keys != np.uint64(0xFFFFFFFFFFFFFFFF)
    got = {}
    for k, c in zip(keys[valid], counts[valid]):
        got[int(k)] = int(c)
    import collections

    want_counts = collections.Counter(letters)
    enc = jnp.asarray(T.encode_strings(sorted(want_counts), 16))
    hs = np.asarray(hashing.fnv1a64(enc))
    for w, h in zip(sorted(want_counts), hs):
        assert got[int(h)] == want_counts[w]
    assert valid.sum() == len(want_counts)


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.sampled_from("abcdefghij"), min_size=1, max_size=100),
    st.lists(st.sampled_from("abcdefghij"), min_size=1, max_size=100),
)
def test_vocab_merge_commutes_and_matches_union(xs, ys):
    ta, tb = _table_from(xs), _table_from(ys)
    m1 = sketches.vocab_merge(ta, tb)
    m2 = sketches.vocab_merge(tb, ta)
    np.testing.assert_array_equal(np.asarray(m1["keys"]), np.asarray(m2["keys"]))
    np.testing.assert_array_equal(np.asarray(m1["counts"]), np.asarray(m2["counts"]))
    tu = _table_from(xs + ys)
    np.testing.assert_array_equal(np.asarray(m1["keys"]), np.asarray(tu["keys"]))
    np.testing.assert_array_equal(np.asarray(m1["counts"]), np.asarray(tu["counts"]))


def test_vocab_eviction_keeps_heavy_hitters():
    words = ["hot"] * 50 + ["warm"] * 20 + [f"cold{i}" for i in range(100)]
    t = sketches.vocab_init(16, 16)
    enc = jnp.asarray(T.encode_strings(words, 16))
    t = sketches.vocab_update(t, hashing.fnv1a64(enc), enc)
    reps = T.decode_strings(np.asarray(t["reps"]))
    counts = np.asarray(t["counts"])
    by = dict(zip(list(reps), counts))
    assert by.get("hot") == 50 and by.get("warm") == 20


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1e-3, 1e6), min_size=20, max_size=300), st.sampled_from([0.1, 0.5, 0.9]))
def test_ddsketch_relative_error(vals, q):
    h = sketches.dd_update(sketches.dd_init(), jnp.asarray(vals, jnp.float64))
    got = float(sketches.dd_quantile(h, q)[0])
    want = float(np.quantile(vals, q, method="inverted_cdf"))
    assert abs(got - want) <= 0.06 * abs(want) + 1e-9


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=60),
    st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=60),
)
def test_moments_merge_equals_concat(xs, ys):
    a = sketches.moments_update(sketches.moments_init(()), jnp.asarray(xs, jnp.float64))
    b = sketches.moments_update(sketches.moments_init(()), jnp.asarray(ys, jnp.float64))
    m = sketches.moments_merge(a, b)
    full = sketches.moments_update(
        sketches.moments_init(()), jnp.asarray(xs + ys, jnp.float64)
    )
    for k in ("count", "sum", "sumsq", "min", "max"):
        np.testing.assert_allclose(
            np.asarray(m[k]), np.asarray(full[k]), rtol=1e-12, err_msg=k
        )


def test_dd_numpy_path_matches_jnp_bin_for_bin():
    """dd_update_np (the serving-telemetry hot path) must land every value in
    the SAME bin as the jnp dd_update, so host and device histograms merge."""
    rng = np.random.default_rng(0)
    vals = np.concatenate(
        [
            rng.lognormal(0, 4, 500),  # magnitudes across many decades
            -rng.lognormal(0, 4, 500),
            np.zeros(7),
            np.array([np.nan, 1e-13, -1e-13]),
        ]
    )
    h_jnp = np.asarray(sketches.dd_update(sketches.dd_init(), jnp.asarray(vals)))
    h_np = sketches.dd_update_np(sketches.dd_init_np(), vals)
    np.testing.assert_array_equal(h_np, h_jnp)
    # and a merged np+jnp histogram quantile-queries like a pure-jnp one
    merged = sketches.dd_merge(h_np, h_jnp)
    q_m = float(sketches.dd_quantile(merged, 0.5)[0])
    q_j = float(sketches.dd_quantile(h_jnp + h_jnp, 0.5)[0])
    assert q_m == q_j


def test_latency_sketch_thread_merge_order_independent():
    """Gateway worker threads each own a histogram; the merged result equals
    a single-threaded fold of all observations regardless of which thread
    recorded what, and quantiles stay inside the documented relative bound."""
    import threading

    from repro.serve.gateway import LatencySketch

    rng = np.random.default_rng(1)
    vals = rng.lognormal(-7, 1.5, 4000)  # latency-shaped: ~1ms scale
    shards = np.array_split(vals, 8)

    sk = LatencySketch()
    threads = [
        threading.Thread(target=lambda s=s: [sk.record(float(v)) for v in s])
        for s in shards
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sk.count == len(vals)

    # order-independence: any permutation of the per-thread histograms (the
    # sketch is a commutative monoid under dd_merge) gives the same result
    single = sketches.dd_update_np(sketches.dd_init_np(), vals)
    np.testing.assert_array_equal(sk.merged(), single)
    hists = list(sk._hists.values())
    for perm in (hists, hists[::-1], hists[3:] + hists[:3]):
        acc = sketches.dd_init_np()
        for h in perm:
            acc = sketches.dd_merge(acc, h)
        np.testing.assert_array_equal(acc, single)

    # documented relative error bound (~4%, asserted at 6% like the jnp test)
    for q in (0.1, 0.5, 0.9, 0.99):
        got = sk.quantiles([q])[q]
        want = float(np.quantile(vals, q, method="inverted_cdf"))
        assert abs(got - want) <= 0.06 * abs(want), (q, got, want)


def test_dd_quantile_empty_histogram_is_nan():
    """An empty histogram must answer NaN, not the bin-0 value (≈ -7e8):
    the serving cost model and any direct caller would otherwise read a
    nonsense 'estimate' out of no data at all."""
    empty = sketches.dd_init()
    out = np.asarray(sketches.dd_quantile(empty, [0.5, 0.99]))
    assert np.isnan(out).all(), out
    out_np = sketches.dd_quantile_np(sketches.dd_init_np(), [0.1, 0.5, 0.999])
    assert np.isnan(out_np).all(), out_np


def test_dd_quantile_np_matches_jnp():
    """The host-side quantile query (cost-model hot path) answers exactly
    what the jnp dd_quantile answers, for the same histogram and qs."""
    rng = np.random.default_rng(3)
    vals = np.concatenate(
        [rng.lognormal(-6, 2, 800), -rng.lognormal(0, 3, 200), np.zeros(5)]
    )
    h = sketches.dd_update_np(sketches.dd_init_np(), vals)
    qs = [0.01, 0.1, 0.5, 0.9, 0.99, 0.999]
    got_np = sketches.dd_quantile_np(h, qs)
    got_jnp = np.asarray(sketches.dd_quantile(jnp.asarray(h), qs))
    np.testing.assert_allclose(got_np, got_jnp, rtol=1e-12)


def test_hash_maxlen_invariance():
    a = hashing.fnv1a64(jnp.asarray(T.encode_strings(["hello"], 8)))
    b = hashing.fnv1a64(jnp.asarray(T.encode_strings(["hello"], 64)))
    assert int(a[0]) == int(b[0])
