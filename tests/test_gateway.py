"""ServingGateway end-to-end: two fused models behind one front door,
concurrent clients with mixed priorities/deadlines, bit-identical outputs,
zero trace after warmup, distinct shed errors, backpressure, priority
ordering, drain-on-close, and (subprocess) mesh-sharded parity."""
import pathlib
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import KamaeSparkPipeline, LogTransformer, ScaleTransformer
from repro.serve import (
    DeadlineExceededError,
    FusedModel,
    QueueFullError,
    ServingGateway,
    UnknownModelError,
)
from repro.serve.gateway import GatewayClosedError

REPO = pathlib.Path(__file__).resolve().parents[1]


def _mk_fused(scale: float, w: float) -> FusedModel:
    """Elementwise pipeline + elementwise head: outputs are bit-identical
    across batch sizes, so gateway batching must reproduce direct calls
    EXACTLY."""
    pipe = KamaeSparkPipeline(
        stages=[
            LogTransformer(inputCol="price", outputCol="pl", alpha=1.0),
            ScaleTransformer(inputCol="qty", outputCol="qs", multiplier=scale),
        ]
    )
    rng = np.random.default_rng(0)
    fit_batch = {
        "price": jnp.asarray(rng.lognormal(3, 1, 64), jnp.float32),
        "qty": jnp.asarray(rng.integers(1, 50, 64), jnp.float32),
    }
    export = pipe.fit(fit_batch).export(outputs=["pl", "qs"])

    def fwd(params, feats):
        return feats["pl"] * params["w"] + feats["qs"]

    return FusedModel(export, fwd, {"w": jnp.float32(w)}, donate=True)


def _row(rng):
    return {
        "price": np.float32(rng.lognormal(3, 1)),
        "qty": np.float32(rng.integers(1, 50)),
    }


def test_gateway_end_to_end_two_models():
    """The acceptance-criteria test: two fused models on one gateway,
    concurrent mixed-priority/deadline clients, bit-identical outputs vs
    direct FusedModel calls, zero trace after warmup, and expired deadlines
    shed with a distinct error."""
    fm_a, fm_b = _mk_fused(0.5, 2.0), _mk_fused(3.0, -1.0)
    gw = ServingGateway(max_pending=128, max_wait_ms=3.0, workers=2)
    gw.register("a", fm_a, example=_row(np.random.default_rng(7)), buckets=(1, 2, 4, 8), max_batch=8)
    gw.register("b", fm_b, example=_row(np.random.default_rng(8)), buckets=(1, 2, 4, 8), max_batch=8)
    warm = gw.warmup()
    assert warm["a"] == len(gw.registry.get("a").buckets)  # one trace per bucket
    tc_a, tc_b = fm_a.trace_count, fm_b.trace_count

    rng = np.random.default_rng(42)
    n = 48
    rows = [_row(rng) for _ in range(n)]
    names = ["a" if i % 3 else "b" for i in range(n)]
    results: list = [None] * n
    errors: list = [None] * n

    def client(i):
        try:
            results[i] = gw.submit(
                names[i],
                rows[i],
                priority=i % 2,
                deadline_ms=None if i % 4 else 5000.0,
                timeout=30.0,
            )
        except BaseException as e:  # pragma: no cover - failure path
            errors[i] = e

    threads = [threading.Thread(target=client, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(e is None for e in errors), errors

    # zero trace after warmup: every served shape was AOT-precompiled
    assert fm_a.trace_count == tc_a
    assert fm_b.trace_count == tc_b

    # bit-identical vs the direct FusedModel path (per-row direct calls,
    # padded to the smallest bucket — the models are elementwise)
    for i in range(n):
        fm = fm_a if names[i] == "a" else fm_b
        direct = fm({k: jnp.asarray(v)[None] for k, v in rows[i].items()})
        np.testing.assert_array_equal(
            np.asarray(results[i]), np.asarray(direct)[0]
        )

    # expired deadline: shed at the door with the DISTINCT shedding error
    with pytest.raises(DeadlineExceededError):
        gw.submit("a", rows[0], deadline_ms=0.0)

    snap = gw.snapshot()
    assert snap["stats"]["completed"] == n
    assert snap["stats"]["shed_at_door"] == 1
    assert snap["stats"]["batches"] < n  # actually batched
    for name in ("a", "b"):
        for stage in ("queue", "execute", "e2e"):
            s = snap["models"][name][stage]
            assert s["count"] > 0
            assert np.isfinite(s["p50_us"]) and s["p50_us"] >= 0
            assert s["p99_us"] >= s["p50_us"]
    gw.close()


def test_gateway_sheds_queued_requests_past_deadline():
    """A request whose deadline expires while the single worker is busy is
    shed at batch formation, not executed."""
    order = []

    def slow(batch):
        time.sleep(0.15)
        x = np.asarray(batch["x"])
        order.append(float(x[0]))
        return {"y": x * 2.0}

    gw = ServingGateway(max_pending=16, max_wait_ms=1.0, workers=1)
    gw.register("slow", slow, example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()

    blocker = gw.submit_async("slow", {"x": np.float32(1.0)})
    time.sleep(0.03)  # the worker is now inside the blocker's 150 ms sleep
    doomed = gw.submit_async("slow", {"x": np.float32(2.0)}, deadline_ms=40.0)
    survivor = gw.submit_async("slow", {"x": np.float32(3.0)}, deadline_ms=5000.0)

    assert blocker.event.wait(5) and blocker.error is None
    assert doomed.event.wait(5)
    assert isinstance(doomed.error, DeadlineExceededError)
    assert survivor.event.wait(5) and survivor.error is None
    assert 2.0 not in order  # the shed request never reached the model
    assert gw.snapshot()["stats"]["shed_queued"] == 1
    gw.close()


def test_gateway_backpressure_queue_full():
    def slow(batch):
        time.sleep(0.1)
        return {"y": np.asarray(batch["x"]) * 2.0}

    gw = ServingGateway(max_pending=3, max_wait_ms=1.0, workers=1)
    gw.register("slow", slow, example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()

    admitted, rejected = [], []
    for i in range(8):
        try:
            admitted.append(gw.submit_async("slow", {"x": np.float32(i)}))
        except QueueFullError as e:
            rejected.append(e)
    assert len(rejected) >= 1  # bounded queue pushed back
    assert len(admitted) <= 3
    for r in admitted:
        assert r.event.wait(10) and r.error is None
    assert gw.snapshot()["stats"]["rejected_full"] == len(rejected)
    gw.close()


def test_gateway_priority_orders_execution():
    """With the worker pinned, a later high-priority request launches before
    an earlier low-priority one (max_batch=1 so they cannot share a batch)."""
    order = []

    def slow(batch):
        time.sleep(0.08)
        order.append(float(np.asarray(batch["x"])[0]))
        return {"y": np.asarray(batch["x"])}

    gw = ServingGateway(max_pending=16, max_wait_ms=1.0, workers=1)
    gw.register("m", slow, example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw.warmup()
    order.clear()  # warmup drove the model once with the example row

    blocker = gw.submit_async("m", {"x": np.float32(0.0)})
    time.sleep(0.02)  # worker now busy; the next two queue up together
    low = gw.submit_async("m", {"x": np.float32(1.0)}, priority=0)
    high = gw.submit_async("m", {"x": np.float32(2.0)}, priority=5)
    for r in (blocker, low, high):
        assert r.event.wait(5) and r.error is None
    assert order == [0.0, 2.0, 1.0]
    gw.close()


def test_gateway_error_isolation_and_close_drains():
    calls = []

    def picky(batch):
        x = np.asarray(batch["x"])
        calls.append(x.shape[0])
        if (x < 0).any():
            raise ValueError("poisoned feature")
        return {"y": x * 2.0}

    gw = ServingGateway(max_pending=32, max_wait_ms=20.0, workers=1)
    gw.register("p", picky, example={"x": np.float32(1.0)}, buckets=(1, 2, 4), max_batch=4)
    gw.warmup()

    reqs = [
        gw.submit_async("p", {"x": np.float32(1.0)}),
        gw.submit_async("p", {"x": np.float32(-1.0)}),  # poisoned
        gw.submit_async("p", {"x": np.float32(3.0)}),
    ]
    for r in reqs:
        assert r.event.wait(10)
    assert reqs[0].error is None and float(reqs[0].result["y"]) == 2.0
    assert isinstance(reqs[1].error, ValueError)
    assert reqs[2].error is None and float(reqs[2].result["y"]) == 6.0

    # close() drains: a queued request behind a busy worker errors out fast
    def slow(batch):
        time.sleep(0.2)
        return {"y": np.asarray(batch["x"])}

    gw2 = ServingGateway(max_pending=8, max_wait_ms=1.0, workers=1)
    gw2.register("s", slow, example={"x": np.float32(0.0)}, buckets=(1,), max_batch=1)
    gw2.warmup()
    running = gw2.submit_async("s", {"x": np.float32(1.0)})
    time.sleep(0.03)
    queued = gw2.submit_async("s", {"x": np.float32(2.0)})
    t0 = time.perf_counter()
    gw2.close()
    assert time.perf_counter() - t0 < 3.0
    assert running.event.wait(1) and running.error is None  # in-flight finished
    assert queued.event.is_set()
    assert isinstance(queued.error, GatewayClosedError)
    with pytest.raises(GatewayClosedError):
        gw2.submit("s", {"x": np.float32(3.0)})
    gw.close()


def test_gateway_unknown_model():
    gw = ServingGateway()
    with pytest.raises(UnknownModelError):
        gw.submit("missing", {"x": np.float32(1.0)})
    assert gw.admission.pending == 0  # rejected before taking a slot
    gw.close()


def test_registry_clamps_max_batch_to_top_bucket():
    """A batch above the top bucket would execute at a never-warmed shape,
    breaking the zero-trace-after-warmup invariant — so it cannot form."""
    gw = ServingGateway()
    entry = gw.register(
        "m",
        lambda b: {"y": np.asarray(b["x"])},
        example={"x": np.float32(0.0)},
        buckets=(1, 2, 4, 8),
        max_batch=32,
    )
    assert entry.max_batch == 8
    assert gw.scheduler._limits["m"] == 8
    gw.close()

    from repro.serve import MicroBatcher

    b = MicroBatcher(lambda f: f, max_batch=20, buckets=(1, 2, 4, 8, 16, 32))
    assert b.buckets == (1, 2, 4, 8, 16)
    assert b.max_batch == 16  # clamped to the top surviving bucket
    b.close()


def test_fused_model_mesh_keyed_cache():
    """FusedModel.jit_for mirrors TransformPlan.jit_for: cached per
    (sharding fingerprint, donate), traced once per signature."""
    from repro.launch.mesh import batch_sharding, make_host_mesh, use_mesh

    fm = _mk_fused(1.0, 1.0)
    host = {
        "price": np.asarray([1.0, 2.0, 3.0, 4.0], np.float32),
        "qty": np.asarray([1.0, 1.0, 2.0, 2.0], np.float32),
    }
    # donation is the serve default: stage a FRESH device batch per call
    fresh = lambda: {k: jnp.asarray(v) for k, v in host.items()}  # noqa: E731
    assert fm.jit_for() is fm.jit_for()  # same cached wrapper object
    assert fm.jit_for(donate=False) is not fm.jit_for(donate=True)
    out0 = np.asarray(fm(fresh()))
    t0 = fm.trace_count
    fm(fresh())
    assert fm.trace_count == t0  # signature cache hit, no retrace

    mesh = make_host_mesh(data=1, model=1)
    sh = batch_sharding(mesh)
    assert fm.jit_for(sh) is fm.jit_for(sh)
    assert fm.jit_for(sh) is not fm.jit_for()
    # an equal-fingerprint mesh hits the SAME executable entry
    assert fm.jit_for(batch_sharding(make_host_mesh(data=1, model=1))) is fm.jit_for(sh)
    with use_mesh(mesh):
        out_sh = np.asarray(fm(fresh(), sharding=sh))
    np.testing.assert_array_equal(out0, out_sh)
    assert fm.stats["jit_cache_entries"] == 3  # (None,d), (None,not d), (mesh,d)


@pytest.mark.subprocess
def test_gateway_serves_mesh_sharded_model():
    """8 host devices (subprocess): the SAME FusedModel registered unsharded
    and mesh-sharded behind one gateway produces identical outputs."""
    script = """
        import numpy as np, jax, jax.numpy as jnp, threading
        from repro.core import KamaeSparkPipeline, LogTransformer
        from repro.launch.mesh import batch_sharding, make_host_mesh
        from repro.serve import FusedModel, ServingGateway

        rng = np.random.default_rng(0)
        pipe = KamaeSparkPipeline(stages=[
            LogTransformer(inputCol="price", outputCol="pl", alpha=1.0)])
        fitted = pipe.fit({"price": jnp.asarray(rng.lognormal(3, 1, 64), jnp.float32)})
        export = fitted.export(outputs=["pl"])
        def fwd(params, feats):
            return feats["pl"] * params["w"]
        fm = FusedModel(export, fwd, {"w": jnp.float32(2.0)}, donate=True)

        mesh = make_host_mesh(data=8, model=1)
        sh = batch_sharding(mesh)
        gw = ServingGateway(max_pending=64, max_wait_ms=3.0, workers=2)
        example = {"price": np.float32(10.0)}
        # buckets on the sharded entry are multiples of the 8 batch shards
        gw.register("plain", fm, example=example, buckets=(1, 2, 4, 8), max_batch=8)
        gw.register("sharded", fm, example=example, buckets=(8, 16), max_batch=16,
                    sharding=sh)
        # no ambient use_mesh: shardings are passed explicitly everywhere, so
        # warmup (main thread) and the gateway workers trace in the SAME jit
        # context — required for the zero-trace-after-warmup property
        gw.warmup()
        tc = fm.trace_count
        rows = rng.lognormal(3, 1, 32).astype(np.float32)
        outs = {}
        def client(name, i):
            outs[(name, i)] = gw.submit(name, {"price": rows[i]}, timeout=60.0)
        ts = [threading.Thread(target=client, args=(name, i))
              for name in ("plain", "sharded") for i in range(32)]
        [t.start() for t in ts]; [t.join() for t in ts]
        assert fm.trace_count == tc, (fm.trace_count, tc)
        for i in range(32):
            a = np.asarray(outs[("plain", i)]); b = np.asarray(outs[("sharded", i)])
            np.testing.assert_array_equal(a, b)
        assert fm.stats["jit_cache_entries"] >= 2
        gw.close()
        print("GATEWAY_SHARDED_OK")
        """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        timeout=560,
        env={
            "PYTHONPATH": str(REPO / "src"),
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            # CPU-emulation child: stop jax probing for a TPU runtime
            "JAX_PLATFORMS": "cpu",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "GATEWAY_SHARDED_OK" in proc.stdout


# ---------------------------------------------------------------------------
# snapshot() under concurrent mutation
# ---------------------------------------------------------------------------


def test_latency_sketch_merge_is_order_independent():
    """Per-thread histograms are a commutative monoid: folding them in ANY
    order yields the same merged histogram — which is what makes snapshot()
    safe to call while recording threads are live."""
    from repro.core import sketches
    from repro.serve.gateway.telemetry import LatencySketch

    sk = LatencySketch()
    rng = np.random.default_rng(11)
    vals = rng.lognormal(-7, 1, 400)
    barrier = threading.Barrier(4)  # overlap all 4 lives: distinct idents

    def recorder(chunk):
        barrier.wait()
        for v in chunk:
            sk.record(float(v))
        barrier.wait()

    threads = [
        threading.Thread(target=recorder, args=(vals[i::4],)) for i in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    hists = list(sk._hists.values())
    assert len(hists) == 4
    ref = sk.merged()
    rng.shuffle(hists)
    out = sketches.dd_init_np()
    for h in hists:
        out = sketches.dd_merge(out, h)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    assert sk.count == len(vals)


def test_snapshot_consistent_under_concurrent_load():
    """snapshot() is read continuously while a replayed mixed-feasibility
    load runs: counters only ever move forward, per-bucket cost fields are
    present from warmup on, and when the dust settles every shed_infeasible*
    increment corresponds to exactly one InfeasibleDeadlineError raised to a
    client (and likewise for the other outcome classes)."""
    from repro.serve import InfeasibleDeadlineError

    exec_s = 0.004

    def sleepy(batch):
        time.sleep(exec_s)
        return {"y": np.asarray(batch["x"]) * 3.0}

    gw = ServingGateway(max_pending=128, max_wait_ms=1.0, workers=2)
    gw.register(
        "m", sleepy, example={"x": np.float32(0.0)}, buckets=(1, 2, 4), max_batch=4
    )
    gw.warmup()
    snap0 = gw.snapshot()
    cost0 = snap0["models"]["m"]["cost"]
    assert {"1", "2", "4"} <= set(cost0)  # per-bucket fields exist pre-traffic
    assert all(cost0[b]["count"] >= 1 for b in ("1", "2", "4"))

    outcomes = {"ok": 0, "infeasible": 0, "deadline": 0}
    out_lock = threading.Lock()

    def client(i):
        # odd requests carry a budget the 4ms execute can never meet
        deadline_ms = 1.0 if i % 2 else 400.0
        try:
            gw.submit("m", {"x": np.float32(i)}, deadline_ms=deadline_ms, timeout=15.0)
            kind = "ok"
        except InfeasibleDeadlineError:
            kind = "infeasible"
        except DeadlineExceededError:
            kind = "deadline"
        with out_lock:
            outcomes[kind] += 1

    monotone = [
        "completed", "shed_queued", "shed_infeasible", "shed_at_door",
        "shed_infeasible_door", "batches", "admitted",
        "sched_formed_batches", "sched_shed_infeasible", "sched_shed_expired",
    ]
    stop = threading.Event()
    seen = {"snaps": 0}

    def poller():
        prev = {k: 0 for k in monotone}
        while not stop.is_set():
            s = gw.snapshot()["stats"]
            for k in monotone:
                assert s[k] >= prev[k], (k, s[k], prev[k])
                prev[k] = s[k]
            seen["snaps"] += 1

    pt = threading.Thread(target=poller)
    pt.start()
    n = 60
    import concurrent.futures as cf

    with cf.ThreadPoolExecutor(max_workers=16) as pool:
        list(pool.map(client, range(n)))
    stop.set()
    pt.join()
    assert seen["snaps"] > 3  # the poller genuinely raced the load

    s = gw.snapshot()["stats"]
    assert sum(outcomes.values()) == n
    assert s["completed"] == outcomes["ok"]
    # every infeasible error a client saw is counted exactly once, at the
    # door or at formation — and formation sheds agree with the scheduler's
    # own independent counter
    assert s["shed_infeasible"] + s["shed_infeasible_door"] == outcomes["infeasible"]
    assert s["sched_shed_infeasible"] == s["shed_infeasible"]
    assert s["shed_at_door"] + s["shed_queued"] == outcomes["deadline"]
    assert s["sched_shed_expired"] == s["shed_queued"]  # no retries ran here
    assert s["failed"] == 0
    assert s["pending"] == 0  # every admission slot released
    gw.close()
