"""Static-analysis subsystem: findings/suppression machinery, the golden
lockcheck corpus (each rule fires exactly once on its seeded-violation
fixture, stays silent on the shipped repo), the plan verifier's abstract
interpretation on the real pipelines (clean) and on mutated schedules
(each rule fires), the export/registry schema gates, and regression tests
for the concurrency fixes the lint surfaced in multihost.py."""
import threading
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import analyze
from repro.analyze import (
    PlanSchemaError,
    Report,
    knobcheck,
    lockcheck,
    parse_suppressions,
    plan_check,
)
from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    StringIndexEstimator,
    StringToStringListTransformer,
)
from repro.core import types as T
from repro.core.export import PreprocessModel
from repro.core.fusion import ChainOp, ChainProgram
from repro.core.plan import TransformPlan, _FusedNode

pytestmark = pytest.mark.analyze

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "analyze_fixtures"


# ---------------------------------------------------------------------------
# findings / suppression machinery
# ---------------------------------------------------------------------------


def test_parse_suppressions_rules_and_reasons():
    text = (
        "x = 1\n"
        "y = 2  # analyze: allow(rule-a, rule-b) both are fine here\n"
        "z = 3  # analyze: allow(rule-c)\n"
    )
    allowed, bad = parse_suppressions(text)
    assert allowed == {
        2: {"rule-a": "both are fine here", "rule-b": "both are fine here"}
    }
    assert bad == [(3, ["rule-c"])]


def test_apply_suppressions_def_line_and_bad_reason():
    rep = Report()
    rep.add("rule-a", "error", "seeded", file="f.py", line=5)
    text = "\n".join(
        [
            "def g():  # analyze: allow(rule-a) covered by caller",
            "    pass",
            "",
            "",
            "x = 1",
            "y = 2  # analyze: allow(rule-b)",
        ]
    )
    rep.apply_suppressions("f.py", text, def_lines={5: 1})
    supp = [f for f in rep.findings if f.suppressed]
    assert len(supp) == 1 and supp[0].suppress_reason == "covered by caller"
    bad = rep.by_rule(analyze.BAD_SUPPRESSION)
    assert len(bad) == 1 and bad[0].line == 6
    assert not rep.ok()  # the bad suppression is itself an error


def test_raise_if_errors_is_typed_and_carries_findings():
    rep = Report()
    rep.add("rule-a", "error", "boom")
    with pytest.raises(PlanSchemaError) as ei:
        rep.raise_if_errors("unit")
    assert ei.value.findings and ei.value.findings[0].rule == "rule-a"
    assert isinstance(ei.value, ValueError)


# ---------------------------------------------------------------------------
# golden corpus: each lint rule fires exactly once on its fixture
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def corpus():
    return lockcheck.check([str(FIXTURES)])


def test_golden_lock_order_inversion_fires_once(corpus):
    hits = corpus.by_rule(lockcheck.ORDER_INVERSION)
    assert len(hits) == 1
    assert hits[0].file.endswith("lock_order.py") and hits[0].line == 15
    assert "opposite order" in hits[0].message


def test_golden_blocking_call_fires_once(corpus):
    active = [
        f for f in corpus.by_rule(lockcheck.BLOCKING_CALL) if not f.suppressed
    ]
    assert len(active) == 1
    assert active[0].file.endswith("blocking.py") and active[0].line == 13
    assert "state_lock" in active[0].message


def test_golden_unguarded_mutation_fires_once(corpus):
    hits = corpus.by_rule(lockcheck.UNGUARDED_MUTATION)
    assert len(hits) == 1
    assert hits[0].file.endswith("unguarded.py") and hits[0].line == 20
    assert hits[0].severity == "warning"


def test_golden_suppressed_finding_is_marked_not_active(corpus):
    supp = [f for f in corpus.findings if f.suppressed]
    assert len(supp) == 1
    assert supp[0].file.endswith("suppressed.py") and supp[0].line == 14
    assert supp[0].suppress_reason.startswith("fixture:")
    assert supp[0] not in corpus.active


def test_golden_bad_suppression_fires_once(corpus):
    hits = corpus.by_rule(analyze.BAD_SUPPRESSION)
    assert len(hits) == 1
    assert hits[0].file.endswith("bad_suppress.py") and hits[0].line == 9


# ---------------------------------------------------------------------------
# the shipped repo is clean
# ---------------------------------------------------------------------------


def test_lockcheck_repo_clean():
    rep = lockcheck.check(lockcheck.default_paths(REPO / "src"))
    assert rep.active == [], "\n" + rep.format_text()
    # the intentional sites are recorded (with reasons), not hidden
    assert any(f.suppressed for f in rep.findings)
    assert all(f.suppress_reason for f in rep.findings if f.suppressed)


def test_lockcheck_started_flag_is_guarded():
    """Regression (lint fix): ``executor._started`` was set with no lock
    held in ``accept_workers`` while other threads read/write it under
    ``_mlock``."""
    rep = lockcheck.check(
        [str(REPO / "src" / "repro" / "serve" / "gateway" / "multihost.py")]
    )
    assert not [
        f
        for f in rep.by_rule(lockcheck.UNGUARDED_MUTATION)
        if "_started" in f.message and not f.suppressed
    ]


def test_knobcheck_repo_clean():
    rep = knobcheck.check(REPO / "src", REPO / "README.md")
    assert rep.ok(), "\n" + rep.format_text()


def test_knobcheck_rules_fire(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    (src / "mod.py").write_text('flag = os.environ.get("REPRO_BOGUS_KNOB")\n')
    (tmp_path / "README.md").write_text("# nothing here\n")
    rep = knobcheck.check(src, tmp_path / "README.md", knobs={})
    rules = sorted(f.rule for f in rep.findings)
    assert rules == [knobcheck.KNOB_UNDOCUMENTED, knobcheck.KNOB_UNREGISTERED]
    assert all(f.file.endswith("mod.py") and f.line == 1 for f in rep.findings)


# ---------------------------------------------------------------------------
# plan verifier: clean on the real pipelines (staged AND fused)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ltr():
    from repro.apps.ltr_pipeline import build_ltr_pipeline
    from repro.data import ltr_rows

    train = ltr_rows(96, seed=0)
    fitted, cols = build_ltr_pipeline(train)
    batch = {k: v[:48] for k, v in ltr_rows(48, seed=5).items()}
    return fitted, cols, batch


@pytest.fixture(scope="module")
def quickstart():
    rng = np.random.default_rng(1)
    n = 64
    batch = {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(rng.choice(["Action|Comedy", "Drama"], n), 32)
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000,
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=4, defaultValue="PADDED",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
        ]
    )
    return pipe.fit(batch), batch


@pytest.fixture()
def hash_chain():
    from repro.core.transformers.math import (
        BucketizeTransformer,
        ClipTransformer,
        ScaleTransformer,
    )

    n = 96
    batch = {
        "city": jnp.asarray(
            T.encode_strings([f"city_{i % 37}" for i in range(n)], 32)
        )
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(inputCol="city", outputCol="h", numBins=97, seed=3),
            ScaleTransformer(inputCol="h", outputCol="s", multiplier=0.25, offset=1.0),
            BucketizeTransformer(inputCol="s", outputCol="b", splits=[2.0, 5.0, 11.0]),
            ClipTransformer(inputCol="b", outputCol="c", minValue=1, maxValue=2),
        ]
    )
    return pipe.fit(batch), batch


def _restricted(plan, batch):
    req = set(plan_check.plan_required_inputs(plan))
    return {k: v for k, v in batch.items() if k in req}


@pytest.mark.parametrize("fuse", [False, True], ids=["staged", "fused"])
def test_verify_plan_ltr_clean(ltr, fuse):
    fitted, cols, batch = ltr
    plan = TransformPlan(fitted.stages, outputs=cols, fuse=fuse)
    rep = plan_check.verify_plan(plan, example=_restricted(plan, batch))
    assert rep.findings == [], "\n" + rep.format_text()


@pytest.mark.parametrize("fuse", [False, True], ids=["staged", "fused"])
def test_verify_plan_quickstart_clean(quickstart, fuse):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=fuse)
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.findings == [], "\n" + rep.format_text()


def test_verify_plan_hash_chain_clean_and_fused(hash_chain):
    fitted, batch = hash_chain
    plan = TransformPlan(fitted.stages, outputs=["c"], fuse=True)
    assert any(isinstance(n, _FusedNode) for n in plan._nodes)
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.findings == [], "\n" + rep.format_text()


def test_verify_plan_from_schema_without_batch(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=True)
    schema = plan_check.schema_of_batch(batch)
    rep = plan_check.verify_plan(plan, schema=schema)
    assert rep.findings == [], "\n" + rep.format_text()


# ---------------------------------------------------------------------------
# plan verifier: mutated schedules (each rule fires)
# ---------------------------------------------------------------------------


def test_mutation_version_flip_detected(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=False)
    node = plan._nodes[-1]
    col, ver, tok = node.in_specs[0]
    node.in_specs[0] = (col, ver + 1, tok)
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.by_rule(plan_check.VERSION_SKEW), "\n" + rep.format_text()


def test_mutation_dropped_producer_detected(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=False)
    # drop the producer of a column a later node reads: its read dangles
    reads = {c for n in plan._nodes for c, _, _ in n.in_specs}
    idx = next(
        i
        for i, n in enumerate(plan._nodes)
        if any(c in reads for c in n.out_cols)
    )
    dropped = plan._nodes.pop(idx)
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.by_rule(plan_check.MISSING_INPUT), (
        f"dropping producer of {dropped.out_cols} raised nothing:\n"
        + rep.format_text()
    )


def test_mutation_bogus_dead_after_is_use_after_free(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=False)
    # free a column right at its producer although a later node reads it
    later = [c for n in plan._nodes[1:] for c, _, _ in n.in_specs]
    victim = next(c for n in plan._nodes for c in n.out_cols if c in later)
    producer = next(n for n in plan._nodes if victim in n.out_cols)
    producer.dead_after = list(producer.dead_after) + [victim]
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.by_rule(plan_check.USE_AFTER_FREE), "\n" + rep.format_text()


def test_mutation_missing_output_detected(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, outputs=["Price_log"], fuse=False)
    plan._nodes = [n for n in plan._nodes if "Price_log" not in n.out_cols]
    rep = plan_check.verify_plan(plan, example=batch)
    assert rep.by_rule(plan_check.MISSING_OUTPUT), "\n" + rep.format_text()


def test_mutation_illegal_fused_op_breaks_legality(hash_chain):
    fitted, batch = hash_chain
    plan = TransformPlan(fitted.stages, outputs=["c"], fuse=True)
    node = next(n for n in plan._nodes if isinstance(n, _FusedNode))
    out = node.out_cols[0]
    # graft a dtype-flipping cast onto the chain output: the program no
    # longer matches its staged members — exactly the skew fusion must
    # never introduce
    node.program = ChainProgram(
        list(node.program.ops) + [ChainOp("cast", (out,), out, ("int16",))],
        node.program.inputs,
        node.program.outputs,
    )
    rep = plan_check.verify_plan(plan, example=batch)
    hits = rep.by_rule(plan_check.FUSION_LEGALITY)
    assert hits and "not" in hits[0].message, "\n" + rep.format_text()


def test_mutation_input_dtype_flip_is_schema_error(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=False)
    skewed = dict(batch)
    skewed["Price"] = np.asarray(batch["Price"]).astype(np.int32)  # kind flip
    provided = plan_check.schema_of_batch(skewed)
    required = {
        c: plan_check.schema_of_batch(batch).get(c)
        for c in plan_check.plan_required_inputs(plan)
    }
    rep = plan_check.check_schema(required, provided)
    errs = [
        f for f in rep.by_rule(plan_check.SCHEMA_SKEW) if f.severity == "error"
    ]
    assert errs and "Price" in errs[0].message


def test_width_only_dtype_difference_is_warning():
    rep = plan_check.check_schema(
        {"x": {"dtype": "float32", "shape": []}},
        {"x": {"dtype": "float64", "shape": []}},
    )
    assert rep.ok()
    assert rep.warnings()


# ---------------------------------------------------------------------------
# structural schedule verification (the jax-free gate)
# ---------------------------------------------------------------------------


def test_schedule_structure_clean_and_closed_world(quickstart):
    fitted, batch = quickstart
    plan = TransformPlan(fitted.stages, fuse=True)
    sched = plan.schedule()
    schema = plan_check.schema_of_batch(batch)
    rep = plan_check.verify_schedule_structure(
        sched, n_stages=len(fitted.stages), input_schema=schema
    )
    assert rep.findings == [], "\n" + rep.format_text()
    # closed world: drop a raw input from the schema -> missing-input error
    short = {k: v for k, v in schema.items() if k != "Price"}
    rep2 = plan_check.verify_schedule_structure(sched, input_schema=short)
    assert rep2.by_rule(plan_check.MISSING_INPUT)


# ---------------------------------------------------------------------------
# export-bundle gate (satellite: typed PlanSchemaError, not silent accept)
# ---------------------------------------------------------------------------


def test_fit_records_input_schema(quickstart):
    fitted, batch = quickstart
    schema = fitted.input_schema
    assert schema is not None
    assert schema["Price"]["dtype"] == "float32"
    assert schema["Genres"]["shape"] == [32]
    assert "UserID_indexed" not in schema  # derived, not raw


def test_export_bundle_round_trips_schema_through_gate(quickstart):
    fitted, batch = quickstart
    model = fitted.export()
    blob = model.save_bytes()  # save gate passes on a healthy artifact
    loaded = PreprocessModel.load_bytes(blob)  # load gate passes too
    assert loaded.input_schema == model.input_schema
    assert loaded.input_schema["Price"]["dtype"] == "float32"


def test_export_save_rejects_skewed_schema(quickstart):
    fitted, batch = quickstart
    model = fitted.export()
    # forge skew: the recorded fit schema loses a column the schedule reads
    model.input_schema = {
        k: v for k, v in model.input_schema.items() if k != "Price"
    }
    with pytest.raises(PlanSchemaError) as ei:
        model.save_bytes()
    assert any(f.rule == plan_check.MISSING_INPUT for f in ei.value.findings)


def test_export_load_rejects_skewed_bundle(quickstart, monkeypatch):
    """Pre-fix behaviour: a bundle whose schedule reads columns its recorded
    fit schema cannot provide loaded silently and failed (or mis-bound) at
    first execute.  The verifier gate now raises a typed PlanSchemaError at
    load time."""
    fitted, batch = quickstart
    model = fitted.export()
    model.input_schema = {
        k: v for k, v in model.input_schema.items() if k != "Price"
    }
    monkeypatch.setenv("REPRO_ANALYZE_GATE", "0")
    blob = model.save_bytes()  # gate off: the skewed artifact serialises
    monkeypatch.delenv("REPRO_ANALYZE_GATE")
    with pytest.raises(PlanSchemaError) as ei:
        PreprocessModel.load_bytes(blob)
    assert any(f.rule == plan_check.MISSING_INPUT for f in ei.value.findings)
    # forensics escape hatch: gate off loads it anyway
    monkeypatch.setenv("REPRO_ANALYZE_GATE", "0")
    assert PreprocessModel.load_bytes(blob) is not None


# ---------------------------------------------------------------------------
# registry gate (satellite: typed PlanSchemaError on a mismatched example)
# ---------------------------------------------------------------------------


def _registry_and_model(quickstart):
    from repro.serve.gateway.registry import ModelRegistry

    fitted, batch = quickstart
    return ModelRegistry(), fitted.export(), batch


def test_registry_accepts_matching_example(quickstart):
    reg, model, batch = _registry_and_model(quickstart)
    example = {k: np.asarray(v)[0] for k, v in batch.items()}
    entry = reg.register("m", model, example, buckets=(1, 2))
    assert entry.name == "m"


def test_registry_rejects_missing_column(quickstart):
    reg, model, batch = _registry_and_model(quickstart)
    example = {k: np.asarray(v)[0] for k, v in batch.items() if k != "Price"}
    with pytest.raises(PlanSchemaError) as ei:
        reg.register("m", model, example, buckets=(1, 2))
    assert "Price" in str(ei.value)
    assert "m" not in reg.names()  # nothing half-registered


def test_registry_rejects_dtype_kind_flip(quickstart):
    reg, model, batch = _registry_and_model(quickstart)
    example = {k: np.asarray(v)[0] for k, v in batch.items()}
    example["Price"] = np.int64(3)  # fit on float32: a kind flip, not width
    with pytest.raises(PlanSchemaError):
        reg.register("m", model, example, buckets=(1, 2))


def test_registry_gate_env_off(quickstart, monkeypatch):
    reg, model, batch = _registry_and_model(quickstart)
    example = {k: np.asarray(v)[0] for k, v in batch.items() if k != "Price"}
    monkeypatch.setenv("REPRO_ANALYZE_GATE", "0")
    assert reg.register("m", model, example, buckets=(1, 2)) is not None


# ---------------------------------------------------------------------------
# concurrency-fix regression tests (satellite: sweeper, _mark_dead)
# ---------------------------------------------------------------------------


class _SlowPollConn:
    """Fake Connection whose poll sleeps out its requested timeout (a silent
    worker) — the pre-fix sweeper blocked dispatch for the whole heartbeat
    window while holding the worker's lock."""

    def __init__(self):
        self.sent = []

    def send(self, frame):
        self.sent.append(frame)

    def poll(self, timeout=0.0):
        time.sleep(min(float(timeout), 2.0))
        return False

    def recv(self):  # pragma: no cover - never answered
        raise EOFError

    def close(self):
        pass


def _executor():
    """A coordinator with one silent fake worker, built without sockets or
    the background sweeper thread (``_sweep_once`` is driven by hand)."""
    from repro.ft import Liveness, StragglerMonitor
    from repro.serve.gateway.multihost import MultiHostExecutor, _Worker
    from repro.serve.gateway.telemetry import CounterSet

    ex = MultiHostExecutor.__new__(MultiHostExecutor)
    ex.num_processes = 2
    ex.heartbeat_s = 5.0
    ex._mlock = threading.Lock()
    ex._lock = threading.Lock()
    ex._workers = {}
    ex._dead = set()
    ex._death_reasons = {}
    ex._degraded_pm = None
    ex._closed = False
    ex._clock = time.monotonic
    ex._shard_lat = {}
    ex.monitor = StragglerMonitor()
    ex._ft = CounterSet()
    w = _Worker(_SlowPollConn(), Liveness(ex.heartbeat_s, ex._clock))
    ex._workers[1] = w
    return ex, w


def test_sweeper_micro_polls_and_tracks_pending():
    """Regression (lint fix): ``_sweep_once`` polled the pong for up to
    ``min(heartbeat_s, 1.0)`` seconds while holding ``w.lock``; every batch
    for that worker queued behind the sweep.  Now it micro-polls (50ms) and
    records the owed pong as pending so ``_drain_stale`` consumes it before
    the socket carries a batch."""
    ex, w = _executor()
    # silent past one window (suspect, not dead): the sweep must ping it
    w.liveness.last = ex._clock() - 1.5 * ex.heartbeat_s
    t0 = time.monotonic()
    ex._sweep_once()
    elapsed = time.monotonic() - t0
    assert elapsed < 0.5, f"sweep held the worker lock for {elapsed:.2f}s"
    assert ("ping",) in w.conn.sent
    assert w.pending and w.pending[0][1] is None  # the owed pong is tracked
    assert w.alive and not w.lock.locked()


def test_sweeper_skips_worker_mid_batch():
    ex, w = _executor()
    w.liveness.last = ex._clock() - 1.5 * ex.heartbeat_s
    with w.lock:  # a dispatch holds the connection
        t0 = time.monotonic()
        ex._sweep_once()
        assert time.monotonic() - t0 < 0.2
    assert w.conn.sent == []  # never pinged a busy connection


class _BlockingCloseConn(_SlowPollConn):
    def __init__(self, gate):
        super().__init__()
        self.gate = gate
        self.closing = threading.Event()

    def close(self):
        self.closing.set()
        self.gate.wait(timeout=5.0)


def test_mark_dead_closes_outside_membership_lock():
    """Regression (lint fix): ``_mark_dead`` closed the worker socket while
    holding ``_mlock`` — a wedged close stalled every membership read
    (live_workers, snapshots, reshard-budget checks)."""
    ex, w = _executor()
    gate = threading.Event()
    w.conn = _BlockingCloseConn(gate)
    t = threading.Thread(target=ex._mark_dead, args=(1, "test"), daemon=True)
    t.start()
    assert w.conn.closing.wait(timeout=2.0)
    # close is in flight: the membership lock must be free
    got = ex._mlock.acquire(timeout=1.0)
    try:
        assert got, "_mlock held across a blocking socket close"
        assert not w.alive and 1 in ex._dead  # state already updated
    finally:
        if got:
            ex._mlock.release()
        gate.set()
        t.join(timeout=5.0)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_lint_only_strict_exits_zero(tmp_path, capsys):
    import json

    from repro.analyze.__main__ import main

    out = tmp_path / "report.json"
    rc = main(["--strict", "--skip-plans", "--json", str(out)])
    assert rc == 0, capsys.readouterr().out
    data = json.loads(out.read_text())
    assert data["errors"] == 0 and data["warnings"] == 0
    assert data["suppressed"] > 0  # the justified sites are on record
