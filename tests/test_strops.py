"""String-primitive tests incl. hypothesis properties (round trips,
python-semantics equivalence)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import strops
from repro.core import types as T

SAFE = st.text(
    alphabet=st.characters(min_codepoint=1, max_codepoint=126), max_size=20
)


@settings(max_examples=40, deadline=None)
@given(st.lists(SAFE, min_size=1, max_size=8))
def test_encode_decode_round_trip(words):
    enc = T.encode_strings(words, 24)
    dec = T.decode_strings(enc)
    assert list(dec) == [w[:24] for w in words]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-(2**31), 2**31 - 1), min_size=1, max_size=16))
def test_number_to_string_matches_python(vals):
    arr = jnp.asarray(vals, jnp.int64)
    out = T.decode_strings(np.asarray(strops.number_to_string(arr, 24)))
    assert list(out) == [str(v) for v in vals]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1, max_size=8))
def test_string_to_number_parses_printed_floats(vals):
    printed = [f"{v:.4f}" for v in vals]
    arr = jnp.asarray(T.encode_strings(printed, 24))
    out = np.asarray(strops.string_to_number(arr, "float64"))
    np.testing.assert_allclose(out, [float(p) for p in printed], rtol=1e-9, atol=1e-9)


def test_string_to_number_invalid():
    arr = jnp.asarray(T.encode_strings(["abc", "", "1.2.3", "--4"], 8))
    out = np.asarray(strops.string_to_number(arr, "float32"))
    assert np.isnan(out).all()


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.lists(st.text(alphabet="abcXYZ09", min_size=0, max_size=6), min_size=0, max_size=5),
        min_size=1,
        max_size=6,
    )
)
def test_split_matches_python(parts_list):
    joined = ["|".join(p) for p in parts_list]
    arr = jnp.asarray(T.encode_strings(joined, 48))
    out = strops.split_to_list(arr, "|", 5, default_value="PAD", out_max_len=8)
    dec = T.decode_strings(np.asarray(out))
    for row, parts in zip(dec, parts_list):
        want = [p[:8] for p in parts][:5]
        want = [w if w else "PAD" for w in want]
        want += ["PAD"] * (5 - len(want))
        # NB: splitting "" yields zero segments -> all PAD
        if parts == [""] or parts == []:
            want = ["PAD"] * 5
        assert list(row) == want


def test_split_multichar_separator():
    arr = jnp.asarray(T.encode_strings(["a<>bb<>c", "x<>y"], 24))
    out = T.decode_strings(np.asarray(strops.split_to_list(arr, "<>", 4, "P", 4)))
    assert list(out[0]) == ["a", "bb", "c", "P"]
    assert list(out[1]) == ["x", "y", "P", "P"]


@settings(max_examples=30, deadline=None)
@given(st.lists(SAFE, min_size=1, max_size=6), st.lists(SAFE, min_size=1, max_size=6))
def test_concat_matches_python(a, b):
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    ea = jnp.asarray(T.encode_strings(a, 24))
    eb = jnp.asarray(T.encode_strings(b, 24))
    out = T.decode_strings(np.asarray(strops.concat([ea, eb], "_", 64)))
    assert list(out) == [f"{x}_{y}" for x, y in zip(a, b)]


def test_case_strip_contains():
    arr = jnp.asarray(T.encode_strings(["  Hello World  ", "ABC", "xyz"], 24))
    assert list(T.decode_strings(np.asarray(strops.upper(arr)))) == [
        "  HELLO WORLD  ", "ABC", "XYZ",
    ]
    stripped = T.decode_strings(np.asarray(strops.strip_char(arr, " ")))
    assert list(stripped) == ["Hello World", "ABC", "xyz"]
    assert list(np.asarray(strops.contains(arr, "World"))) == [True, False, False]
    assert list(np.asarray(strops.startswith(arr, "AB"))) == [False, True, False]
    assert list(np.asarray(strops.endswith(arr, "yz"))) == [False, False, True]


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 3000),
    st.integers(1, 12),
    st.integers(1, 28),
)
def test_civil_round_trip(y, m, d):
    days = strops.days_from_civil(jnp.asarray([y]), jnp.asarray([m]), jnp.asarray([d]))
    yy, mm, dd = strops.civil_from_days(days)
    assert (int(yy[0]), int(mm[0]), int(dd[0])) == (y, m, d)


def test_parse_date_and_weekday():
    arr = jnp.asarray(T.encode_strings(["2026-07-12", "1999-12-31", "bad"], 12))
    days = strops.parse_date(arr)
    import datetime

    assert int(days[0]) == (datetime.date(2026, 7, 12) - datetime.date(1970, 1, 1)).days
    assert int(days[1]) == (datetime.date(1999, 12, 31) - datetime.date(1970, 1, 1)).days
    assert int(days[2]) < -(2**61)
    # 2026-07-12 is a Sunday (ISO 7)
    assert int(strops.weekday_from_days(days[:1])[0]) == 7
