"""Unit tests for the fault-tolerance substrate and its serving-tier wiring.

Everything here runs IN-PROCESS (fake clocks, Pipe-backed worker threads) —
the subprocess chaos schedules live in tests/test_chaos.py.  Covered:

* StragglerMonitor fleet statistics: warm-rank-only median (a cold joiner's
  compile-skewed EWMA must not enter the reference), the true even-count
  median (the old upper-middle shortcut made a 2-rank fleet unable to flag
  anything), clear/forget semantics;
* Liveness staleness (healthy/suspect/dead) under a fake clock;
* ProcessMesh.degraded: orphan shards to the nearest preceding live owner,
  contiguity preserved, coordinator as fallback;
* ExecuteCostModel.feasible — the single feasibility judgement the gateway
  applies at the door, at formation and on failure-path re-admission;
* gateway telemetry: hedged/resharded batches land in execute_hedge /
  execute_reshard and stay OUT of the cost model;
* MultiHostExecutor over Pipes: hedged dispatch (winner + stale-reply
  drain), death recovery, the reshard budget, rejoin, and the shutdown
  drain handshake.
"""
import threading
import time

import numpy as np
import pytest

from repro.ft import Liveness, StragglerMonitor
from repro.launch.mesh import ProcessMesh


# ---------------------------------------------------------------------------
# straggler statistics
# ---------------------------------------------------------------------------


def test_straggler_two_rank_fleet_flags_slow_member():
    """With the true median, a 2-rank fleet CAN flag its slow member (the
    old upper-middle median equalled the slow rank's own EWMA, so the
    threshold test could never trip)."""
    mon = StragglerMonitor(alpha=0.5, threshold=1.5, warmup_steps=3)
    for _ in range(4):
        mon.report("fast", 0.01)
        mon.report("slow", 0.10)
    assert "slow" in mon.flagged
    assert "fast" not in mon.flagged
    # median is the mean of the two EWMAs, not the slow one itself
    assert 0.01 < mon.summary()["median"] < 0.10


def test_straggler_cold_rank_excluded_from_median():
    """A late joiner still in warmup (cold: compile + cache fill) must not
    enter the fleet median — mixing it in skewed the reference and could
    false-flag healthy peers."""
    mon = StragglerMonitor(alpha=0.5, threshold=1.5, warmup_steps=3)
    for _ in range(4):
        mon.report("a", 0.01)
        mon.report("b", 0.012)
    med_before = mon.summary()["median"]
    mon.report("late", 5.0)  # first (cold) report: below warmup
    summary = mon.summary()
    assert "late" not in summary["warm"]
    assert summary["median"] == med_before
    # healthy peers stay unflagged with the cold EWMA around
    mon.report("a", 0.01)
    mon.report("b", 0.012)
    assert mon.flagged == []


def test_straggler_clear_and_forget():
    mon = StragglerMonitor(alpha=0.5, threshold=1.5, warmup_steps=2)
    for _ in range(3):
        mon.report("ok", 0.01)
        mon.report("bad", 0.2)
    assert "bad" in mon.flagged
    mon.clear("bad")
    assert "bad" not in mon.flagged
    # still slow: the next report re-flags (EWMA was kept)
    mon.report("ok", 0.01)
    mon.report("bad", 0.2)
    assert "bad" in mon.flagged
    # forget drops the rank entirely — a restart is a new population
    mon.forget("bad")
    assert "bad" not in mon.flagged
    assert "bad" not in mon.ewma and "bad" not in mon.count
    mon.report("bad", 0.01)  # fresh history: one report, far below warmup
    assert mon.count["bad"] == 1 and mon.flagged == []


def test_liveness_states_under_fake_clock():
    t = [100.0]
    lv = Liveness(timeout_s=2.0, clock=lambda: t[0])
    assert lv.state() == "healthy"
    t[0] = 101.9
    assert lv.state() == "healthy"
    t[0] = 103.0  # one missed window: maybe merely slow
    assert lv.state() == "suspect"
    t[0] = 104.5  # two missed windows: presumed down
    assert lv.state() == "dead"
    lv.beat()
    assert lv.age() == 0.0 and lv.state() == "healthy"


# ---------------------------------------------------------------------------
# degraded-mesh derivation (pure shard arithmetic: no devices touched)
# ---------------------------------------------------------------------------


def _mesh(shard_process, process_id=0):
    return ProcessMesh(
        process_id=process_id,
        num_processes=max(shard_process) + 1,
        shard_process=tuple(shard_process),
        local_mesh=None,
    )


def test_degraded_reassigns_to_nearest_preceding_live_owner():
    pm = _mesh((0, 1, 2))
    assert pm.degraded(frozenset()).shard_process == (0, 1, 2)
    assert pm.degraded({1}).shard_process == (0, 0, 2)
    assert pm.degraded({2}).shard_process == (0, 1, 1)  # predecessor, not 0
    assert pm.degraded({1, 2}).shard_process == (0, 0, 0)


def test_degraded_multi_shard_processes_stay_contiguous():
    pm = _mesh((0, 0, 1, 1, 2, 2))
    deg = pm.degraded({1})
    assert deg.shard_process == (0, 0, 0, 0, 2, 2)
    # the contiguity contract (post_init would reject otherwise) and the
    # row partition are preserved: same shard blocks, new owners
    assert deg.shard_row_blocks(12) == pm.shard_row_blocks(12)
    assert deg.row_block(12) == (0, 8)


def test_degraded_leading_orphan_falls_to_first_live_owner():
    # seen from a non-coordinator survivor: shards before any live process
    # fall forward to the first live owner
    pm = _mesh((0, 1, 2), process_id=1)
    assert pm.degraded({0}).shard_process == (1, 1, 2)


def test_degraded_rejects_own_death_and_empty_fleet():
    pm = _mesh((0, 1))
    with pytest.raises(ValueError):
        pm.degraded({0})  # a process cannot outlive its own death
    with pytest.raises(ValueError):
        _mesh((0, 1), process_id=0).degraded({0, 1})


# ---------------------------------------------------------------------------
# cost-model feasibility
# ---------------------------------------------------------------------------


def test_cost_model_feasible_judgement():
    from repro.serve.gateway.costmodel import ExecuteCostModel

    cm = ExecuteCostModel(quantile=0.5, safety=1.0)
    for _ in range(8):
        cm.observe("m", 4, 0.010)
    ok, est = cm.feasible("m", 4, now=100.0, deadline=100.5)
    assert ok and est == pytest.approx(0.010, rel=0.1)
    ok, _ = cm.feasible("m", 4, now=100.0, deadline=100.001)
    assert not ok
    # no deadline, or no data (never shed on ignorance): feasible
    assert cm.feasible("m", 4, now=0.0, deadline=None) == (True, est)
    assert cm.feasible("unknown", 4, now=0.0, deadline=0.001) == (True, None)


# ---------------------------------------------------------------------------
# gateway stage tagging: failure-path durations land apart
# ---------------------------------------------------------------------------


def test_gateway_tags_hedged_and_resharded_batches(monkeypatch):
    """Batches whose routing hit a hedge or a reshard are recorded into
    execute_hedge / execute_reshard (not "execute") and are NOT fed to the
    cost model — failure-path wall time must not pollute the estimates
    healthy batches are scheduled by."""
    import itertools

    from repro.serve.gateway.costmodel import ExecuteCostModel
    from repro.serve.gateway.gateway import ServingGateway

    class TaggingServable:
        self_staging = True  # host columns straight through

        def __init__(self):
            self.next_events = None

        def __call__(self, cols):
            return {"y": np.asarray(cols["x"]) * 2.0}

        def take_batch_events(self):
            ev, self.next_events = self.next_events, None
            return ev

    ticks = itertools.count()
    fake_clock = lambda: next(ticks) * 1e-3  # noqa: E731 — deterministic durations
    sv = TaggingServable()
    cm = ExecuteCostModel()
    gw = ServingGateway(max_wait_ms=0.5, workers=1, clock=fake_clock, cost_model=cm)
    gw.register("m", sv, example={"x": np.float32(1.0)}, buckets=(1, 2), max_batch=2)

    def run_one(events):
        sv.next_events = events
        return gw.submit("m", {"x": np.float32(3.0)}, timeout=10.0)

    np.testing.assert_array_equal(run_one(None)["y"], 6.0)
    np.testing.assert_array_equal(run_one({"hedged": 1, "resharded": 0})["y"], 6.0)
    np.testing.assert_array_equal(run_one({"hedged": 1, "resharded": 2})["y"], 6.0)
    snap = gw.snapshot()["models"]["m"]
    assert snap["execute"]["count"] == 1
    assert snap["execute_hedge"]["count"] == 1
    assert snap["execute_reshard"]["count"] == 1  # reshard outranks hedge
    assert cm.observed["live"] == 1  # only the healthy batch fed the model
    gw.close()


def test_registry_passes_example_to_servable_hook():
    """register() hands self-staging servables the example row and the
    final (floored) bucket list — the warm template for rejoining workers."""
    from repro.serve.gateway.registry import ModelRegistry

    seen = {}

    class FakeServable:
        self_staging = True
        num_processes = 2

        def __call__(self, cols):
            return cols

        def register_example(self, example, buckets):
            seen["example"] = example
            seen["buckets"] = tuple(buckets)

    reg = ModelRegistry()
    reg.register(
        "m",
        FakeServable(),
        example={"x": np.float32(7.0)},
        buckets=(1, 2, 4),
        max_batch=4,
    )
    assert seen["buckets"] == (2, 4)  # sub-shard bucket already floored away
    np.testing.assert_array_equal(seen["example"]["x"], np.float32(7.0))


# ---------------------------------------------------------------------------
# executor fault paths over Pipes (one in-process worker thread)
# ---------------------------------------------------------------------------


def _double(batch):
    return {"y": np.asarray(batch["x"]) * 2.0}


def _start_worker(model, pm=None):
    """A real ShardServer serving one Pipe end on a thread; returns
    (coordinator_conn, thread, result_box)."""
    from multiprocessing import Pipe

    from repro.serve import ShardServer

    ca, cb = Pipe()
    server = ShardServer(pm or ProcessMesh.emulated(2, 1), {"m": model})
    box = {}

    def run():
        box["batches"] = server.serve(cb)
        box["shutdown"] = server.shutdown_received

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return ca, t, box


def test_executor_hedges_flagged_straggler_and_drains_stale_reply():
    from repro.serve import MultiHostExecutor

    def slow_double(batch):
        time.sleep(0.25)
        return _double(batch)

    ca, t, box = _start_worker(slow_double)
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    # pre-warm the monitor so the worker is flagged before the first batch
    for _ in range(3):
        ex.monitor.report("process0", 0.001)
        ex.monitor.report("process1", 1.0)
    assert "process1" in ex.monitor.flagged

    t0 = time.perf_counter()
    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    hedge_latency = time.perf_counter() - t0
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    ev = servable.take_batch_events()  # simulating what the gateway pops
    assert ev["hedged"] >= 1 and ev["resharded"] == 0
    ft = ex.ft_snapshot()
    assert ft["hedges"] >= 1 and ft["hedge_wins"] >= 1
    assert ft["workers"]["process1"]["outstanding"] == 1  # reply still owed
    assert hedge_latency < 0.25  # the hedge won the race, not the sleep

    # the stale reply is drained before the connection's next use — either
    # the next batch routes over a clean socket or the block is absorbed
    time.sleep(0.3)  # let the straggler's reply land
    out = servable({"x": np.asarray([3.0, 4.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [6.0, 8.0])
    assert ex._workers[1].pending == [] or len(ex._workers[1].pending) == 1
    ex.close()
    t.join(timeout=5)
    assert box.get("shutdown") in (True, None) or box.get("batches") is not None


def test_executor_recovers_dead_worker_and_reshards():
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor

    ca, cb = Pipe()
    cb.close()  # the "worker" died before ever serving (kill -9 analogue)
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    out = servable({"x": np.asarray([1.0, 2.0, 3.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0, 6.0])
    ev = servable.take_batch_events()
    assert ev["resharded"] >= 1
    ft = ex.ft_snapshot()
    assert ft["worker_deaths"] == 1 and ft["dead"] == [1]
    assert ft["recovered_blocks"] >= 1
    assert ft["kill_recover_ms"] > 0
    # subsequent batches are carved over the degraded mesh: all-local, and
    # still correct
    out = servable({"x": np.asarray([5.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [10.0])
    assert servable.take_batch_events()["resharded"] == 0
    ex.close()


def test_executor_enforces_reshard_budget():
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor, WorkerFailedError

    ca, cb = Pipe()
    cb.close()
    ex = MultiHostExecutor(
        ProcessMesh.emulated(2, 0), heartbeat_s=5.0, max_reshards=0
    )
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    with pytest.raises(WorkerFailedError, match="REPRO_FT_MAX_RESHARDS"):
        servable({"x": np.asarray([1.0, 2.0], np.float32)})
    ex.close()


def test_executor_rejoin_returns_worker_to_rotation():
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor

    ca, cb = Pipe()
    cb.close()
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    servable({"x": np.asarray([1.0, 2.0], np.float32)})  # detects the death
    assert ex.ft_snapshot()["dead"] == [1]

    # a restarted worker dials back in: trace re-probe + warm, then rotation
    ca2, t, box = _start_worker(_double)
    ex.attach(1, ca2)
    ft = ex.ft_snapshot()
    assert ft["worker_rejoins"] == 1 and ft["dead"] == []
    assert ft["workers"]["process1"]["state"] == "healthy"
    out = servable({"x": np.asarray([3.0, 4.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [6.0, 8.0])
    assert servable.take_batch_events() == {"hedged": 0, "resharded": 0}
    assert ex._workers[1].batches >= 1  # genuinely routed, not absorbed
    ex.close()
    t.join(timeout=5)
    assert box["shutdown"] is True  # acked shutdown frame, clean drain


def test_executor_close_drains_with_shutdown_handshake():
    from repro.serve import MultiHostExecutor

    ca, t, box = _start_worker(_double)
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    ex.close()
    t.join(timeout=5)
    assert box["shutdown"] is True  # explicit frame, not an EOF race
    assert box["batches"] == 1
    assert ex._workers == {}


def test_executor_idle_death_detected_by_ping_sweep():
    """A worker that dies while NO batch is in flight is still detected:
    the idle sweep pings past the heartbeat window and walks it to dead."""
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor

    ca, cb = Pipe()
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=0.05)
    ex.add_model("m", _double)
    ex.attach(1, ca)
    cb.close()  # dies silently; nothing in flight, nothing to EOF against
    deadline = time.monotonic() + 5.0
    while ex.ft_snapshot()["dead"] != [1] and time.monotonic() < deadline:
        time.sleep(0.02)
    assert ex.ft_snapshot()["dead"] == [1]
    ex.close()


def test_sweep_ping_timeout_keeps_late_pong_off_the_batch_path():
    """A ping whose pong misses the poll window while the worker is merely
    SUSPECT must be tracked in ``pending``: untracked, the late pong would
    be consumed as the next batch's reply and desync every reply after it
    (off-by-one rows — a silent bit-identity break)."""
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor, ShardServer

    tk = [100.0]
    ca, cb = Pipe()
    ex = MultiHostExecutor(
        ProcessMesh.emulated(2, 0), heartbeat_s=0.4, clock=lambda: tk[0]
    )
    servable = ex.add_model("m", _double)
    cb.send(("ok", 100.0))  # pre-loaded answer for the attach clock probe
    ex.attach(1, ca)
    assert cb.recv() == ("clock",)  # consume the probe frame
    # drive the sweep by hand: stop the background thread so exactly one
    # ping is in play
    ex._closed = True
    ex._sweeper.join(timeout=3.0)
    ex._closed = False  # close() below still runs its full drain

    tk[0] += 0.5  # one silent window: suspect, NOT dead
    ex._sweep_once()  # worker side never answers within the poll window
    w = ex._workers[1]
    assert w.alive  # suspect is not death
    assert len(w.pending) == 1 and w.pending[0][1] is None  # pong tracked

    # the pong lands LATE, then the worker serves normally
    assert cb.recv() == ("ping",)
    cb.send(("ok", "pong"))
    server = ShardServer(ProcessMesh.emulated(2, 1), {"m": _double})
    t = threading.Thread(target=server.serve, args=(cb,), daemon=True)
    t.start()

    # the next batch drains the stale pong first and gets ITS OWN rows back
    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    assert w.pending == []
    assert w.batches == 1  # genuinely routed over the cleaned socket
    out = servable({"x": np.asarray([3.0, 4.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [6.0, 8.0])
    ex.close()
    t.join(timeout=5)


def test_trace_probe_timeout_tracks_outstanding_reply():
    """A trace probe that misses its poll window on a live socket leaves a
    reply owed — it must enter ``pending`` so the next batch drains it
    instead of reading the stale int as its own output."""
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor, ShardServer

    ca, cb = Pipe()
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", _double)
    cb.send(("ok", time.perf_counter()))  # answer for the attach clock probe
    ex.attach(1, ca)
    assert cb.recv() == ("clock",)  # consume the probe frame
    ex.probe_poll_s = 0.1  # don't wait the full production window in a test

    total = servable.trace_count()  # worker silent: probe gives up
    assert isinstance(total, int)
    w = ex._workers[1]
    assert w.alive
    assert len(w.pending) == 1 and w.pending[0][1] is None  # reply owed

    assert cb.recv() == ("traces", "m")
    cb.send(("ok", 0))  # the stale payload a batch must never consume
    server = ShardServer(ProcessMesh.emulated(2, 1), {"m": _double})
    t = threading.Thread(target=server.serve, args=(cb,), daemon=True)
    t.start()

    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    assert w.pending == []
    ex.close()
    t.join(timeout=5)


def test_reshard_budget_exhaustion_is_persistent():
    """Past-budget degradation must fail EVERY batch, not just the one that
    recorded the reshard event: later batches carve around the dead worker
    with no events, and the gateway's per-request retry re-enters execute()
    — both used to succeed silently on the degraded mesh."""
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor, WorkerFailedError

    ca, cb = Pipe()
    cb.close()
    ex = MultiHostExecutor(
        ProcessMesh.emulated(2, 0), heartbeat_s=5.0, max_reshards=0
    )
    servable = ex.add_model("m", _double)
    ex.attach(1, ca)
    with pytest.raises(WorkerFailedError, match="REPRO_FT_MAX_RESHARDS"):
        servable({"x": np.asarray([1.0, 2.0], np.float32)})
    # the degraded mesh is in place now: no reshard events on later batches,
    # but serving over budget must STAY loud (this is also what the
    # gateway's solo retry hits, so the failure reaches the client)
    with pytest.raises(WorkerFailedError, match="REPRO_FT_MAX_RESHARDS"):
        servable({"x": np.asarray([3.0], np.float32)})
    ex.close()


def test_hedge_loss_unflags_recovered_straggler():
    """When the original beats the hedge, the straggler flag is lifted —
    a single transient slowdown must not duplicate-execute that worker's
    rows on every later batch forever."""
    from multiprocessing import Pipe

    from repro.serve import MultiHostExecutor

    ca, cb = Pipe()
    go = threading.Event()
    calls = [0]

    def local_model(batch):
        calls[0] += 1
        if calls[0] == 2:
            # this is the hedge re-execute: release the worker's reply and
            # linger so the original deterministically lands mid-race
            go.set()
            time.sleep(0.2)
        return _double(batch)

    def worker():
        while True:
            try:
                msg = cb.recv()
            except (EOFError, OSError):
                return
            if msg[0] == "execute":
                go.wait(5.0)
                cb.send(("ok", _double(msg[2])))
            elif msg[0] == "shutdown":
                cb.send(("ok", {"batches": 1}))
                return
            elif msg[0] == "ping":
                cb.send(("ok", "pong"))
            elif msg[0] == "clock":
                cb.send(("ok", time.perf_counter()))

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    ex = MultiHostExecutor(ProcessMesh.emulated(2, 0), heartbeat_s=5.0)
    servable = ex.add_model("m", local_model)
    ex.attach(1, ca)
    for _ in range(3):
        ex.monitor.report("process0", 0.05)
        ex.monitor.report("process1", 0.2)
    assert "process1" in ex.monitor.flagged

    out = servable({"x": np.asarray([1.0, 2.0], np.float32)})
    np.testing.assert_array_equal(out["y"], [2.0, 4.0])
    ft = ex.ft_snapshot()
    assert ft["hedges"] == 1 and ft["hedge_losses"] == 1
    # the worker caught up: un-flagged (it used to stay flagged forever)
    assert "process1" not in ex.monitor.flagged
    assert ex._workers[1].pending == []
    ex.close()
    t.join(timeout=5)


def test_env_flag_falsy_spellings(monkeypatch):
    """REPRO_FT_HEDGE=False / no / off must DISABLE hedging — any-string-
    is-true parsing silently enabled it."""
    from repro.serve.gateway.multihost import _env_flag

    for v in ("0", "false", "False", "FALSE", "no", "No", "off", "OFF", "", " no "):
        monkeypatch.setenv("REPRO_FT_HEDGE", v)
        assert _env_flag("REPRO_FT_HEDGE", True) is False, v
    for v in ("1", "true", "True", "yes", "on"):
        monkeypatch.setenv("REPRO_FT_HEDGE", v)
        assert _env_flag("REPRO_FT_HEDGE", False) is True, v
    monkeypatch.delenv("REPRO_FT_HEDGE")
    assert _env_flag("REPRO_FT_HEDGE", True) is True
    assert _env_flag("REPRO_FT_HEDGE", False) is False
