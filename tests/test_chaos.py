"""Chaos harness: the fault-tolerant serving tier under injected failures.

Every test replays the SAME seeded gateway traffic twice — once through a
1-process gateway (the reference) and once through an N-process routed
gateway with a fault schedule injected into its shard workers — and asserts
the contract of the fault-tolerant executor:

* every request completes (no fault schedule may surface a
  ``WorkerFailedError`` to a client — worker loss is the executor's problem,
  not the caller's);
* completed results are BIT-IDENTICAL to the 1-process run (recovery paths
  re-execute the same row blocks through the same bit-stable program — see
  tests/test_multihost.py for why only hash/index/affine stages qualify);
* no admission slot leaks (``pending == 0`` once traffic drains).

Fault kinds cover kill -9 mid-stream, delayed replies (straggler), dropped
connections, and drop + rejoin (a supervisor-restarted worker re-attaching
through the live accept loop).  Schedules run under both traffic shapes —
"replay" (one concurrent burst) and "stream" (paced clients, the trickle
shape of a streaming feed).

Marked ``chaos`` (plus ``multihost``/``subprocess``): slow and
timing-sensitive by nature; deselect with ``-m "not chaos"``.
"""
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from multihost import launch  # noqa: E402

pytestmark = [pytest.mark.chaos, pytest.mark.multihost, pytest.mark.subprocess]


def _base_payload(**over):
    payload = {
        "seed": 11,
        "requests": 40,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "heartbeat_s": 0.5,
        "cost_model": False,
        "traffic": "stream",
        "clients": 3,
    }
    payload.update(over)
    return payload


def _reference(payload):
    """The 1-process run of the same traffic: no faults, no routing."""
    ref_payload = dict(payload)
    ref_payload.pop("faults", None)
    ref_payload.pop("deadline_ms", None)
    return launch("gateway_chaos", 1, ref_payload, devices_per_proc=1)[0]


def _assert_contract(coord, ref, n_requests):
    """The failure-semantics contract every schedule must honour."""
    assert coord["worker_failed"] == 0, coord["errors"]
    assert coord["errors"] == {}, coord["errors"]
    assert coord["completed"] == n_requests
    assert coord["stats"]["pending"] == 0  # no leaked admission slots
    for i, (got, want) in enumerate(zip(coord["results"], ref["results"])):
        np.testing.assert_array_equal(got, want, err_msg=f"request {i}")


@pytest.mark.parametrize("nproc", [2, 3])
@pytest.mark.parametrize("traffic", ["stream", "replay"])
def test_kill_mid_stream_bit_identical(nproc, traffic):
    """kill -9 of the LAST worker mid-stream: the coordinator reshards the
    orphan row blocks onto survivors, re-executes the in-flight block, and
    every request still answers bit-identically to the 1-process run."""
    victim = nproc - 1
    # after_batches=4 lands the kill in TRAFFIC, past the 2-3 warmup batches
    # (warmup deaths recover too, but the reshard tag below asserts a
    # client-visible batch crossed the degraded mesh)
    payload = _base_payload(
        traffic=traffic,
        faults=[{"process": victim, "type": "kill", "after_batches": 4}],
    )
    ref = _reference(payload)
    parts = launch(
        "gateway_chaos", nproc, payload, devices_per_proc=1, expendable=[victim]
    )
    coord = parts[0]
    _assert_contract(coord, ref, payload["requests"])
    ft = coord["ft"]
    assert ft["worker_deaths"] >= 1
    assert ft["reshards"] >= 1
    assert victim in ft["dead"]
    assert ft["workers"][f"process{victim}"]["state"] == "dead"
    assert ft.get("recovered_blocks", 0) >= 1
    assert ft.get("kill_recover_ms", 0) > 0
    # at least one batch completed through the degraded mesh
    assert coord["stage_counts"]["execute_reshard"] >= 1
    if nproc == 3:
        # the surviving worker (process 1) kept serving after the death
        assert parts[1] is not None and parts[1]["batches"] > 0


@pytest.mark.parametrize("traffic", ["stream", "replay"])
def test_straggler_delay_hedged_bit_identical(traffic):
    """A worker delaying every reply gets flagged and hedged around; results
    stay bit-identical (the hedge re-executes the same block through the
    same program) and no request fails."""
    payload = _base_payload(
        traffic=traffic,
        hedge=True,
        faults=[
            {"process": 1, "type": "delay", "delay_s": 0.35, "batches": (0, 1 << 30)}
        ],
    )
    ref = _reference(payload)
    coord = launch("gateway_chaos", 2, payload, devices_per_proc=1)[0]
    _assert_contract(coord, ref, payload["requests"])
    ft = coord["ft"]
    assert ft.get("hedges", 0) + ft.get("busy_skips", 0) >= 1
    assert coord["stage_counts"]["execute_hedge"] >= 1
    # hedging routes AROUND the straggler, never through failure: the worker
    # was flagged (or its block absorbed), not killed
    assert ft["dead"] == []


def test_straggler_hedging_improves_deadline_hit_rate():
    """The acceptance gate: with an injected straggler and per-request
    deadlines, hedging ON yields a strictly higher deadline hit rate than
    hedging OFF at equal load."""
    base = _base_payload(
        requests=36,
        deadline_ms=400.0,
        clients=4,
        faults=[
            {"process": 1, "type": "delay", "delay_s": 0.5, "batches": (0, 1 << 30)}
        ],
    )
    off = launch(
        "gateway_chaos", 2, dict(base, hedge=False), devices_per_proc=1
    )[0]
    on = launch(
        "gateway_chaos", 2, dict(base, hedge=True), devices_per_proc=1
    )[0]
    assert on["worker_failed"] == 0 and off["worker_failed"] == 0
    assert on["hit_rate"] > off["hit_rate"], (
        f"hedging on hit rate {on['hit_rate']:.3f} not strictly above "
        f"off {off['hit_rate']:.3f}"
    )
    # completed requests still answer bit-identically to the reference
    ref = _reference(base)
    for i, got in enumerate(on["results"]):
        if got is not None:
            np.testing.assert_array_equal(got, ref["results"][i], err_msg=f"request {i}")


@pytest.mark.parametrize("nproc", [2, 3])
def test_drop_connection_bit_identical(nproc):
    """A severed connection (no rejoin): the executor reshards around the
    vanished worker exactly as for a kill, and the worker's serve loop
    drains out instead of erroring (its child exits cleanly, rc=0)."""
    payload = _base_payload(
        faults=[{"process": 1, "type": "drop", "after_batches": 4}],
    )
    ref = _reference(payload)
    parts = launch("gateway_chaos", nproc, payload, devices_per_proc=1)
    coord = parts[0]
    _assert_contract(coord, ref, payload["requests"])
    assert coord["ft"]["worker_deaths"] >= 1
    assert 1 in coord["ft"]["dead"]
    # the dropped worker reported normally (clean drain, not a crash)
    assert parts[1] is not None and parts[1]["serves"] == 1


@pytest.mark.parametrize("traffic", ["stream", "replay"])
def test_restart_and_rejoin_reenters_rotation(traffic):
    """Drop + rejoin (supervisor restart): the worker dials the live accept
    loop back, is re-probed and warmed, and re-enters rotation — its second
    life serves real batches — with results still bit-identical."""
    payload = _base_payload(
        requests=64,
        traffic=traffic,
        clients=2,
        gap_s=0.02,
        # replay is one instantaneous burst — split it so traffic remains
        # for the rejoined worker's second life to actually serve
        waves=2,
        wave_gap_s=0.8,
        rejoin_delay_s=0.2,
        faults=[{"process": 1, "type": "drop", "after_batches": 4, "rejoin": True}],
    )
    ref = _reference(payload)
    parts = launch("gateway_chaos", 2, payload, devices_per_proc=1)
    coord, worker = parts[0], parts[1]
    _assert_contract(coord, ref, payload["requests"])
    ft = coord["ft"]
    assert ft.get("worker_rejoins", 0) >= 1
    assert ft["dead"] == []  # back in rotation at shutdown
    assert worker["serves"] == 2  # first life dropped, second life served
    # the second life did real work: beyond the four pre-drop batches and
    # the rejoin warmup execute, at least one ROUTED batch ran through it
    assert worker["batches"] > 5
