"""Per-architecture smoke tests (reduced same-family configs) + decode/prefill
consistency of the cache machinery."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry

ARCHS = list(configs.ARCH_IDS)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "whisper":
        b["frames"] = jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_patches, cfg.d_model)), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_and_decode(arch):
    cfg = configs.get(arch).smoke()
    model = registry.build(cfg)
    params = model.init(0)
    batch = _batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert 0 < float(loss) < 20

    cache = model.init_cache(2, 64)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, batch["tokens"][:, :1])
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite decode logits"


@pytest.mark.parametrize("arch", ["codeqwen1_5_7b", "mamba2_780m", "recurrentgemma_9b", "deepseek_v2_236b"])
def test_decode_matches_prefill(arch):
    """Token-by-token decode reproduces the full-sequence forward logits —
    the correctness property of every cache variant (GQA append, rolling
    window, MLA latent, SSM state)."""
    cfg = dataclasses.replace(configs.get(arch).smoke(), scan_layers=False, n_layers=2)
    if cfg.block_pattern:
        cfg = dataclasses.replace(cfg, n_layers=3)
    model = registry.build(cfg)
    params = model.init(0)
    B, S = 1, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    full_logits, _ = model.logits(params, toks)

    cache = model.init_cache(B, 32)
    decode = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = decode(params, cache, toks[:, t : t + 1])
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), atol=2e-2, rtol=2e-2
    )


def test_rolling_window_cache_equals_full_history():
    """Windowed attention with a W-slot rolling cache == window-masked full
    attention once history exceeds the window."""
    arch = "recurrentgemma_9b"
    cfg = dataclasses.replace(configs.get(arch).smoke(), scan_layers=False, n_layers=3, window=8)
    model = registry.build(cfg)
    params = model.init(0)
    rng = np.random.default_rng(1)
    S = 20  # > 2x window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    full_logits, _ = model.logits(params, toks)
    cache = model.init_cache(1, 16)  # rolling: window slots only
    decode = jax.jit(model.decode_step)
    for t in range(S):
        lg, cache = decode(params, cache, toks[:, t : t + 1])
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full_logits[:, -1]), atol=2e-2, rtol=2e-2
    )


def test_vocab_padding_masked():
    cfg = configs.get("mamba2_780m").smoke()  # vocab 512 -> padded 512 (already mult)
    cfg = dataclasses.replace(cfg, vocab=500)  # force padding to 512
    model = registry.build(cfg)
    params = model.init(0)
    logits, _ = model.logits(params, jnp.zeros((1, 4), jnp.int32))
    assert logits.shape[-1] == 512
    pad_mass = np.asarray(jax.nn.softmax(logits, axis=-1)[..., 500:]).sum()
    assert pad_mass < 1e-8


def test_scan_vs_unrolled_same_loss():
    cfg = configs.get("codeqwen1_5_7b").smoke()
    b = _batch(cfg)
    m1 = registry.build(dataclasses.replace(cfg, scan_layers=True))
    m2 = registry.build(dataclasses.replace(cfg, scan_layers=False))
    p = m1.init(0)
    l1 = float(m1.loss(p, b))
    l2 = float(m2.loss(p, b))
    assert abs(l1 - l2) < 1e-4


def test_moe_aux_loss_and_balance():
    cfg = configs.get("deepseek_moe_16b").smoke()
    model = registry.build(cfg)
    params = model.init(0)
    b = _batch(cfg)
    logits, aux = model.logits(params, b["tokens"])
    assert float(aux) > 0  # load-balance loss present
    assert np.isfinite(np.asarray(logits)).all()
