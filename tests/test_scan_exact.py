"""Bit-exactness of the scan-based string/hash primitives against the seed
(unrolled-loop) reference implementations, over randomized byte tensors —
padding, signs, fractions, multi-byte separators, every seed the pipelines
use.  The references below are verbatim copies of the pre-scan code paths.

These references are jnp, so they guard the scan REWRITES; the independent
exactness backstop — pure Python/numpy references sharing nothing with jnp,
hundreds of generated cases per op, kernel interpret mode included — lives
in ``tests/test_fuzz_exact.py``."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import hashing, strops
from repro.core import types as T

RNG = np.random.default_rng(0xC5E)


# ---------------------------------------------------------------------------
# reference implementations (frozen copies of the seed's unrolled loops)
# ---------------------------------------------------------------------------

def _ref_fnv1a64(strings, seed=0):
    s = strings.astype(jnp.uint64)
    h = jnp.full(strings.shape[:-1], hashing.FNV_OFFSET ^ jnp.uint64(seed), jnp.uint64)
    for i in range(strings.shape[-1]):
        b = s[..., i]
        upd = (h ^ b) * hashing.FNV_PRIME
        h = jnp.where(b == 0, h, upd)
    return hashing._avalanche(h)


def _ref_string_to_number(strings, dtype="float32"):
    s = strings.astype(jnp.int32)
    L = strings.shape[-1]
    shape = strings.shape[:-1]
    val = jnp.zeros(shape, jnp.float64)
    scale = jnp.ones(shape, jnp.float64)
    seen_dot = jnp.zeros(shape, bool)
    seen_digit = jnp.zeros(shape, bool)
    invalid = jnp.zeros(shape, bool)
    neg = jnp.zeros(shape, bool)
    for i in range(L):
        c = s[..., i]
        is_nul = c == 0
        is_digit = (c >= 48) & (c <= 57)
        is_dot = c == 46
        is_sign = ((c == 43) | (c == 45)) & (i == 0)
        d = (c - 48).astype(jnp.float64)
        val = jnp.where(is_digit & ~seen_dot, val * 10.0 + d, val)
        scale = jnp.where(is_digit & seen_dot, scale * 0.1, scale)
        val = jnp.where(is_digit & seen_dot, val + d * scale, val)
        seen_digit = seen_digit | is_digit
        invalid = invalid | ~(is_nul | is_digit | is_dot | is_sign) | (is_dot & seen_dot)
        seen_dot = seen_dot | is_dot
        neg = jnp.where(is_sign & (c == 45), True, neg)
    invalid = invalid | ~seen_digit
    out = jnp.where(neg, -val, val)
    jdt = jnp.dtype(dtype)
    if jnp.issubdtype(jdt, jnp.floating):
        return jnp.where(invalid, jnp.nan, out).astype(jdt)
    return jnp.where(invalid, 0, out).astype(jdt)


def _ref_concat(parts, separator="", max_len=32):
    """Frozen copy of the pre-scan concat: python loop over parts x offsets."""
    lead = jnp.broadcast_shapes(*[p.shape[:-1] for p in parts])
    N = 1
    for dd in lead:
        N *= dd
    pieces = []
    if separator:
        sep_const = jnp.broadcast_to(
            jnp.asarray(T.encode_strings([separator], len(separator))[0]),
            (N, len(separator)),
        )
    for i, p in enumerate(parts):
        if i > 0 and separator:
            pieces.append(sep_const)
        pieces.append(jnp.broadcast_to(p, lead + p.shape[-1:]).reshape(N, p.shape[-1]))
    out = jnp.zeros((N * max_len,), jnp.uint8)
    offs = jnp.zeros((N,), jnp.int64)
    rows = jnp.arange(N)
    for p in pieces:
        Lp = p.shape[-1]
        cols = offs[:, None] + jnp.arange(Lp)[None, :]
        valid = (p != 0) & (cols < max_len)
        flat = rows[:, None] * max_len + jnp.clip(cols, 0, max_len - 1)
        flat = jnp.where(valid, flat, N * max_len)
        out = out.at[flat.reshape(-1)].set(p.reshape(-1), mode="drop")
        offs = offs + T.string_lengths(p).astype(jnp.int64)
    return out.reshape((N, max_len)).reshape(lead + (max_len,))


def _ref_split_starts(s, separator):
    """The seed's greedy covered-until carry (python loop over positions)."""
    d = len(separator)
    raw = strops._match_at(s, separator)
    N, L = raw.shape
    starts = []
    cu = jnp.zeros((N,), jnp.int32)
    for p in range(L):
        act = raw[:, p] & (p >= cu)
        cu = jnp.where(act, p + d, cu)
        starts.append(act)
    return jnp.stack(starts, axis=1)


# ---------------------------------------------------------------------------
# randomized byte tensors: text-ish, numeric-ish, and adversarial raw bytes
# ---------------------------------------------------------------------------

def _random_strings(n, max_len, kind):
    if kind == "bytes":  # arbitrary non-NUL bytes with random zero padding
        arr = RNG.integers(1, 256, (n, max_len)).astype(np.uint8)
        lens = RNG.integers(0, max_len + 1, n)
        for i, l in enumerate(lens):
            arr[i, l:] = 0
        return arr
    words = []
    for _ in range(n):
        if kind == "numeric":
            sign = RNG.choice(["", "-", "+"])
            ip = str(RNG.integers(0, 10**9))
            frac = "" if RNG.random() < 0.5 else "." + str(RNG.integers(0, 10**6))
            w = sign + ip + frac
            if RNG.random() < 0.2:  # corrupt some rows
                w = w.replace(w[RNG.integers(0, len(w))], "x", 1)
        else:
            alpha = "abcXYZ019 .,|<>-+"
            w = "".join(RNG.choice(list(alpha), RNG.integers(0, max_len)))
        words.append(w)
    return T.encode_strings(words, max_len)


@pytest.mark.parametrize("kind", ["text", "numeric", "bytes"])
@pytest.mark.parametrize("max_len", [8, 32])
def test_fnv1a64_scan_bit_exact(kind, max_len):
    s = jnp.asarray(_random_strings(200, max_len, kind))
    for seed in (0, 1, 5, 2**31):
        got = np.asarray(hashing.fnv1a64(s, seed))
        want = np.asarray(_ref_fnv1a64(s, seed))
        np.testing.assert_array_equal(got, want)


def test_fnv1a64_scan_nested_shape():
    s = jnp.asarray(_random_strings(60, 16, "text")).reshape(3, 20, 16)
    np.testing.assert_array_equal(
        np.asarray(hashing.fnv1a64(s)), np.asarray(_ref_fnv1a64(s))
    )


@pytest.mark.parametrize("kind", ["numeric", "text", "bytes"])
@pytest.mark.parametrize("dtype", ["float64", "float32", "int64"])
def test_string_to_number_scan_bit_exact(kind, dtype):
    s = jnp.asarray(_random_strings(300, 24, kind))
    got = np.asarray(strops.string_to_number(s, dtype))
    want = np.asarray(_ref_string_to_number(s, dtype))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("sep", ["|", "<>", ",,", "abc"])
def test_split_carry_scan_bit_exact(sep):
    # adversarial: separators adjacent, overlapping, at the edges
    pieces = ["", "a", "ab", sep, sep + sep, "x" + sep, sep + "y", "end"]
    words = [
        sep.join(RNG.choice(pieces, RNG.integers(0, 5)).tolist()) for _ in range(200)
    ]
    s = jnp.asarray(T.encode_strings(words, 40))
    got = np.asarray(_ref_split_starts(s, sep))
    # reproduce the scan path's starts via the public function result: compare
    # full outputs of split_to_list against a reference split built from the
    # reference starts — simplest is comparing public output to python split
    out = T.decode_strings(np.asarray(strops.split_to_list(s, sep, 6, "P", 10)))
    for row, w in zip(out, words):
        want = [p[:10] for p in w.split(sep)][:6]
        want = [p if p else "P" for p in want]
        if w == "":
            want = []
        want += ["P"] * (6 - len(want))
        assert list(row) == want, (w, list(row), want)
    # and the internal greedy-carry is identical to the seed loop
    from repro.core.strops import _match_at

    d = len(sep)
    raw = _match_at(s, sep)

    def carry_step(cu, xs):
        rawp, p = xs
        act = rawp & (p >= cu)
        return jnp.where(act, p + d, cu), act

    _, start_t = jax.lax.scan(
        carry_step,
        jnp.zeros((s.shape[0],), jnp.int32),
        (jnp.moveaxis(raw, 1, 0), jnp.arange(s.shape[1], dtype=jnp.int32)),
    )
    np.testing.assert_array_equal(np.asarray(jnp.moveaxis(start_t, 0, 1)), got)


@pytest.mark.parametrize("sep", ["", "-", "||"])
@pytest.mark.parametrize("max_len", [12, 40])
def test_concat_scan_bit_exact(sep, max_len):
    """Scan-based concat == the seed's unrolled parts x offsets loop, over
    randomized piece widths (truncation at max_len included)."""
    parts = [
        jnp.asarray(_random_strings(150, w, kind))
        for w, kind in [(6, "text"), (10, "bytes"), (4, "numeric"), (14, "text")]
    ]
    got = np.asarray(strops.concat(parts, sep, max_len))
    want = np.asarray(_ref_concat(parts, sep, max_len))
    np.testing.assert_array_equal(got, want)


def test_concat_scan_nested_shape():
    a = jnp.asarray(_random_strings(60, 8, "text")).reshape(3, 20, 8)
    b = jnp.asarray(_random_strings(60, 6, "text")).reshape(3, 20, 6)
    got = np.asarray(strops.concat([a, b], "+", 20))
    want = np.asarray(_ref_concat([a, b], "+", 20))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("sep", ["|", "<>", "abc"])
def test_split_gather_bit_exact(sep):
    """Gather-based split materialisation == the seed's scatter over
    adversarial inputs (adjacent separators, edges, interior zeros only via
    padding)."""
    pieces = ["", "a", "ab", sep, sep + sep, "x" + sep, sep + "y", "end", "0.5"]
    words = [
        sep.join(RNG.choice(pieces, RNG.integers(0, 6)).tolist()) for _ in range(300)
    ]
    s = jnp.asarray(T.encode_strings(words, 48))
    out = T.decode_strings(np.asarray(strops.split_to_list(s, sep, 5, "D", 12)))
    for row, w in zip(out, words):
        want = [p[:12] for p in w.split(sep)][:5]
        want = [p if p else "D" for p in want]
        if w == "":
            want = []
        want += ["D"] * (5 - len(want))
        assert list(row) == want, (w, list(row), want)


# ---------------------------------------------------------------------------
# kernel routing: raw-hash and seeded-bin kernel paths match the jnp scan
# ---------------------------------------------------------------------------

def test_kernel_raw_hash_bit_exact():
    from repro.kernels.bloom_hash import ops

    s = jnp.asarray(_random_strings(130, 16, "text"))
    for seed in (0, 3):
        np.testing.assert_array_equal(
            np.asarray(ops.fnv1a64_raw(s, seed)),
            np.asarray(hashing.fnv1a64(s, seed)),
        )


def test_kernel_seeded_bins_bit_exact():
    from repro.kernels.bloom_hash import ops

    s = jnp.asarray(_random_strings(130, 16, "text"))
    for seed in (0, 7):
        np.testing.assert_array_equal(
            np.asarray(ops.hash_indices_seeded(s, 4096, seed)),
            np.asarray(hashing.hash_to_bins(s, 4096, seed)),
        )


def test_routed_helpers_jnp_fallback(monkeypatch):
    # off-TPU with no override, routing must take the jnp path
    monkeypatch.delenv("REPRO_HASH_KERNEL", raising=False)
    s = jnp.asarray(_random_strings(50, 16, "text"))
    np.testing.assert_array_equal(
        np.asarray(hashing.fnv1a64_routed(s, 2)), np.asarray(hashing.fnv1a64(s, 2))
    )
    # forced kernel (interpret mode on CPU) stays bit-exact
    monkeypatch.setenv("REPRO_HASH_KERNEL", "1")
    np.testing.assert_array_equal(
        np.asarray(hashing.fnv1a64_routed(s)), np.asarray(hashing.fnv1a64(s))
    )
