"""Per-transformer / per-estimator unit tests against numpy references."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AbsoluteValueTransformer,
    ArrayAggregateTransformer,
    BloomEncodeTransformer,
    BucketizeTransformer,
    ClipTransformer,
    CoalesceTransformer,
    ComparisonTransformer,
    DateAddTransformer,
    DateDiffTransformer,
    DatePartTransformer,
    HashIndexTransformer,
    IfThenElseTransformer,
    ImputeEstimator,
    IsNullTransformer,
    LogTransformer,
    LogicalTransformer,
    MathBinaryTransformer,
    MinMaxScaleEstimator,
    OneHotTransformer,
    QuantileBinEstimator,
    SharedStringIndexEstimator,
    StringIndexEstimator,
    StringToDateTransformer,
    StringCaseTransformer,
)
from repro.core import types as T


def _apply(t, batch):
    return t.transform(batch)


def test_math_transformers():
    x = jnp.asarray([1.0, 4.0, 9.0], jnp.float32)
    b = {"x": x}
    assert np.allclose(
        _apply(LogTransformer(inputCol="x", outputCol="y", alpha=1.0), b)["y"],
        np.log1p([1, 4, 9]),
    )
    assert np.allclose(
        _apply(MathBinaryTransformer(inputCols=["x", "x"], outputCol="y", op="mul"), b)["y"],
        [1, 16, 81],
    )
    assert np.allclose(
        _apply(MathBinaryTransformer(inputCol="x", outputCol="y", op="div", constant=2.0), b)["y"],
        [0.5, 2, 4.5],
    )
    assert np.allclose(
        _apply(ClipTransformer(inputCol="x", outputCol="y", minValue=2, maxValue=5), b)["y"],
        [2, 4, 5],
    )
    assert np.allclose(
        _apply(AbsoluteValueTransformer(inputCol="x", outputCol="y"), {"x": -x})["y"],
        [1, 4, 9],
    )
    out = _apply(BucketizeTransformer(inputCol="x", outputCol="y", splits=[2.0, 5.0]), b)["y"]
    assert list(np.asarray(out)) == [0, 1, 2]


def test_logical_conditional():
    b = {
        "a": jnp.asarray([1.0, np.nan, 3.0], jnp.float32),
        "c": jnp.asarray([True, False, True]),
        "t": jnp.asarray([1.0, 1.0, 1.0], jnp.float32),
        "e": jnp.asarray([0.0, 0.0, 0.0], jnp.float32),
    }
    assert list(np.asarray(_apply(IsNullTransformer(inputCol="a", outputCol="y"), b)["y"])) == [
        False, True, False,
    ]
    out = _apply(CoalesceTransformer(inputCol="a", outputCol="y", fillValue=-1.0), b)["y"]
    assert list(np.asarray(out)) == [1.0, -1.0, 3.0]
    out = _apply(IfThenElseTransformer(inputCols=["c", "t", "e"], outputCol="y"), b)["y"]
    assert list(np.asarray(out)) == [1.0, 0.0, 1.0]
    out = _apply(ComparisonTransformer(inputCol="a", outputCol="y", op="gt", constant=2.0), b)["y"]
    assert list(np.asarray(out)) == [False, False, True]
    out = _apply(LogicalTransformer(inputCols=["c", "c"], outputCol="y", op="xor"), b)["y"]
    assert list(np.asarray(out)) == [False, False, False]


def test_dates():
    b = {"d": jnp.asarray(T.encode_strings(["2024-02-29", "2024-03-01"], 12))}
    b = _apply(StringToDateTransformer(inputCol="d", outputCol="days"), b)
    b = _apply(DatePartTransformer(inputCol="days", outputCol="m", part="month"), b)
    b = _apply(DatePartTransformer(inputCol="days", outputCol="wd", part="weekday"), b)
    b = _apply(DateAddTransformer(inputCol="days", outputCol="d2", days=1), b)
    b = _apply(DateDiffTransformer(inputCols=["d2", "days"], outputCol="diff"), b)
    assert list(np.asarray(b["m"])) == [2, 3]
    assert list(np.asarray(b["diff"])) == [1, 1]
    assert list(np.asarray(b["wd"])) == [4, 5]  # Thu, Fri


def test_hash_and_bloom_determinism_and_range():
    s = jnp.asarray(T.encode_strings(["alpha", "beta", "alpha"], 16))
    out = _apply(HashIndexTransformer(inputCol="s", outputCol="y", numBins=97), {"s": s})["y"]
    a = np.asarray(out)
    assert a[0] == a[2] and (a >= 0).all() and (a < 97).all()
    out = _apply(
        BloomEncodeTransformer(inputCol="s", outputCol="y", numBins=50, numHashes=3),
        {"s": s},
    )["y"]
    a = np.asarray(out)
    assert a.shape == (3, 3)
    assert (a[0] == a[2]).all()
    # distinct seeds should (overwhelmingly) not all collide
    assert len(np.unique(a[0])) > 1 or True


def test_hash_index_int_passthrough_matches_string():
    ids = jnp.asarray([17, 42, 17], jnp.int32)
    via_string = _apply(
        HashIndexTransformer(inputCol="i", outputCol="y", numBins=1000, inputDtype="string"),
        {"i": ids},
    )["y"]
    s = jnp.asarray(T.encode_strings(["17", "42", "17"], 32))
    direct = _apply(
        HashIndexTransformer(inputCol="s", outputCol="y", numBins=1000), {"s": s}
    )["y"]
    np.testing.assert_array_equal(np.asarray(via_string), np.asarray(direct))


def test_string_indexer_oov_and_mask():
    train = jnp.asarray(T.encode_strings(["a", "a", "a", "b", "b", "c", "PAD"], 8))
    est = StringIndexEstimator(
        inputCol="s", outputCol="y", numOOVIndices=2, maskToken="PAD",
        stringOrderType="frequencyDesc",
    )
    fitted = est.fit_batch({"s": train})
    test = jnp.asarray(T.encode_strings(["a", "b", "c", "UNSEEN", "PAD"], 8))
    idx = np.asarray(fitted.transform({"s": test})["y"])
    # layout: 0=mask, 1..2=OOV, 3=a (most frequent), 4=b, 5=c
    assert idx[0] == 3 and idx[1] == 4 and idx[2] == 5
    assert idx[3] in (1, 2)
    assert idx[4] == 0


def test_string_indexer_alphabetical():
    train = jnp.asarray(T.encode_strings(["pear", "apple", "mango", "apple"], 8))
    est = StringIndexEstimator(
        inputCol="s", outputCol="y", numOOVIndices=0, stringOrderType="alphabeticalAsc"
    )
    fitted = est.fit_batch({"s": train})
    idx = np.asarray(fitted.transform({"s": train})["y"])
    assert list(idx) == [2, 0, 1, 0]


def test_shared_indexer_spans_columns():
    a = jnp.asarray(T.encode_strings(["x", "y"], 8))
    b = jnp.asarray(T.encode_strings(["y", "z"], 8))
    est = SharedStringIndexEstimator(
        inputCols=["a", "b"], outputCols=["ai", "bi"], numOOVIndices=0
    )
    fitted = est.fit_batch({"a": a, "b": b})
    out = fitted.transform({"a": a, "b": b})
    ai, bi = np.asarray(out["ai"]), np.asarray(out["bi"])
    assert ai[1] == bi[0]  # "y" maps identically through both columns
    assert len({ai[0], ai[1], bi[1]}) == 3


def test_impute_mean_and_median():
    x = jnp.asarray([1.0, np.nan, 3.0, np.nan, 100.0], jnp.float32)
    mean_f = ImputeEstimator(inputCol="x", outputCol="y", strategy="mean").fit_batch({"x": x})
    out = np.asarray(mean_f.transform({"x": x})["y"])
    want_mean = np.nanmean(np.asarray(x))
    np.testing.assert_allclose(out[1], want_mean, rtol=1e-6)
    med_f = ImputeEstimator(inputCol="x", outputCol="y", strategy="median").fit_batch({"x": x})
    out = np.asarray(med_f.transform({"x": x})["y"])
    assert abs(out[1] - 3.0) / 3.0 < 0.05  # DDSketch ~4% relative error


def test_minmax_and_quantile():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.lognormal(0, 1, 4000), jnp.float32)
    mm = MinMaxScaleEstimator(inputCol="x", outputCol="y").fit_batch({"x": x})
    y = np.asarray(mm.transform({"x": x})["y"])
    assert y.min() >= -1e-6 and y.max() <= 1 + 1e-6
    qb = QuantileBinEstimator(inputCol="x", outputCol="y", numBuckets=4).fit_batch({"x": x})
    y = np.asarray(qb.transform({"x": x})["y"])
    frac = [(y == i).mean() for i in range(4)]
    assert all(0.15 < f < 0.35 for f in frac), frac  # ~equal-frequency


def test_one_hot_fixed_depth():
    out = OneHotTransformer(inputCol="i", outputCol="y", depth=4).transform(
        {"i": jnp.asarray([0, 3, 2])}
    )["y"]
    np.testing.assert_array_equal(
        np.asarray(out), np.eye(4, dtype=np.float32)[[0, 3, 2]]
    )


def test_array_aggregate_masked():
    x = jnp.asarray([[1.0, 2.0, -1.0], [3.0, -1.0, -1.0]], jnp.float32)
    out = ArrayAggregateTransformer(
        inputCol="x", outputCol="y", op="mean", maskValue=-1.0
    ).transform({"x": x})["y"]
    np.testing.assert_allclose(np.asarray(out), [1.5, 3.0])


def test_nested_sequence_elementwise():
    """Paper §2: element-wise ops preserve nested (batch, list) shapes."""
    amen = jnp.asarray(
        T.encode_strings([["pool,spa", "gym"], ["wifi", "pool"]], 24)
    )  # (2, 2, 24)
    t = HashIndexTransformer(inputCol="a", outputCol="y", numBins=64)
    out = t.transform({"a": amen})["y"]
    assert out.shape == (2, 2)
    # same string -> same index across nest positions
    a = np.asarray(out)
    t2 = HashIndexTransformer(inputCol="a", outputCol="y", numBins=64)
    flat = t2.transform({"a": amen.reshape(4, 24)})["y"]
    np.testing.assert_array_equal(a.reshape(-1), np.asarray(flat))
