"""PlanRunner streaming executor + sharding-aware plan cache + persisted
schedules: batch-for-batch equivalence with the eager interpreter, executable
cache hits across (signature, mesh) and misses across meshes, one plan
serving unsharded and mesh-sharded calls without re-analysis, and export
bundles that reload without re-running plan analysis."""
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Engine,
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    PlanRunner,
    StringIndexEstimator,
    TransformPlan,
)
from repro.core import types as T

REPO = pathlib.Path(__file__).resolve().parents[1]


def _assert_batch_close(a, b, keys=None):
    keys = keys if keys is not None else set(a.keys())
    assert set(a.keys()) >= set(keys) and set(b.keys()) >= set(keys)
    for k in keys:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        assert x.shape == y.shape, k
        if np.issubdtype(x.dtype, np.floating):
            np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6, err_msg=k)
        else:
            np.testing.assert_array_equal(x, y, err_msg=k)


def _mk_batch(n, seed):
    rng = np.random.default_rng(seed)
    return {
        "UserID": np.asarray(rng.integers(1, 500, n), np.int32),
        "Price": np.asarray(rng.lognormal(3, 2, n), np.float32),
        "unused_extra": np.asarray(rng.normal(0, 1, n), np.float32),
    }


@pytest.fixture(scope="module")
def fitted():
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="uh", inputDtype="string", numBins=1000
            ),
            StringIndexEstimator(
                inputCol="UserID", outputCol="uv", inputDtype="string", numOOVIndices=1
            ),
            LogTransformer(inputCol="Price", outputCol="pl", alpha=1.0),
        ]
    )
    return pipe.fit({k: jnp.asarray(v) for k, v in _mk_batch(64, 0).items()})


# ---------------------------------------------------------------------------
# streaming equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "kwargs",
    [
        dict(pack=1, workers=1, prefetch=0),
        dict(pack=3, workers=1, prefetch=2),
        dict(pack=4, workers=2, prefetch=2),
        dict(pack=4, workers=2, prefetch=2, materialize="host"),
    ],
)
def test_runner_matches_eager_batch_for_batch(fitted, kwargs):
    batches = [_mk_batch(16, 100 + i) for i in range(7)]
    runner = PlanRunner(fitted.plan(), donate=True, **kwargs)
    outs = runner.run_collect(iter(batches))
    assert len(outs) == len(batches)
    for b, o in zip(batches, outs):
        ref = fitted.transform({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref)
    assert runner.stats["rows"] == 16 * 7
    assert runner.stats["batches_in"] == 7


def test_runner_pruned_outputs_and_required_inputs(fitted):
    plan = fitted.plan(outputs=["uh", "pl"])
    req = plan.required_inputs()
    assert set(req) == {"UserID", "Price"}  # unused_extra never staged
    batches = [_mk_batch(16, 200 + i) for i in range(5)]
    runner = PlanRunner(plan, pack=2, materialize="host")
    outs = runner.run_collect(iter(batches))
    for b, o in zip(batches, outs):
        assert set(o.keys()) == {"uh", "pl"}
        assert all(isinstance(v, np.ndarray) for v in o.values())
        ref = fitted.transform({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref, keys=["uh", "pl"])


def test_runner_handles_signature_changes_and_leftovers(fitted):
    # 3 batches of 16, then 2 of 8: groups flush on signature change and at
    # iterator end; every batch still comes back, in order
    batches = [_mk_batch(16, i) for i in range(3)] + [_mk_batch(8, 50 + i) for i in range(2)]
    runner = PlanRunner(fitted.plan(), pack=8)
    outs = runner.run_collect(iter(batches))
    assert [int(next(iter(o.values())).shape[0]) for o in outs] == [16, 16, 16, 8, 8]
    for b, o in zip(batches, outs):
        ref = fitted.transform({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref)


def test_runner_never_donates_caller_arrays(fitted):
    """A lone device-resident batch passes through device_put unchanged; the
    donating executable must still not invalidate the CALLER's arrays."""
    batch = {k: jnp.asarray(v) for k, v in _mk_batch(16, 77).items()}
    runner = PlanRunner(fitted.plan(), donate=True, pack=4, workers=1)
    outs = runner.run_collect(iter([batch]))
    assert len(outs) == 1
    # caller's arrays survive the donated execution
    _ = [np.asarray(v) for v in batch.values()]
    ref = fitted.transform(batch)
    _assert_batch_close(outs[0], ref)


def test_transform_stream_api(fitted):
    batches = [_mk_batch(16, 300 + i) for i in range(3)]
    outs = list(fitted.transform_stream(iter(batches), pack=2))
    assert len(outs) == 3
    ref = fitted.transform({k: jnp.asarray(v) for k, v in batches[0].items()})
    _assert_batch_close(outs[0], ref)


# ---------------------------------------------------------------------------
# sharding-aware executable cache: one plan, many execution contexts
# ---------------------------------------------------------------------------

def test_plan_cache_unsharded_and_mesh_sharded_without_reanalysis(fitted):
    from repro.launch.mesh import make_host_mesh, use_mesh

    # fresh plan so trace/cache counters start at zero (the module fixture's
    # cached plan has served other tests)
    plan = TransformPlan(fitted.stages)
    batch = {k: jnp.asarray(v) for k, v in _mk_batch(32, 9).items()}

    out_plain = plan(batch)
    plan(batch)  # same signature, no engine: cache hit
    assert plan.stats["trace_count"] == 1
    assert plan.stats["jit_cache_entries"] == 1

    mesh = make_host_mesh(data=1, model=1)
    eng = Engine(mesh)
    with use_mesh(mesh):
        sharded = eng.shard_batch(batch)
        out_sh = plan(sharded, engine=eng)
        # same signature + same mesh: cache hit, no retrace
        plan(sharded, engine=eng)
    assert plan.stats["trace_count"] == 2
    assert plan.stats["jit_cache_entries"] == 2

    # a mesh with different axes is a different sharding -> cache miss
    mesh2 = jax.make_mesh((1,), ("data",))
    eng2 = Engine(mesh2)
    with use_mesh(mesh2):
        out_sh2 = plan(eng2.shard_batch(batch), engine=eng2)
    assert plan.stats["trace_count"] == 3
    assert plan.stats["jit_cache_entries"] == 3

    _assert_batch_close(out_plain, out_sh)
    _assert_batch_close(out_plain, out_sh2)

    # transform_jit with an engine routes through the pipeline's plan cache:
    # one new entry for this engine's sharding, then hits
    pipeline_plan = fitted.plan()
    n0 = pipeline_plan.stats["jit_cache_entries"]
    fitted.transform_jit(batch, engine=eng)
    assert pipeline_plan.stats["jit_cache_entries"] == n0 + 1
    fitted.transform_jit(batch, engine=eng)
    assert pipeline_plan.stats["jit_cache_entries"] == n0 + 1


def test_engine_jit_transform_delegates_to_plan(fitted):
    plan = fitted.plan()
    eng = Engine(None)
    fn = eng.jit_transform(plan)
    assert fn is plan.jit_for()  # same cached wrapper object


def test_mesh_fingerprint():
    from repro.launch.mesh import batch_sharding, make_host_mesh, mesh_fingerprint

    assert mesh_fingerprint(None) == ()
    mesh = make_host_mesh(data=1, model=1)
    fp = mesh_fingerprint(mesh)
    assert fp[0] == ("data", "model")
    assert fp == mesh_fingerprint(make_host_mesh(data=1, model=1))
    sh = batch_sharding(mesh)
    assert sh == Engine(mesh).batch_sharding()


@pytest.mark.subprocess
def test_sharded_stream_matches_single_device():
    """8 host devices (subprocess): the SAME plan streams a sharded epoch
    through Engine.batch_sharding() and matches the single-device result."""
    script = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import (Engine, KamaeSparkPipeline, LogTransformer,
                                PlanRunner, StringIndexEstimator)
        from repro.launch.mesh import make_host_mesh, use_mesh

        rng = np.random.default_rng(0)
        def mk(seed):
            r = np.random.default_rng(seed)
            return {"MovieID": np.asarray(r.integers(1, 300, 64), np.int32),
                    "Price": np.asarray(r.lognormal(3, 2, 64), np.float32)}
        pipe = KamaeSparkPipeline(stages=[
            StringIndexEstimator(inputCol="MovieID", outputCol="mi", inputDtype="string"),
            LogTransformer(inputCol="Price", outputCol="pl", alpha=1.0),
        ])
        fitted = pipe.fit({k: jnp.asarray(v) for k, v in mk(0).items()})
        plan = fitted.plan()
        batches = [mk(10 + i) for i in range(6)]

        # unsharded pass first: entry 1 in the executable cache
        single = PlanRunner(plan, workers=1).run_collect(iter(batches))

        mesh = make_host_mesh(data=8, model=1)
        eng = Engine(mesh)
        with use_mesh(mesh):
            runner = PlanRunner(plan, engine=eng, pack=2, workers=1)
            sharded = runner.run_collect(iter(batches))
        assert plan.stats["jit_cache_entries"] == 2, plan.stats
        for a, b in zip(single, sharded):
            for k in a:
                x, y = np.asarray(a[k]), np.asarray(b[k])
                if np.issubdtype(x.dtype, np.floating):
                    np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)
                else:
                    np.testing.assert_array_equal(x, y)
        print("SHARDED_STREAM_OK")
        """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True,
        text=True,
        timeout=560,
        env={
            "PYTHONPATH": str(REPO / "src"),
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            # CPU-emulation child: stop jax probing for a TPU runtime
            "JAX_PLATFORMS": "cpu",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_STREAM_OK" in proc.stdout


# ---------------------------------------------------------------------------
# adaptive pack (REPRO_RUNNER_AUTOPACK)
# ---------------------------------------------------------------------------

class _FakeClock:
    """Monotonic fake: every read advances by ``step``, so any timed span
    measures exactly ``step`` seconds regardless of real wall time."""

    def __init__(self, step: float):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


def test_autopack_halves_toward_target(fitted):
    """Superbatches measuring over the target halve the pack (first
    measurement is discarded as compile warmup)."""
    batches = [_mk_batch(8, 500 + i) for i in range(24)]
    runner = PlanRunner(
        fitted.plan(),
        pack=8,
        prefetch=0,
        workers=1,
        autopack=True,
        autopack_target_ms=10.0,
        clock=_FakeClock(step=0.040),  # every superbatch "takes" 40ms
    )
    outs = runner.run_collect(iter(batches))
    assert len(outs) == 24
    for b, o in zip(batches, outs):
        ref = fitted.transform({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref)
    # groups: 8 (warmup), 8 -> 4, 4 -> 2, 2 -> 1, then settled at the floor
    assert runner.pack == 1
    assert runner._autopack.settled


def test_autopack_doubles_when_cheap(fitted):
    batches = [_mk_batch(8, 600 + i) for i in range(24)]
    runner = PlanRunner(
        fitted.plan(),
        pack=1,
        prefetch=0,
        workers=1,
        autopack=True,
        autopack_target_ms=10.0,
        clock=_FakeClock(step=0.001),  # far under target/2: keep doubling
    )
    outs = runner.run_collect(iter(batches))
    assert len(outs) == 24
    # groups: 1 (warmup), 1 -> 2, 2 -> 4, 4 -> 8, ...
    assert runner.pack >= 8
    for b, o in zip(batches, outs):
        ref = fitted.transform({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref)


def test_autopack_settles_inside_band(fitted):
    runner = PlanRunner(
        fitted.plan(),
        pack=4,
        prefetch=0,
        workers=1,
        autopack=True,
        autopack_target_ms=10.0,
        clock=_FakeClock(step=0.008),  # inside [target/2, target]
    )
    runner.run_collect(iter([_mk_batch(8, 700 + i) for i in range(12)]))
    assert runner.pack == 4
    assert runner._autopack.settled
    assert runner._autopack.adjustments == 0


def test_autopack_env_flag(fitted, monkeypatch):
    monkeypatch.setenv("REPRO_RUNNER_AUTOPACK", "1")
    monkeypatch.setenv("REPRO_RUNNER_PACK_TARGET_MS", "25")
    r = PlanRunner(fitted.plan())
    assert r._autopack is not None
    assert r._autopack.target == 0.025
    monkeypatch.setenv("REPRO_RUNNER_AUTOPACK", "0")
    assert PlanRunner(fitted.plan())._autopack is None
    monkeypatch.delenv("REPRO_RUNNER_AUTOPACK")
    assert PlanRunner(fitted.plan())._autopack is None  # off by default


# ---------------------------------------------------------------------------
# cross-request plan persistence (schedule in the export bundle)
# ---------------------------------------------------------------------------

def test_bundle_reload_skips_plan_analysis(fitted, monkeypatch):
    from repro.core.export import PreprocessModel

    model = fitted.export()
    blob = model.save_bytes()
    loaded = PreprocessModel.load_bytes(blob)
    assert loaded._schedule is not None

    # a loaded bundle must never re-run analysis for the full plan
    def boom(self):
        raise AssertionError("plan analysis ran on a loaded bundle")

    monkeypatch.setattr(TransformPlan, "_analyze", boom)
    plan = loaded.plan()
    assert plan.built_from_schedule
    assert loaded.plan() is plan  # and it is cached
    monkeypatch.undo()

    batch = {k: jnp.asarray(v) for k, v in _mk_batch(16, 5).items()}
    _assert_batch_close(plan(batch), model(batch))


def test_bundle_schedule_round_trips_cse_stats(fitted, tmp_path):
    from repro.core.export import PreprocessModel

    model = fitted.export()
    p = tmp_path / "bundle.rpp"
    model.save(str(p))
    loaded = PreprocessModel.load(str(p))
    plan0 = model.plan()
    plan1 = loaded.plan()
    assert plan1.cse_stats == plan0.cse_stats
    assert len(plan1._nodes) == len(plan0._nodes)
    for n0, n1 in zip(plan0._nodes, plan1._nodes):
        assert n0.in_specs == n1.in_specs
        assert n0.out_cols == n1.out_cols
        assert n0.hash_seeds == n1.hash_seeds
        assert n0.dead_after == n1.dead_after


def test_runner_streams_loaded_bundle(fitted):
    from repro.core.export import PreprocessModel

    loaded = PreprocessModel.load_bytes(fitted.export().save_bytes())
    batches = [_mk_batch(16, 400 + i) for i in range(4)]
    outs = list(loaded.stream(iter(batches), pack=2))
    assert len(outs) == 4
    for b, o in zip(batches, outs):
        ref = loaded({k: jnp.asarray(v) for k, v in b.items()})
        _assert_batch_close(o, ref)
