"""Reusable fake-device multi-host launcher.

Real multi-host jax runs need a TPU pod (or at least a cluster) — CI has one
machine.  This launcher gives every multi-host code path a faithful stand-in:
it spawns N python subprocesses, each with its OWN jax runtime over
``--xla_force_host_platform_device_count`` fake CPU devices, and hands all of
them a shared coordinator address (process 0 listens, the rest dial in) plus
a results channel back to the launching process.  Entry functions run inside
the children; whatever they return is pickled back, so a pytest (or a
benchmark, or an example) can launch the same scenario at nproc=1 and
nproc=N and compare outputs bit-for-bit.

Used by ``tests/test_multihost.py`` (differential multi-host tests, marker
``multihost``), ``benchmarks/multihost.py`` (stream_mh_*/serve_mh_* rows)
and ``examples/stream_multihost.py``.

Usage from the launching process::

    from multihost import launch
    results = launch("stream_plan", nproc=2, payload={"seed": 7})

Entry functions receive ``(ctx, payload)`` where ``ctx`` is an
:class:`MHContext`:

* ``ctx.process_id`` / ``ctx.num_processes`` — this child's coordinate;
* ``ctx.process_mesh()`` — a ``ProcessMesh.emulated`` over the child's
  fake devices;
* ``ctx.listen()`` / ``ctx.connect()`` — the shared coordinator address
  (``multiprocessing.connection`` Listener / Client with a shared authkey);
* ``ctx.init_jax_distributed()`` — a REAL ``jax.distributed.initialize``
  against a second shared port, for tests of the global-runtime topology
  paths (device enumeration works on CPU; cross-process XLA execution does
  not — execution tests use the local shard mode instead).

The child process re-executes THIS file; entry functions are looked up in
its module namespace (or importable as ``"pkg.mod:fn"``).
"""
from __future__ import annotations

import base64
import os
import pickle
import socket
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_AUTH = b"repro-multihost"


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


class MHContext:
    """Per-child handle on the launched job (see module docstring)."""

    def __init__(self, process_id, num_processes, coord_port, jaxdist_port, devices):
        self.process_id = int(process_id)
        self.num_processes = int(num_processes)
        self.coord_address = ("127.0.0.1", int(coord_port))
        self.jaxdist_port = int(jaxdist_port)
        self.devices_per_process = int(devices)
        self.authkey = _AUTH

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0

    def process_mesh(self, data_axes=("data",)):
        from repro.launch.mesh import ProcessMesh

        return ProcessMesh.emulated(
            self.num_processes, self.process_id, data_axes=data_axes
        )

    def listen(self):
        """Coordinator-side Listener on the shared address (process 0)."""
        from multiprocessing.connection import Listener

        return Listener(self.coord_address, authkey=self.authkey)

    def connect(self, timeout_s: float = 60.0):
        """Worker-side Client to the coordinator (retries until it is up)."""
        from multiprocessing.connection import Client

        deadline = time.monotonic() + timeout_s
        while True:
            try:
                return Client(self.coord_address, authkey=self.authkey)
            except (ConnectionRefusedError, FileNotFoundError, OSError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)

    def init_jax_distributed(self):
        """Initialize the real multi-process jax runtime (global device
        enumeration + process topology over the shared jaxdist port)."""
        import jax

        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{self.jaxdist_port}",
            num_processes=self.num_processes,
            process_id=self.process_id,
        )


def launch(
    entry: str,
    nproc: int,
    payload=None,
    devices_per_proc: int = 2,
    timeout_s: float = 480.0,
    extra_env=None,
):
    """Run ``entry`` in ``nproc`` fresh fake-device processes; returns the
    per-process results in process order.  Any child failure raises with
    that child's traceback and stderr tail."""
    from multiprocessing.connection import Listener

    coord_port, jaxdist_port, result_port = free_port(), free_port(), free_port()
    env = {
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "PYTHONPATH": os.path.join(REPO, "src"),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices_per_proc}",
        # the fake-device harness is CPU by definition; without the pin a
        # container with libtpu baked in stalls for minutes probing the TPU
        # metadata service before falling back
        "JAX_PLATFORMS": "cpu",
        "REPRO_MH_PAYLOAD": base64.b64encode(pickle.dumps(payload)).decode(),
    }
    env.update(extra_env or {})
    listener = Listener(("127.0.0.1", result_port), authkey=_AUTH)
    procs = []
    try:
        for pid in range(nproc):
            procs.append(
                subprocess.Popen(
                    [
                        sys.executable,
                        os.path.abspath(__file__),
                        entry,
                        str(pid),
                        str(nproc),
                        str(coord_port),
                        str(jaxdist_port),
                        str(result_port),
                        str(devices_per_proc),
                    ],
                    env=env,
                    stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE,
                    text=True,
                    cwd=REPO,
                )
            )
        import select

        results = {}
        deadline = time.monotonic() + timeout_s
        sock = listener._listener._socket  # select-able accept (stdlib impl)
        while len(results) < nproc:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{entry}: {len(results)}/{nproc} results before timeout"
                )
            ready, _, _ = select.select([sock], [], [], 1.0)
            if not ready:
                # a child that crashed before dialing in would block accept
                # forever; fail fast with its stderr instead
                for i, p in enumerate(procs):
                    if i not in results and p.poll() not in (None, 0):
                        err = p.stderr.read() if p.stderr else ""
                        raise RuntimeError(
                            f"{entry}: process {i} exited rc={p.returncode} "
                            f"before reporting:\n{err[-3000:]}"
                        )
                continue
            conn = listener.accept()
            status, pid, value = conn.recv()
            conn.close()
            if status != "ok":
                raise RuntimeError(f"{entry}: process {pid} failed:\n{value}")
            results[pid] = value
        for p in procs:
            p.wait(timeout=30)
        return [results[i] for i in range(nproc)]
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        # surface child stderr on failure paths (pytest shows it on raise)
        for i, p in enumerate(procs):
            if p.returncode not in (0, None):
                err = p.stderr.read() if p.stderr else ""
                sys.stderr.write(f"--- {entry} process {i} stderr ---\n{err[-3000:]}\n")
        listener.close()


# ---------------------------------------------------------------------------
# entry functions (run inside the children)
# ---------------------------------------------------------------------------


def _bitstable_pipeline(seed: int):
    """A fitted pipeline of bit-stable stages: hash / vocab indexing and
    affine scaling only.  Transcendental stages (log etc.) are excluded on
    purpose — XLA CPU's vectorised libm differs by lanes-per-call, so their
    outputs are only ulp-close, not bit-identical, across shard widths."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        HashIndexTransformer,
        KamaeSparkPipeline,
        StandardScaleEstimator,
        StringIndexEstimator,
    )

    rng = np.random.default_rng(seed)
    lake = {
        "MovieID": jnp.asarray(rng.integers(1, 300, 256), jnp.int32),
        "Price": jnp.asarray(rng.lognormal(3, 2, 256), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            StringIndexEstimator(
                inputCol="MovieID", outputCol="mi", inputDtype="string"
            ),
            HashIndexTransformer(
                inputCol="MovieID", outputCol="mh", inputDtype="string", numBins=997
            ),
            StandardScaleEstimator(inputCol="Price", outputCol="ps"),
        ]
    )
    return pipe.fit(lake)


def _stream_batches(payload):
    import numpy as np

    rng_sizes = payload.get("sizes", [16, 16, 16, 12, 16, 8])
    out = []
    for i, n in enumerate(rng_sizes):
        r = np.random.default_rng(1000 + payload.get("seed", 0) * 97 + i)
        out.append(
            {
                "MovieID": np.asarray(r.integers(1, 300, n), np.int32),
                "Price": np.asarray(r.lognormal(3, 2, n), np.float32),
            }
        )
    return out


def stream_plan(ctx: MHContext, payload):
    """Differential PlanRunner stream: every process drives the SAME batch
    stream through the same TransformPlan, staging only its addressable
    rows (local shard mode); returns its per-batch output blocks."""
    import numpy as np

    from repro.core import PlanRunner

    fitted = _bitstable_pipeline(payload.get("seed", 0))
    pm = ctx.process_mesh()
    runner = PlanRunner(
        fitted.plan(),
        process_mesh=pm,
        shard_mode=payload.get("shard_mode", "local"),
        pack=payload.get("pack", 2),
        workers=1,
        materialize="host",
    )
    outs = runner.run_collect(iter(_stream_batches(payload)))
    return {
        "outputs": [{k: np.asarray(v) for k, v in o.items()} for o in outs],
        "stats": dict(runner.stats),
        "fingerprint": pm.fingerprint(),
    }


def _fused_model(seed: int):
    """A FusedModel whose fwd is affine (bit-stable across shard widths)."""
    import jax.numpy as jnp

    from repro.serve import FusedModel

    fitted = _bitstable_pipeline(seed)
    export = fitted.export(outputs=["mh", "ps"])

    def fwd(params, feats):
        return feats["ps"] * params["w"] + feats["mh"] % 97

    return FusedModel(export, fwd, {"w": jnp.float32(0.5)}, donate=True)


def _replay_rows(payload):
    import numpy as np

    n = payload.get("requests", 48)
    rng = np.random.default_rng(2000 + payload.get("seed", 0))
    return [
        {
            "MovieID": np.int32(rng.integers(1, 300)),
            "Price": np.float32(rng.lognormal(3, 2)),
        }
        for _ in range(n)
    ]


def gateway_replay(ctx: MHContext, payload):
    """Differential gateway traffic replay.

    Process 0 runs the WHOLE gateway (admission, scheduler, cost model) and
    replays a seeded request schedule; at nproc>1 each formed batch is
    routed across the shard workers.  Workers run :class:`ShardServer` over
    the coordinator address.  Returns, from process 0, the per-request
    results plus snapshot facts; workers return their batch counts."""
    import numpy as np

    from repro.serve import MultiHostExecutor, ServingGateway, ShardServer, accept_workers

    seed = payload.get("seed", 0)
    pm = ctx.process_mesh()
    if not ctx.is_coordinator:
        server = ShardServer(pm, {"ranker": _fused_model(seed)})
        batches = server.connect_and_serve(ctx.coord_address, ctx.authkey)
        return {"batches": batches}

    # listen BEFORE the (slow) model build so early worker dial-ins land in
    # the backlog instead of racing connect_and_serve's retry window
    listener = ctx.listen() if ctx.num_processes > 1 else None
    fm = _fused_model(seed)
    gw = ServingGateway(
        max_pending=256,
        max_wait_ms=payload.get("max_wait_ms", 1.0),
        workers=2,
        cost_model=payload.get("cost_model", False),
    )
    ex = None
    if ctx.num_processes > 1:
        ex = MultiHostExecutor(pm)
        servable = ex.add_model("ranker", fm)
        accept_workers(listener, ex)
        listener.close()
        gw.register(
            "ranker",
            servable,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    else:
        gw.register(
            "ranker",
            fm,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    gw.warmup()
    entry = gw.registry.get("ranker")
    traces_after_warmup = entry.trace_count()
    rows = _replay_rows(payload)
    import concurrent.futures as cf

    results = [None] * len(rows)

    def client(i):
        results[i] = np.asarray(gw.submit("ranker", rows[i], timeout=60.0))

    with cf.ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(client, range(len(rows))))
    snap = gw.snapshot()
    out = {
        "results": results,
        "traces_since_warmup": entry.trace_count() - traces_after_warmup,
        "stats": snap["stats"],
        "shards": snap["models"]["ranker"]["shards"],
        "e2e_us": snap["models"]["ranker"]["e2e"],
        "execute_us": snap["models"]["ranker"]["execute"],
        "shard_us": snap["models"]["ranker"].get("shard_us", {}),
    }
    if ex is not None:
        ex.close()
    gw.close()
    return out


def jaxdist_topology(ctx: MHContext, payload):
    """Real ``jax.distributed`` initialization over fake CPU devices: every
    process sees the global device set, ProcessMesh.from_runtime computes
    the same topology everywhere, and global batch assembly via
    ``make_array_from_single_device_arrays`` places exactly this process's
    addressable rows.  (Cross-process XLA execution is not available on the
    CPU backend — execution paths are covered by the local shard mode.)"""
    ctx.init_jax_distributed()
    import jax
    import numpy as np

    from repro.core.runner import gather_addressable
    from repro.launch.mesh import ProcessMesh

    pm = ProcessMesh.from_runtime()
    n = payload.get("rows", 16)
    rows = np.arange(n, dtype=np.float32) * 2.0
    s, e = pm.addressable_row_block(n)
    staged = pm.stage_global({"x": rows[s:e]}, n)
    gathered = gather_addressable(staged["x"])
    shards = sorted(
        (int(sh.index[0].start or 0), np.asarray(sh.data)) for sh in staged["x"].addressable_shards
    )
    return {
        "process_id": pm.process_id,
        "num_processes": pm.num_processes,
        "global_devices": len(jax.devices()),
        "local_devices": len(jax.local_devices()),
        "shard_process": pm.shard_process,
        "fingerprint": pm.fingerprint(),
        "row_block": pm.row_block(n),
        "staged_shape": tuple(staged["x"].shape),
        "staged_shards": shards,
        "fully_addressable": bool(staged["x"].is_fully_addressable),
        "gathered": gathered,
        "addressable_block": (s, e),
    }


# ---------------------------------------------------------------------------
# child main
# ---------------------------------------------------------------------------


def _child_main(argv):
    entry, pid, nproc, coord_port, jaxdist_port, result_port, devices = argv
    sys.path.insert(0, os.path.join(REPO, "src"))
    ctx = MHContext(pid, nproc, coord_port, jaxdist_port, devices)
    payload = pickle.loads(base64.b64decode(os.environ["REPRO_MH_PAYLOAD"]))
    if ":" in entry:
        mod_name, fn_name = entry.split(":", 1)
        import importlib

        fn = getattr(importlib.import_module(mod_name), fn_name)
    else:
        fn = globals()[entry]
    from multiprocessing.connection import Client

    try:
        value = fn(ctx, payload or {})
        status = ("ok", ctx.process_id, value)
    except BaseException:
        import traceback

        traceback.print_exc()  # the launcher surfaces child stderr too
        status = ("err", ctx.process_id, traceback.format_exc())
    conn = Client(("127.0.0.1", int(result_port)), authkey=_AUTH)
    conn.send(status)
    conn.close()
    if status[0] == "err":
        sys.exit(1)


if __name__ == "__main__":
    _child_main(sys.argv[1:])
