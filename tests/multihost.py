"""Reusable fake-device multi-host launcher.

Real multi-host jax runs need a TPU pod (or at least a cluster) — CI has one
machine.  This launcher gives every multi-host code path a faithful stand-in:
it spawns N python subprocesses, each with its OWN jax runtime over
``--xla_force_host_platform_device_count`` fake CPU devices, and hands all of
them a shared coordinator address (process 0 listens, the rest dial in) plus
a results channel back to the launching process.  Entry functions run inside
the children; whatever they return is pickled back, so a pytest (or a
benchmark, or an example) can launch the same scenario at nproc=1 and
nproc=N and compare outputs bit-for-bit.

Used by ``tests/test_multihost.py`` (differential multi-host tests, marker
``multihost``), ``benchmarks/multihost.py`` (stream_mh_*/serve_mh_* rows)
and ``examples/stream_multihost.py``.

Usage from the launching process::

    from multihost import launch
    results = launch("stream_plan", nproc=2, payload={"seed": 7})

Entry functions receive ``(ctx, payload)`` where ``ctx`` is an
:class:`MHContext`:

* ``ctx.process_id`` / ``ctx.num_processes`` — this child's coordinate;
* ``ctx.process_mesh()`` — a ``ProcessMesh.emulated`` over the child's
  fake devices;
* ``ctx.listen()`` / ``ctx.connect()`` — the shared coordinator address
  (``multiprocessing.connection`` Listener / Client with a shared authkey);
* ``ctx.init_jax_distributed()`` — a REAL ``jax.distributed.initialize``
  against a second shared port, for tests of the global-runtime topology
  paths (device enumeration works on CPU; cross-process XLA execution does
  not — execution tests use the local shard mode instead).

The child process re-executes THIS file; entry functions are looked up in
its module namespace (or importable as ``"pkg.mod:fn"``).
"""
from __future__ import annotations

import base64
import os
import pickle
import socket
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_AUTH = b"repro-multihost"


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


class MHContext:
    """Per-child handle on the launched job (see module docstring)."""

    def __init__(self, process_id, num_processes, coord_port, jaxdist_port, devices):
        self.process_id = int(process_id)
        self.num_processes = int(num_processes)
        self.coord_address = ("127.0.0.1", int(coord_port))
        self.jaxdist_port = int(jaxdist_port)
        self.devices_per_process = int(devices)
        self.authkey = _AUTH

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0

    def process_mesh(self, data_axes=("data",)):
        from repro.launch.mesh import ProcessMesh

        return ProcessMesh.emulated(
            self.num_processes, self.process_id, data_axes=data_axes
        )

    def listen(self):
        """Coordinator-side Listener on the shared address (process 0)."""
        from multiprocessing.connection import Listener

        return Listener(self.coord_address, authkey=self.authkey)

    def connect(self, timeout_s: float = 60.0):
        """Worker-side Client to the coordinator (retries until it is up)."""
        from multiprocessing.connection import Client

        deadline = time.monotonic() + timeout_s
        while True:
            try:
                return Client(self.coord_address, authkey=self.authkey)
            except (ConnectionRefusedError, FileNotFoundError, OSError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)

    def init_jax_distributed(self):
        """Initialize the real multi-process jax runtime (global device
        enumeration + process topology over the shared jaxdist port)."""
        import jax

        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{self.jaxdist_port}",
            num_processes=self.num_processes,
            process_id=self.process_id,
        )


def launch(
    entry: str,
    nproc: int,
    payload=None,
    devices_per_proc: int = 2,
    timeout_s: float = 480.0,
    extra_env=None,
    expendable=(),
):
    """Run ``entry`` in ``nproc`` fresh fake-device processes; returns the
    per-process results in process order.  Any child failure raises with
    that child's traceback and stderr tail.

    ``expendable`` lists process ids that are ALLOWED to die without
    reporting (chaos schedules kill -9 workers mid-stream): their slot in
    the returned list is ``None`` (or their result, if they reported before
    dying) and their exit code is not an error."""
    from multiprocessing.connection import Listener

    coord_port, jaxdist_port, result_port = free_port(), free_port(), free_port()
    env = {
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "PYTHONPATH": os.path.join(REPO, "src"),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices_per_proc}",
        # the fake-device harness is CPU by definition; without the pin a
        # container with libtpu baked in stalls for minutes probing the TPU
        # metadata service before falling back
        "JAX_PLATFORMS": "cpu",
        "REPRO_MH_PAYLOAD": base64.b64encode(pickle.dumps(payload)).decode(),
    }
    env.update(extra_env or {})
    listener = Listener(("127.0.0.1", result_port), authkey=_AUTH)
    procs = []
    try:
        for pid in range(nproc):
            procs.append(
                subprocess.Popen(
                    [
                        sys.executable,
                        os.path.abspath(__file__),
                        entry,
                        str(pid),
                        str(nproc),
                        str(coord_port),
                        str(jaxdist_port),
                        str(result_port),
                        str(devices_per_proc),
                    ],
                    env=env,
                    stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE,
                    text=True,
                    cwd=REPO,
                )
            )
        import select

        expendable = set(expendable)
        needed = set(range(nproc)) - expendable
        results = {}
        deadline = time.monotonic() + timeout_s
        sock = listener._listener._socket  # select-able accept (stdlib impl)
        while not needed <= set(results):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{entry}: {len(results)}/{nproc} results before timeout"
                )
            ready, _, _ = select.select([sock], [], [], 1.0)
            if not ready:
                # a child that crashed before dialing in would block accept
                # forever; fail fast with its stderr instead (an EXPENDABLE
                # child dying is part of the schedule, not a failure)
                for i, p in enumerate(procs):
                    if (
                        i not in results
                        and i not in expendable
                        and p.poll() not in (None, 0)
                    ):
                        err = p.stderr.read() if p.stderr else ""
                        raise RuntimeError(
                            f"{entry}: process {i} exited rc={p.returncode} "
                            f"before reporting:\n{err[-3000:]}"
                        )
                continue
            conn = listener.accept()
            status, pid, value = conn.recv()
            conn.close()
            if status != "ok":
                raise RuntimeError(f"{entry}: process {pid} failed:\n{value}")
            results[pid] = value
        for i, p in enumerate(procs):
            if i in expendable and i not in results:
                p.kill()  # an expendable child may be wedged on a dead peer
            p.wait(timeout=30)
        return [results.get(i) for i in range(nproc)]
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        # surface child stderr on failure paths (pytest shows it on raise)
        for i, p in enumerate(procs):
            if p.returncode not in (0, None) and i not in expendable:
                err = p.stderr.read() if p.stderr else ""
                sys.stderr.write(f"--- {entry} process {i} stderr ---\n{err[-3000:]}\n")
        listener.close()


# ---------------------------------------------------------------------------
# entry functions (run inside the children)
# ---------------------------------------------------------------------------


def _bitstable_pipeline(seed: int):
    """A fitted pipeline of bit-stable stages: hash / vocab indexing and
    affine scaling only.  Transcendental stages (log etc.) are excluded on
    purpose — XLA CPU's vectorised libm differs by lanes-per-call, so their
    outputs are only ulp-close, not bit-identical, across shard widths."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        HashIndexTransformer,
        KamaeSparkPipeline,
        StandardScaleEstimator,
        StringIndexEstimator,
    )

    rng = np.random.default_rng(seed)
    lake = {
        "MovieID": jnp.asarray(rng.integers(1, 300, 256), jnp.int32),
        "Price": jnp.asarray(rng.lognormal(3, 2, 256), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            StringIndexEstimator(
                inputCol="MovieID", outputCol="mi", inputDtype="string"
            ),
            HashIndexTransformer(
                inputCol="MovieID", outputCol="mh", inputDtype="string", numBins=997
            ),
            StandardScaleEstimator(inputCol="Price", outputCol="ps"),
        ]
    )
    return pipe.fit(lake)


def _stream_batches(payload):
    import numpy as np

    rng_sizes = payload.get("sizes", [16, 16, 16, 12, 16, 8])
    out = []
    for i, n in enumerate(rng_sizes):
        r = np.random.default_rng(1000 + payload.get("seed", 0) * 97 + i)
        out.append(
            {
                "MovieID": np.asarray(r.integers(1, 300, n), np.int32),
                "Price": np.asarray(r.lognormal(3, 2, n), np.float32),
            }
        )
    return out


def stream_plan(ctx: MHContext, payload):
    """Differential PlanRunner stream: every process drives the SAME batch
    stream through the same TransformPlan, staging only its addressable
    rows (local shard mode); returns its per-batch output blocks."""
    import numpy as np

    from repro.core import PlanRunner

    fitted = _bitstable_pipeline(payload.get("seed", 0))
    pm = ctx.process_mesh()
    runner = PlanRunner(
        fitted.plan(),
        process_mesh=pm,
        shard_mode=payload.get("shard_mode", "local"),
        pack=payload.get("pack", 2),
        workers=1,
        materialize="host",
    )
    outs = runner.run_collect(iter(_stream_batches(payload)))
    return {
        "outputs": [{k: np.asarray(v) for k, v in o.items()} for o in outs],
        "stats": dict(runner.stats),
        "fingerprint": pm.fingerprint(),
    }


def _fused_model(seed: int):
    """A FusedModel whose fwd is affine (bit-stable across shard widths)."""
    import jax.numpy as jnp

    from repro.serve import FusedModel

    fitted = _bitstable_pipeline(seed)
    export = fitted.export(outputs=["mh", "ps"])

    def fwd(params, feats):
        return feats["ps"] * params["w"] + feats["mh"] % 97

    return FusedModel(export, fwd, {"w": jnp.float32(0.5)}, donate=True)


def _replay_rows(payload):
    import numpy as np

    n = payload.get("requests", 48)
    rng = np.random.default_rng(2000 + payload.get("seed", 0))
    return [
        {
            "MovieID": np.int32(rng.integers(1, 300)),
            "Price": np.float32(rng.lognormal(3, 2)),
        }
        for _ in range(n)
    ]


def gateway_replay(ctx: MHContext, payload):
    """Differential gateway traffic replay.

    Process 0 runs the WHOLE gateway (admission, scheduler, cost model) and
    replays a seeded request schedule; at nproc>1 each formed batch is
    routed across the shard workers.  Workers run :class:`ShardServer` over
    the coordinator address.  Returns, from process 0, the per-request
    results plus snapshot facts; workers return their batch counts."""
    import numpy as np

    from repro.serve import MultiHostExecutor, ServingGateway, ShardServer, accept_workers

    seed = payload.get("seed", 0)
    pm = ctx.process_mesh()
    if not ctx.is_coordinator:
        server = ShardServer(pm, {"ranker": _fused_model(seed)})
        batches = server.connect_and_serve(ctx.coord_address, ctx.authkey)
        return {"batches": batches}

    # listen BEFORE the (slow) model build so early worker dial-ins land in
    # the backlog instead of racing connect_and_serve's retry window
    listener = ctx.listen() if ctx.num_processes > 1 else None
    fm = _fused_model(seed)
    gw = ServingGateway(
        max_pending=256,
        max_wait_ms=payload.get("max_wait_ms", 1.0),
        workers=2,
        cost_model=payload.get("cost_model", False),
    )
    ex = None
    if ctx.num_processes > 1:
        ex = MultiHostExecutor(pm)
        servable = ex.add_model("ranker", fm)
        accept_workers(listener, ex)
        listener.close()
        gw.register(
            "ranker",
            servable,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    else:
        gw.register(
            "ranker",
            fm,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    gw.warmup()
    entry = gw.registry.get("ranker")
    traces_after_warmup = entry.trace_count()
    rows = _replay_rows(payload)
    import concurrent.futures as cf

    results = [None] * len(rows)

    def client(i):
        results[i] = np.asarray(gw.submit("ranker", rows[i], timeout=60.0))

    with cf.ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(client, range(len(rows))))
    snap = gw.snapshot()
    out = {
        "results": results,
        "traces_since_warmup": entry.trace_count() - traces_after_warmup,
        "stats": snap["stats"],
        "shards": snap["models"]["ranker"]["shards"],
        "e2e_us": snap["models"]["ranker"]["e2e"],
        "execute_us": snap["models"]["ranker"]["execute"],
        "shard_us": snap["models"]["ranker"].get("shard_us", {}),
    }
    if ex is not None:
        ex.close()
    gw.close()
    return out


def gateway_obs(ctx: MHContext, payload):
    """Distributed-trace stitching probe: gateway_replay's topology with a
    fresh always-sampling trace recorder in every process.  Worker spans ride
    the shard replies back (clock-aligned via the attach-time offset probe),
    so the coordinator ring holds the WHOLE stitched story — process 0
    returns it as span tuples; workers return only their batch counts."""
    import numpy as np

    from repro.obs import trace as obs_trace
    from repro.serve import (
        MultiHostExecutor,
        ServingGateway,
        ShardServer,
        accept_workers,
    )

    seed = payload.get("seed", 0)
    pm = ctx.process_mesh()
    rec = obs_trace.TraceRecorder(capacity=8192, enabled=True, sample=1.0)
    obs_trace.set_recorder(rec)
    if not ctx.is_coordinator:
        server = ShardServer(pm, {"ranker": _fused_model(seed)})
        batches = server.connect_and_serve(ctx.coord_address, ctx.authkey)
        return {"batches": batches, "recorded": rec.recorded}

    listener = ctx.listen() if ctx.num_processes > 1 else None
    fm = _fused_model(seed)
    gw = ServingGateway(
        max_pending=256,
        max_wait_ms=payload.get("max_wait_ms", 1.0),
        workers=2,
        cost_model=False,
    )
    ex = None
    if ctx.num_processes > 1:
        ex = MultiHostExecutor(pm)
        servable = ex.add_model("ranker", fm)
        accept_workers(listener, ex)
        listener.close()
        gw.register(
            "ranker", servable, example=_replay_rows(payload)[0],
            buckets=(2, 4, 8), max_batch=8,
        )
    else:
        gw.register(
            "ranker", fm, example=_replay_rows(payload)[0],
            buckets=(2, 4, 8), max_batch=8,
        )
    gw.warmup()
    rows = _replay_rows({"requests": payload.get("requests", 8), "seed": seed})
    import concurrent.futures as cf

    results = [None] * len(rows)

    def client(i):
        results[i] = np.asarray(gw.submit("ranker", rows[i], timeout=60.0))

    with cf.ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(client, range(len(rows))))
    out = {
        "spans": [s.as_tuple() for s in rec.spans()],
        "recorded": rec.recorded,
        "completed": sum(1 for r in results if r is not None),
        "clock_offsets": (
            {p: w.clock_offset for p, w in ex._workers.items()}
            if ex is not None
            else {}
        ),
    }
    if ex is not None:
        ex.close()
    gw.close()
    return out


class ChaosShardServer:
    """A ShardServer with an injectable fault schedule (built lazily so the
    module stays importable without jax).

    Faults are dicts selected per worker by ``process``; kinds:

    * ``{"type": "delay", "delay_s": s, "batches": (lo, hi)}`` — sleep
      before replying to batches ``lo <= n < hi`` (a straggling worker);
    * ``{"type": "kill", "after_batches": k}`` — SIGKILL this process after
      computing batch ``k``, before its reply is sent (kill -9 mid-stream);
    * ``{"type": "drop", "after_batches": k, "rejoin": bool}`` — sever the
      connection after batch ``k``; with ``rejoin`` the worker entry dials
      back in with a FRESH (fault-free) server, modelling a supervisor
      restart.
    """

    def __new__(cls, pm, models, faults=(), **kw):
        import os as _os
        import signal as _signal
        import time as _time

        from repro.serve import ShardServer

        class _Chaos(ShardServer):
            def fault_hook(self, name, batches_done):
                for f in self._faults:
                    kind = f["type"]
                    if kind == "delay":
                        lo, hi = f.get("batches", (0, 1 << 30))
                        if lo <= batches_done < hi:
                            _time.sleep(f["delay_s"])
                    elif kind == "kill" and batches_done == f["after_batches"]:
                        _os.kill(_os.getpid(), _signal.SIGKILL)
                    elif kind == "drop" and batches_done == f["after_batches"]:
                        raise ShardServer.Drop("injected connection drop")

        server = _Chaos(pm, models, **kw)
        server._faults = list(faults)
        return server


def gateway_chaos(ctx: MHContext, payload):
    """Differential gateway traffic under an injected fault schedule.

    Like :func:`gateway_replay`, but the coordinator runs the FAULT-TOLERANT
    executor configuration (fast heartbeat, straggler monitor, optional
    hedging, live rejoin accept loop) and the workers run
    :class:`ChaosShardServer` with the payload's fault schedule.  At nproc=1
    the same traffic runs single-process — the bit-identity reference.

    Payload knobs beyond gateway_replay's: ``faults`` (see ChaosShardServer),
    ``hedge``, ``heartbeat_s``, ``deadline_ms`` (per-request finish bound;
    completion within it is a "hit"), ``traffic`` ("replay" = one concurrent
    burst, "stream" = a few paced clients with one request in flight each —
    the trickle shape of a streaming feed), ``straggler_threshold`` /
    ``straggler_warmup``.

    Satellite note: "{stream, gateway} traffic" means these two TRAFFIC
    SHAPES through the gateway — a PlanRunner stream proper has no
    per-request recovery channel (a lost block fails the whole stream), so
    fault schedules are meaningful only behind the gateway's request/reply
    contract.
    """
    import threading
    import time as _time

    import numpy as np

    from repro.serve import MultiHostExecutor, ServingGateway, accept_workers

    seed = payload.get("seed", 0)
    pm = ctx.process_mesh()
    if not ctx.is_coordinator:
        my_faults = [
            f
            for f in payload.get("faults", [])
            if int(f.get("process", 1)) == ctx.process_id
        ]
        rejoins_left = sum(1 for f in my_faults if f.get("rejoin"))
        total, serves, dial_error = 0, 0, None
        times = []
        # built ONCE: rebuilding (re-fitting) the model per life costs ~1s,
        # which would lose the rejoin race against short test traffic.  The
        # reused model keeps its jit cache warm — acceptable here, since the
        # cold-restart compile path is exercised by the coordinator-side
        # rejoin warmup regardless of worker-side cache state.
        fm = _fused_model(seed)
        while True:
            server = ChaosShardServer(
                pm,
                {"ranker": fm},
                faults=my_faults if serves == 0 else (),
            )
            times.append(("dial", _time.perf_counter()))
            try:
                total += server.connect_and_serve(
                    ctx.coord_address, ctx.authkey, timeout_s=20.0
                )
            except OSError as e:
                # coordinator already gone: nothing to rejoin to (recorded so
                # a rejoin test that LOST the race can say why)
                dial_error = f"{type(e).__name__}: {e}"
                break
            times.append(("served", _time.perf_counter()))
            serves += 1
            if server.shutdown_received or rejoins_left <= 0:
                break
            rejoins_left -= 1
            _time.sleep(payload.get("rejoin_delay_s", 0.2))
        return {
            "batches": total,
            "serves": serves,
            "dial_error": dial_error,
            "times": times,
        }

    listener = ctx.listen() if ctx.num_processes > 1 else None
    fm = _fused_model(seed)
    gw = ServingGateway(
        max_pending=512,
        max_wait_ms=payload.get("max_wait_ms", 1.0),
        workers=2,
        cost_model=payload.get("cost_model", False),
    )
    ex = None
    if ctx.num_processes > 1:
        from repro.ft import StragglerMonitor

        ex = MultiHostExecutor(
            pm,
            hedge=bool(payload.get("hedge", True)),
            heartbeat_s=payload.get("heartbeat_s", 0.5),
            # threshold must sit BELOW 2 for a 2-rank fleet: as the straggler
            # slows, the true median tends to half its EWMA, so the
            # EWMA/median ratio is bounded by 2
            monitor=StragglerMonitor(
                alpha=0.5,
                threshold=payload.get("straggler_threshold", 1.5),
                warmup_steps=payload.get("straggler_warmup", 2),
            ),
        )
        servable = ex.add_model("ranker", fm)
        # the listener stays OPEN: accept_workers keeps a live accept loop
        # so dropped/restarted workers can rejoin mid-traffic
        accept_workers(listener, ex)
        gw.register(
            "ranker",
            servable,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    else:
        gw.register(
            "ranker",
            fm,
            example=_replay_rows(payload)[0],
            buckets=tuple(payload.get("buckets", (2, 4, 8))),
            max_batch=payload.get("max_batch", 8),
        )
    gw.warmup()
    rows = _replay_rows(payload)
    deadline_ms = payload.get("deadline_ms")
    results = [None] * len(rows)
    errors = [None] * len(rows)
    lat = [None] * len(rows)

    def one(i):
        t0 = _time.perf_counter()
        try:
            results[i] = np.asarray(
                gw.submit("ranker", rows[i], deadline_ms=deadline_ms, timeout=120.0)
            )
        except BaseException as e:
            errors[i] = type(e).__name__
        lat[i] = _time.perf_counter() - t0

    t_run0 = _time.perf_counter()
    if payload.get("traffic", "replay") == "replay":
        import concurrent.futures as cf

        # "waves" splits the burst: a rejoin schedule needs traffic LEFT
        # after the worker's second life attaches, which a single
        # instantaneous burst never leaves
        waves = max(1, int(payload.get("waves", 1)))
        per = -(-len(rows) // waves)
        with cf.ThreadPoolExecutor(max_workers=8) as pool:
            for wv in range(waves):
                list(pool.map(one, range(wv * per, min((wv + 1) * per, len(rows)))))
                if wv < waves - 1:
                    _time.sleep(payload.get("wave_gap_s", 0.5))
    else:
        import queue as _queue

        q = _queue.Queue()
        for i in range(len(rows)):
            q.put(i)
        gap = payload.get("gap_s", 0.0)

        def client():
            while True:
                try:
                    i = q.get_nowait()
                except _queue.Empty:
                    return
                one(i)
                if gap:
                    _time.sleep(gap)

        threads = [
            threading.Thread(target=client)
            for _ in range(payload.get("clients", 3))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall_s = _time.perf_counter() - t_run0
    from repro.obs import flight as obs_flight

    flights = [
        {"reason": d["reason"], "span_names": sorted({s[3] for s in d["spans"]})}
        for d in obs_flight.get_flight().history
    ]
    snap = gw.snapshot()
    completed = [i for i in range(len(rows)) if results[i] is not None]
    err_counts = {}
    for e in errors:
        if e is not None:
            err_counts[e] = err_counts.get(e, 0) + 1
    hit_rate = None
    if deadline_ms is not None:
        hits = sum(1 for i in completed if lat[i] * 1e3 <= deadline_ms)
        hit_rate = hits / len(rows)
    out = {
        "results": results,
        "errors": err_counts,
        "completed": len(completed),
        "worker_failed": err_counts.get("WorkerFailedError", 0),
        "hit_rate": hit_rate,
        "ft": snap["models"]["ranker"].get("ft", {}),
        "stats": snap["stats"],
        "stage_counts": {
            s: snap["models"]["ranker"][s]["count"]
            for s in ("execute", "execute_retry", "execute_hedge", "execute_reshard")
        },
        "wall_s": wall_s,
        "flights": flights,
    }
    gw.close()
    if ex is not None:
        ex.close()
    if listener is not None:
        listener.close()
    return out


def _wide_row_model():
    """A jitted wide row-local model — purely elementwise (no reductions),
    so outputs are bit-identical whatever shard widths the rows were
    computed under; wide in AND out, so both wire directions carry the
    fat payload the transport benchmark times."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def fn(cols):
        items = cols["items"]  # (rows, width) f32
        q = cols["q"]  # (rows,) f32
        return {
            "boosted": items * jnp.float32(1.5) + q[:, None],
            "score": items[:, 0] * jnp.float32(2.0) - q,
        }

    return fn


def _ltr_score_model():
    """Wide-in narrow-out, the LTR serving shape: a fat feature block rides
    the wire in and a per-row score comes back.  Explicit column arithmetic
    only (no axis reductions, whose summation order the compiler may pick
    per batch shape) keeps outputs bit-stable across shard widths."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def fn(cols):
        items = cols["items"]
        q = cols["q"]
        score = (
            items[:, 0] * jnp.float32(1.5)
            + items[:, 1]
            - items[:, 2] * jnp.float32(0.25)
            + q
        )
        return {"score": score, "rank_key": items[:, 3] % jnp.float32(97.0)}

    return fn


def transport_roundtrip(ctx: MHContext, payload):
    """Direct shard round-trip driver for the transport benchmark and the
    differential transport tests: the coordinator executes ``iters`` routed
    batches of a wide row-local model through ``MultiHostExecutor`` (the
    payload picks the transport), returning the final outputs (bit-identity
    is asserted by the caller across transports and process counts), the
    measured per-call latency, the executor's transport/ft snapshot, and a
    post-close ``/dev/shm`` leak census.  At nproc=1 the same model runs
    in-process — the reference leg.  ``rows`` below the shard count
    exercises the empty-block dispatch path end to end."""
    import os as _os
    import time as _time

    import numpy as np

    from repro.serve import MultiHostExecutor, ShardServer, accept_workers

    pm = ctx.process_mesh()
    model = (
        _ltr_score_model() if payload.get("narrow_out") else _wide_row_model()
    )
    if not ctx.is_coordinator:
        server = ShardServer(pm, {"wide": model})
        batches = server.connect_and_serve(ctx.coord_address, ctx.authkey)
        return {"batches": batches}

    rows = int(payload.get("rows", 64))
    width = int(payload.get("width", 512))
    iters = int(payload.get("iters", 20))
    rng = np.random.default_rng(7000 + payload.get("seed", 0))
    cols = {
        "items": np.asarray(rng.normal(size=(rows, width)), np.float32),
        "q": np.asarray(rng.normal(size=(rows,)), np.float32),
    }
    ex = None
    if ctx.num_processes > 1:
        listener = ctx.listen()
        ex = MultiHostExecutor(
            pm, hedge=False, transport=payload.get("transport")
        )
        ex.add_model("wide", model)
        accept_workers(listener, ex, live=False)
        listener.close()

        def run():
            return ex.execute("wide", cols)

    else:
        import jax

        from repro.core.runner import stage_batch

        def run():
            return jax.device_get(model(stage_batch(cols)))

    out = run()  # compile + first routed round trip
    t0 = _time.perf_counter()
    for _ in range(iters):
        out = run()
    dt = _time.perf_counter() - t0
    snap = ex.ft_snapshot() if ex is not None else {}
    # per-shard round-trip sketches (dispatch -> reply consumed): the
    # transport benchmark's metric — coordinator-local compute and output
    # concat, identical across transports, are excluded
    shard_us = ex.shard_snapshot("wide") if ex is not None else {}
    if ex is not None:
        ex.close()
    leaked = sorted(
        f for f in _os.listdir("/dev/shm") if f.startswith("repro_mh_")
    )
    return {
        "outputs": {k: np.asarray(v) for k, v in out.items()},
        "us_per_call": dt / iters * 1e6,
        "shard_us": shard_us,
        "bytes_per_call": sum(v.nbytes for v in cols.values()),
        "ft": snap,
        "leaked_shm": leaked,
    }


def jaxdist_topology(ctx: MHContext, payload):
    """Real ``jax.distributed`` initialization over fake CPU devices: every
    process sees the global device set, ProcessMesh.from_runtime computes
    the same topology everywhere, and global batch assembly via
    ``make_array_from_single_device_arrays`` places exactly this process's
    addressable rows.  (Cross-process XLA execution is not available on the
    CPU backend — execution paths are covered by the local shard mode.)"""
    ctx.init_jax_distributed()
    import jax
    import numpy as np

    from repro.core.runner import gather_addressable
    from repro.launch.mesh import ProcessMesh

    pm = ProcessMesh.from_runtime()
    n = payload.get("rows", 16)
    rows = np.arange(n, dtype=np.float32) * 2.0
    s, e = pm.addressable_row_block(n)
    staged = pm.stage_global({"x": rows[s:e]}, n)
    gathered = gather_addressable(staged["x"])
    shards = sorted(
        (int(sh.index[0].start or 0), np.asarray(sh.data)) for sh in staged["x"].addressable_shards
    )
    return {
        "process_id": pm.process_id,
        "num_processes": pm.num_processes,
        "global_devices": len(jax.devices()),
        "local_devices": len(jax.local_devices()),
        "shard_process": pm.shard_process,
        "fingerprint": pm.fingerprint(),
        "row_block": pm.row_block(n),
        "staged_shape": tuple(staged["x"].shape),
        "staged_shards": shards,
        "fully_addressable": bool(staged["x"].is_fully_addressable),
        "gathered": gathered,
        "addressable_block": (s, e),
    }


# ---------------------------------------------------------------------------
# child main
# ---------------------------------------------------------------------------


def _child_main(argv):
    entry, pid, nproc, coord_port, jaxdist_port, result_port, devices = argv
    sys.path.insert(0, os.path.join(REPO, "src"))
    ctx = MHContext(pid, nproc, coord_port, jaxdist_port, devices)
    payload = pickle.loads(base64.b64decode(os.environ["REPRO_MH_PAYLOAD"]))
    if ":" in entry:
        mod_name, fn_name = entry.split(":", 1)
        import importlib

        fn = getattr(importlib.import_module(mod_name), fn_name)
    else:
        fn = globals()[entry]
    from multiprocessing.connection import Client

    try:
        value = fn(ctx, payload or {})
        status = ("ok", ctx.process_id, value)
    except BaseException:
        import traceback

        traceback.print_exc()  # the launcher surfaces child stderr too
        status = ("err", ctx.process_id, traceback.format_exc())
    conn = Client(("127.0.0.1", int(result_port)), authkey=_AUTH)
    conn.send(status)
    conn.close()
    if status[0] == "err":
        sys.exit(1)


if __name__ == "__main__":
    _child_main(sys.argv[1:])
