"""serve_step (one-token decode) latency per architecture family at smoke
scale — exercises each cache variant (GQA append / rolling window / MLA
latent / SSD state) end to end."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry

from .common import emit, time_fn


def run() -> None:
    for arch in ["codeqwen1_5_7b", "deepseek_v2_236b", "recurrentgemma_9b", "mamba2_780m"]:
        cfg = configs.get(arch).smoke()
        model = registry.build(cfg)
        params = model.init(0)
        B = 4
        cache = model.init_cache(B, 128)
        tok = jnp.zeros((B, 1), jnp.int32)
        step = jax.jit(model.decode_step, donate_argnums=(1,))
        logits, cache = step(params, cache, tok)  # compile

        def stepper(c):
            out, c2 = step(params, c, tok)
            return out

        # non-donating timing closure: rebuild cache each call is unfair;
        # time the jitted step with a fresh cache per iteration set
        import time

        times = []
        c = cache
        for _ in range(20):
            t0 = time.perf_counter()
            logits, c = step(params, c, tok)
            jax.block_until_ready(logits)
            times.append(time.perf_counter() - t0)
        times.sort()
        emit(f"decode_step_{arch}", times[len(times) // 2] * 1e6, f"batch={B} smoke-scale")
