"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from the dry-run
artifacts.  Usage: PYTHONPATH=src python -m benchmarks.report [dir]"""
from __future__ import annotations

import json
import pathlib
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath):
    rows = {}
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.loads(p.read_text())
        key = (r["arch"], r["shape"], r["mesh"])
        rows[key] = r
    return rows


def gib(b):
    return f"{b / 2**30:.2f}"


def render(dirpath="benchmarks/artifacts/dryrun") -> str:
    rows = load(dirpath)
    archs = sorted({k[0] for k in rows})
    out = []
    for mesh in ("16x16", "2x16x16"):
        out.append(f"\n#### Mesh {mesh} ({256 if mesh=='16x16' else 512} chips)\n")
        out.append(
            "| arch | shape | status | peak GiB/dev | HLO GFLOP/dev | coll GiB/dev "
            "| compute_s | memory_s | collective_s | dominant | useful | roofline frac |"
        )
        out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
        for a in archs:
            for sh in SHAPE_ORDER:
                r = rows.get((a, sh, mesh))
                if r is None:
                    continue
                if "skipped" in r:
                    out.append(f"| {a} | {sh} | SKIP (sub-quadratic-only shape) | — | — | — | — | — | — | — | — | — |")
                    continue
                if "error" in r:
                    out.append(f"| {a} | {sh} | ERROR | — | — | — | — | — | — | — | — | — |")
                    continue
                t = r["roofline"]
                out.append(
                    f"| {a} | {sh} | ok ({r['compile_s']:.0f}s compile) "
                    f"| {gib(r['memory']['peak_est_bytes_per_dev'])} "
                    f"| {r['cost']['flops_per_dev']/1e9:.0f} "
                    f"| {gib(r['collectives']['total_bytes_per_dev'])} "
                    f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
                    f"| {t['dominant'].replace('_s','')} | {t['useful_flops_ratio']:.2f} "
                    f"| {t['roofline_fraction']:.3f} |"
                )
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else "benchmarks/artifacts/dryrun"))
