"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from the dry-run
artifacts, and the longitudinal bench table from BENCH_preprocessing.json.

Usage: PYTHONPATH=src python -m benchmarks.report [dir-or-json]
(a ``.json`` path renders the bench trajectory; a directory renders the
dry-run roofline tables)."""
from __future__ import annotations

import json
import pathlib
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath):
    rows = {}
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.loads(p.read_text())
        key = (r["arch"], r["shape"], r["mesh"])
        rows[key] = r
    return rows


def gib(b):
    return f"{b / 2**30:.2f}"


def render(dirpath="benchmarks/artifacts/dryrun") -> str:
    rows = load(dirpath)
    archs = sorted({k[0] for k in rows})
    out = []
    for mesh in ("16x16", "2x16x16"):
        out.append(f"\n#### Mesh {mesh} ({256 if mesh=='16x16' else 512} chips)\n")
        out.append(
            "| arch | shape | status | peak GiB/dev | HLO GFLOP/dev | coll GiB/dev "
            "| compute_s | memory_s | collective_s | dominant | useful | roofline frac |"
        )
        out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
        for a in archs:
            for sh in SHAPE_ORDER:
                r = rows.get((a, sh, mesh))
                if r is None:
                    continue
                if "skipped" in r:
                    out.append(f"| {a} | {sh} | SKIP (sub-quadratic-only shape) | — | — | — | — | — | — | — | — | — |")
                    continue
                if "error" in r:
                    out.append(f"| {a} | {sh} | ERROR | — | — | — | — | — | — | — | — | — |")
                    continue
                t = r["roofline"]
                out.append(
                    f"| {a} | {sh} | ok ({r['compile_s']:.0f}s compile) "
                    f"| {gib(r['memory']['peak_est_bytes_per_dev'])} "
                    f"| {r['cost']['flops_per_dev']/1e9:.0f} "
                    f"| {gib(r['collectives']['total_bytes_per_dev'])} "
                    f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
                    f"| {t['dominant'].replace('_s','')} | {t['useful_flops_ratio']:.2f} "
                    f"| {t['roofline_fraction']:.3f} |"
                )
    return "\n".join(out)


def render_bench(path="BENCH_preprocessing.json", flag_pct: float = 10.0) -> str:
    """Longitudinal bench table from run.py --smoke's appended record.

    Since the record became append-only (rows tagged with a ``run`` id) a
    naive per-name table silently mixed measurements from different runs.
    Rows are grouped by run id first; the table compares the LATEST run
    against run 0 (the recorded baseline) per row name and flags any
    latency regression above ``flag_pct`` percent."""
    rows = json.loads(pathlib.Path(path).read_text())
    by_run: dict = {}
    for r in rows:
        by_run.setdefault(int(r.get("run", 0)), {})[r["name"]] = r
    if not by_run:
        return "(no bench rows recorded)"
    runs = sorted(by_run)
    base_id, latest_id = runs[0], runs[-1]
    base, latest = by_run[base_id], by_run[latest_id]

    out = [
        f"\n#### Bench trajectory: run {latest_id} ({len(runs)} runs recorded) "
        f"vs run {base_id}\n",
        "| name | run0 us | latest us | delta | flag | derived (latest) |",
        "|---|---|---|---|---|---|",
    ]
    flagged = []
    for name in sorted(latest):
        cur = latest[name]
        ref = base.get(name)
        if ref is None or not ref["us_per_call"]:
            delta, flag = "new", ""
        else:
            pct = 100.0 * (cur["us_per_call"] / ref["us_per_call"] - 1.0)
            delta = f"{pct:+.1f}%"
            flag = f"REGRESSION(>{flag_pct:.0f}%)" if pct > flag_pct else ""
            if flag:
                flagged.append(name)
        ref_us = f"{ref['us_per_call']:.1f}" if ref is not None else "—"
        out.append(
            f"| {name} | {ref_us} | {cur['us_per_call']:.1f} | {delta} "
            f"| {flag} | {cur.get('derived', '')} |"
        )
    only_base = sorted(set(base) - set(latest))
    if only_base:
        out.append(f"\n(rows present in run {base_id} but gone in run {latest_id}: "
                   + ", ".join(only_base) + ")")
    if flagged:
        out.append(f"\n{len(flagged)} row(s) regressed >{flag_pct:.0f}%: "
                   + ", ".join(flagged))
    return "\n".join(out)


def main(argv) -> str:
    target = argv[1] if len(argv) > 1 else "benchmarks/artifacts/dryrun"
    if target.endswith(".json"):
        return render_bench(target)
    return render(target)


if __name__ == "__main__":
    print(main(sys.argv))
