"""Paper §3 analogue: fused vs unfused serving latency, plus the
compile-once planner comparison.

The paper reports a 61% serving-latency reduction after replacing the
pipeline-interpreting runtime (MLeap) with a fused Keras bundle.  Here the
same comparison is: exported PreprocessModel + ranking head compiled as ONE
XLA program (fused) vs preprocessing-program-then-model-program with a host
round-trip between them (the MLeap-shaped baseline), plus a per-stage
interpreted mode (dispatching each pipeline stage as its own XLA call —
closest to how a pipeline interpreter executes).

A second block measures the transform path in isolation:

  pre_interpreted   per-stage jitted dispatch (pipeline-interpreter shape)
  pre_naive_jit     jax.jit over the whole interpreting loop (re-traces the
                    interpreter; XLA must CSE duplicate coercions/hashes)
  pre_planned       TransformPlan: liveness + coercion/hash CSE + persistent
                    jit cache (repro.core.plan)

with trace-time and HLO-op-count deltas between the naive jit and the
planned graph — the "cheap to trace, small to compile" claim made concrete.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.plan import hlo_op_count
from repro.data import ltr_rows
from repro.serve import FusedModel

from .common import emit, time_fn
from repro.apps.ltr_pipeline import build_ltr_pipeline


def _ranking_head(feature_names):
    rng = np.random.default_rng(0)

    def init(dim):
        return {
            "w1": jnp.asarray(rng.normal(0, 0.1, (dim, 64)), jnp.float32),
            "w2": jnp.asarray(rng.normal(0, 0.1, (64, 1)), jnp.float32),
        }

    def fwd(params, feats):
        x = jnp.concatenate(
            [feats[n][..., None] if feats[n].ndim == 2 else feats[n] for n in feature_names],
            axis=-1,
        ).astype(jnp.float32)
        h = jax.nn.relu(jnp.einsum("qlf,fh->qlh", x, params["w1"]))
        return jnp.einsum("qlh,ho->qlo", h, params["w2"])[..., 0]

    return init, fwd


def run(smoke: bool = False) -> None:
    rows = 64 if smoke else 512
    train = ltr_rows(rows, seed=0)
    fitted, out_cols = build_ltr_pipeline(train)
    export = fitted.export(outputs=out_cols)
    init, fwd = _ranking_head(out_cols)
    dim = len(out_cols)
    params = init(dim)
    fm = FusedModel(export, fwd, params)

    for bs, tag in [(1, "b1"), (64, "b64")]:
        req = {k: v[:bs] for k, v in ltr_rows(max(bs, 2), seed=9).items()}
        req.pop("label_click")

        t_fused = time_fn(fm, req)
        t_unfused = time_fn(fm.call_unfused, req)

        # per-stage interpreted baseline (pipeline-interpreter shape)
        stages = [jax.jit(s.transform) for s in fitted.stages]
        model_j = jax.jit(fwd)

        def interpreted(r):
            b = dict(r)
            for s in stages:
                b = s(b)
            return model_j(params, b)

        t_interp = time_fn(interpreted, req)
        red_vs_unfused = 100 * (1 - t_fused / t_unfused)
        red_vs_interp = 100 * (1 - t_fused / t_interp)
        emit(f"serve_fused_{tag}", t_fused, f"baseline")
        emit(f"serve_unfused_{tag}", t_unfused, f"fused_saves={red_vs_unfused:.0f}%")
        emit(
            f"serve_interpreted_{tag}",
            t_interp,
            f"fused_saves={red_vs_interp:.0f}% (paper reports 61% vs MLeap)",
        )

    _run_planner_comparison(fitted, smoke=smoke)


def _run_planner_comparison(fitted, smoke: bool = False) -> None:
    """Planned vs interpreted vs naive whole-pipeline jit on the transform
    path, plus trace-time / HLO-op-count metrics for the compile story."""
    bs = 16 if smoke else 64
    batch = {k: v[:bs] for k, v in ltr_rows(max(bs, 2), seed=11).items()}
    batch.pop("label_click")
    iters = 5 if smoke else 20

    # per-stage interpreted: one jitted XLA call per stage, dict rebuilt on
    # the host between stages (the MLeap execution shape)
    stage_fns = [jax.jit(s.transform) for s in fitted.stages]

    def interpreted(b):
        out = dict(b)
        for f in stage_fns:
            out = f(out)
        return out

    naive = jax.jit(fitted.transform)
    plan = fitted.plan()

    t_interp = time_fn(interpreted, batch, iters=iters)
    t_naive = time_fn(naive, batch, iters=iters)
    t_planned = time_fn(plan, batch, iters=iters)

    speedup = t_interp / t_planned
    emit(f"pre_interpreted_b{bs}", t_interp, "per-stage dispatch baseline")
    emit(f"pre_naive_jit_b{bs}", t_naive, f"vs_interpreted={t_interp / t_naive:.2f}x")
    emit(
        f"pre_planned_b{bs}",
        t_planned,
        f"vs_interpreted={speedup:.2f}x vs_naive_jit={t_naive / t_planned:.2f}x "
        f"hash_shared={plan.cse_stats['hash_shared']} "
        f"coerce_shared={plan.cse_stats['coerce_shared']}",
    )

    # trace time + HLO op count: fresh wrappers so nothing is pre-traced
    t0 = time.perf_counter()
    low_naive = jax.jit(fitted.transform).lower(batch)
    trace_naive = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    low_planned = plan.lower(batch)
    trace_planned = (time.perf_counter() - t0) * 1e6
    ops_naive = hlo_op_count(low_naive)
    ops_planned = hlo_op_count(low_planned)
    emit("pre_trace_naive_jit", trace_naive, f"hlo_ops={ops_naive}")
    emit(
        "pre_trace_planned",
        trace_planned,
        f"hlo_ops={ops_planned} trace_speedup={trace_naive / trace_planned:.2f}x "
        f"hlo_ops_saved={ops_naive - ops_planned}",
    )
