"""Paper §3 analogue: fused vs unfused serving latency.

The paper reports a 61% serving-latency reduction after replacing the
pipeline-interpreting runtime (MLeap) with a fused Keras bundle.  Here the
same comparison is: exported PreprocessModel + ranking head compiled as ONE
XLA program (fused) vs preprocessing-program-then-model-program with a host
round-trip between them (the MLeap-shaped baseline), plus a per-stage
interpreted mode (dispatching each pipeline stage as its own XLA call —
closest to how a pipeline interpreter executes).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.data import ltr_rows
from repro.serve import FusedModel

from .common import emit, time_fn
from repro.apps.ltr_pipeline import build_ltr_pipeline


def _ranking_head(feature_names):
    rng = np.random.default_rng(0)

    def init(dim):
        return {
            "w1": jnp.asarray(rng.normal(0, 0.1, (dim, 64)), jnp.float32),
            "w2": jnp.asarray(rng.normal(0, 0.1, (64, 1)), jnp.float32),
        }

    def fwd(params, feats):
        x = jnp.concatenate(
            [feats[n][..., None] if feats[n].ndim == 2 else feats[n] for n in feature_names],
            axis=-1,
        ).astype(jnp.float32)
        h = jax.nn.relu(jnp.einsum("qlf,fh->qlh", x, params["w1"]))
        return jnp.einsum("qlh,ho->qlo", h, params["w2"])[..., 0]

    return init, fwd


def run() -> None:
    train = ltr_rows(512, seed=0)
    fitted, out_cols = build_ltr_pipeline(train)
    export = fitted.export(outputs=out_cols)
    init, fwd = _ranking_head(out_cols)
    dim = len(out_cols)
    params = init(dim)
    fm = FusedModel(export, fwd, params)

    for bs, tag in [(1, "b1"), (64, "b64")]:
        req = {k: v[:bs] for k, v in ltr_rows(max(bs, 2), seed=9).items()}
        req.pop("label_click")

        t_fused = time_fn(fm, req)
        t_unfused = time_fn(fm.call_unfused, req)

        # per-stage interpreted baseline (pipeline-interpreter shape)
        stages = [jax.jit(s.transform) for s in fitted.stages]
        model_j = jax.jit(fwd)

        def interpreted(r):
            b = dict(r)
            for s in stages:
                b = s(b)
            return model_j(params, b)

        t_interp = time_fn(interpreted, req)
        red_vs_unfused = 100 * (1 - t_fused / t_unfused)
        red_vs_interp = 100 * (1 - t_fused / t_interp)
        emit(f"serve_fused_{tag}", t_fused, f"baseline")
        emit(f"serve_unfused_{tag}", t_unfused, f"fused_saves={red_vs_unfused:.0f}%")
        emit(
            f"serve_interpreted_{tag}",
            t_interp,
            f"fused_saves={red_vs_interp:.0f}% (paper reports 61% vs MLeap)",
        )
