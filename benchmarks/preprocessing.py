"""Paper §3 analogue: fused vs unfused serving latency, plus the
compile-once planner comparison.

The paper reports a 61% serving-latency reduction after replacing the
pipeline-interpreting runtime (MLeap) with a fused Keras bundle.  Here the
same comparison is: exported PreprocessModel + ranking head compiled as ONE
XLA program (fused) vs preprocessing-program-then-model-program with a host
round-trip between them (the MLeap-shaped baseline), plus a per-stage
interpreted mode (dispatching each pipeline stage as its own XLA call —
closest to how a pipeline interpreter executes).

A second block measures the transform path in isolation:

  pre_interpreted   per-stage jitted dispatch (pipeline-interpreter shape)
  pre_naive_jit     jax.jit over the whole interpreting loop (re-traces the
                    interpreter; XLA must CSE duplicate coercions/hashes)
  pre_planned       TransformPlan: liveness + coercion/hash CSE + persistent
                    jit cache (repro.core.plan)

with trace-time and HLO-op-count deltas between the naive jit and the
planned graph — the "cheap to trace, small to compile" claim made concrete.

A third block measures the offline streaming path: a whole epoch of batches
through the per-batch ``transform_jit`` loop (stage, dispatch, block — every
batch) vs the :class:`~repro.core.runner.PlanRunner` streaming executor
(packed superbatches, double-buffered staging, donated buffers), reported as
rows/s; plus the FusedModel serve path with buffer donation off vs on.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.plan import hlo_op_count
from repro.core.runner import PlanRunner
from repro.data import ltr_rows
from repro.serve import FusedModel

from .common import emit, time_fn
from repro.apps.ltr_pipeline import build_ltr_pipeline


def _ranking_head(feature_names):
    rng = np.random.default_rng(0)

    def init(dim):
        return {
            "w1": jnp.asarray(rng.normal(0, 0.1, (dim, 64)), jnp.float32),
            "w2": jnp.asarray(rng.normal(0, 0.1, (64, 1)), jnp.float32),
        }

    def fwd(params, feats):
        x = jnp.concatenate(
            [feats[n][..., None] if feats[n].ndim == 2 else feats[n] for n in feature_names],
            axis=-1,
        ).astype(jnp.float32)
        h = jax.nn.relu(jnp.einsum("qlf,fh->qlh", x, params["w1"]))
        return jnp.einsum("qlh,ho->qlo", h, params["w2"])[..., 0]

    return init, fwd


def run(smoke: bool = False) -> None:
    rows = 64 if smoke else 512
    train = ltr_rows(rows, seed=0)
    fitted, out_cols = build_ltr_pipeline(train)
    # streaming first: it is the most allocation-sensitive measurement, so it
    # runs before the serve sections grow the live heap
    _run_streaming_comparison(fitted, out_cols, smoke=smoke)

    export = fitted.export(outputs=out_cols)
    init, fwd = _ranking_head(out_cols)
    dim = len(out_cols)
    params = init(dim)
    # donate=False here: time_fn re-submits the SAME request arrays, which
    # donation would invalidate; the donate win is measured separately below
    # with a fresh request per call.
    fm = FusedModel(export, fwd, params, donate=False)

    for bs, tag in [(1, "b1"), (64, "b64")]:
        req = {k: v[:bs] for k, v in ltr_rows(max(bs, 2), seed=9).items()}
        req.pop("label_click")

        t_fused = time_fn(fm, req)
        t_unfused = time_fn(fm.call_unfused, req)

        # per-stage interpreted baseline (pipeline-interpreter shape)
        stages = [jax.jit(s.transform) for s in fitted.stages]
        model_j = jax.jit(fwd)

        def interpreted(r):
            b = dict(r)
            for s in stages:
                b = s(b)
            return model_j(params, b)

        t_interp = time_fn(interpreted, req)
        red_vs_unfused = 100 * (1 - t_fused / t_unfused)
        red_vs_interp = 100 * (1 - t_fused / t_interp)
        emit(f"serve_fused_{tag}", t_fused, f"baseline")
        emit(f"serve_unfused_{tag}", t_unfused, f"fused_saves={red_vs_unfused:.0f}%")
        emit(
            f"serve_interpreted_{tag}",
            t_interp,
            f"fused_saves={red_vs_interp:.0f}% (paper reports 61% vs MLeap)",
        )

    _run_donation_comparison(export, fwd, params, smoke=smoke)
    _run_planner_comparison(fitted, smoke=smoke)


def _run_donation_comparison(export, fwd, params, smoke: bool = False) -> None:
    """FusedModel serve path with buffer donation off vs on (the ROADMAP
    "donation by default" flip, measured).  Each call stages a FRESH request
    batch — the MicroBatcher's behaviour, and the reason donation is safe as
    the serve default."""
    bs = 64
    iters = 10 if smoke else 30
    base = {k: np.asarray(v[:bs]) for k, v in ltr_rows(max(bs, 2), seed=21).items()}
    base.pop("label_click")

    variants = [
        ("off", FusedModel(export, fwd, params, donate=False)),
        ("on", FusedModel(export, fwd, params, donate=True)),
    ]
    results = {}
    for tag, fm in variants:
        for _ in range(3):  # warmup (compile)
            jax.block_until_ready(fm({k: jnp.asarray(v) for k, v in base.items()}))
        times = []
        for _ in range(iters):
            req = {k: jnp.asarray(v) for k, v in base.items()}  # fresh buffers
            t0 = time.perf_counter()
            out = fm(req)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
        times.sort()
        results[tag] = times[len(times) // 2] * 1e6

    saved = 100 * (1 - results["on"] / results["off"])
    emit(f"serve_donate_off_b{bs}", results["off"], "fresh request per call")
    emit(f"serve_donate_on_b{bs}", results["on"], f"donate_saves={saved:.0f}% (serve default)")


def _run_streaming_comparison(fitted, sweep_cols, smoke: bool = False) -> None:
    """Offline epoch throughput: per-batch transform_jit loop vs the
    PlanRunner streaming executor, single-device and (devices permitting)
    mesh-sharded.  Four lines so the comparison is transparent:

      stream_perbatch       transform_jit loop (full env), block per batch
      stream_runner         PlanRunner, same full-env plan (orchestration
                            only: prefetch + workers + donation)
      stream_runner_sweep   PlanRunner on the outputs-pruned plan with
                            packing and host materialization — the actual
                            offline feature sweep; the acceptance target
                            (>=2x per-batch at b>=64, CPU) compares this
                            against stream_perbatch
      stream_sharded        the SAME TransformPlan driven through a mesh

    Rows/s counts leading-dim rows."""
    bs = 64
    nb = 32 if smoke else 48
    host_batches = []
    for i in range(nb):
        b = {k: np.asarray(v) for k, v in ltr_rows(bs, seed=100 + i).items()}
        b.pop("label_click")
        host_batches.append(b)
    rows_total = bs * nb

    # pipeline fit + compilation leave a large live-object graph; freeze it
    # out of GC so collector pauses triggered by the streaming loops don't
    # rescan it every generation (unfrozen in the finally below even if a
    # section raises — later benchmarks must not run with a frozen heap)
    import gc

    gc.collect()
    gc.freeze()
    try:
        _streaming_body(fitted, sweep_cols, bs, nb, host_batches, rows_total)
    finally:
        gc.unfreeze()


def _streaming_body(fitted, sweep_cols, bs, nb, host_batches, rows_total) -> None:
    plan = fitted.plan()
    plan_sweep = fitted.plan(outputs=sweep_cols)

    def median_epoch(run_epoch, reps: int = 5) -> float:
        """Median wall time of a full epoch pass (the first, untimed pass is
        the compile warmup for every signature involved)."""
        run_epoch()
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            run_epoch()
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    def perbatch_epoch():
        for b in host_batches:
            out = fitted.transform_jit({k: jnp.asarray(v) for k, v in b.items()})
            jax.block_until_ready(out)

    t_perbatch = median_epoch(perbatch_epoch)
    rps_perbatch = rows_total / t_perbatch
    emit(
        f"stream_perbatch_b{bs}",
        1e6 * t_perbatch / nb,
        f"rows_per_s={rps_perbatch:.0f}",
    )

    def timed_stream(runner):
        def epoch():
            n_out = sum(1 for _ in runner.run(iter(host_batches)))
            assert n_out == nb

        return median_epoch(epoch)

    t_stream = timed_stream(
        PlanRunner(plan, donate=True, pack=1, prefetch=2, workers=1)
    )
    rps_stream = rows_total / t_stream
    emit(
        f"stream_runner_b{bs}",
        1e6 * t_stream / nb,
        f"rows_per_s={rps_stream:.0f} vs_perbatch={rps_stream / rps_perbatch:.2f}x "
        f"(full env, pipelining only)",
    )

    t_sweep = timed_stream(
        PlanRunner(plan_sweep, donate=True, pack=8, prefetch=2, materialize="host")
    )
    rps_sweep = rows_total / t_sweep
    emit(
        f"stream_runner_sweep_b{bs}",
        1e6 * t_sweep / nb,
        f"rows_per_s={rps_sweep:.0f} vs_perbatch={rps_sweep / rps_perbatch:.2f}x "
        f"pack=8 outputs={len(sweep_cols)} (target >=2x)",
    )

    if len(jax.devices()) > 1:
        from repro.core import Engine
        from repro.launch.mesh import make_host_mesh, use_mesh

        mesh = make_host_mesh(data=len(jax.devices()))
        eng = Engine(mesh)
        with use_mesh(mesh):
            t_sh = timed_stream(
                PlanRunner(plan_sweep, engine=eng, donate=True, pack=8, prefetch=2)
            )
        emit(
            f"stream_sharded_b{bs}",
            1e6 * t_sh / nb,
            f"rows_per_s={rows_total / t_sh:.0f} mesh_devices={len(jax.devices())} "
            f"jit_cache={plan_sweep.stats['jit_cache_entries']}",
        )
    else:
        emit("stream_sharded_b64", 0.0, "skipped: 1 device (see tests/test_runner.py)")


def _run_planner_comparison(fitted, smoke: bool = False) -> None:
    """Planned vs interpreted vs naive whole-pipeline jit on the transform
    path, plus trace-time / HLO-op-count metrics for the compile story.

    The planned-vs-fused pair (``pre_planned_b*`` vs ``pre_fused_b*``) is
    measured at BOTH b16 and b64 in every mode: ``run.py --smoke`` enforces
    that the fused rows exist and are not slower than the staged plan, so a
    fusion regression fails CI instead of silently shipping."""
    bs_main = 16 if smoke else 64
    iters = 5 if smoke else 20

    # staged baseline pinned to fuse=False — fitted.plan() now fuses by
    # default, and the point of this block is the fusion delta itself
    plan = fitted.plan(fuse=False)
    plan_fused = fitted.plan(fuse=True)

    batch_main = {k: v[:bs_main] for k, v in ltr_rows(max(bs_main, 2), seed=11).items()}
    batch_main.pop("label_click")

    # per-stage interpreted: one jitted XLA call per stage, dict rebuilt on
    # the host between stages (the MLeap execution shape)
    stage_fns = [jax.jit(s.transform) for s in fitted.stages]

    def interpreted(b):
        out = dict(b)
        for f in stage_fns:
            out = f(out)
        return out

    naive = jax.jit(fitted.transform)
    t_interp = time_fn(interpreted, batch_main, iters=iters)
    t_naive = time_fn(naive, batch_main, iters=iters)
    emit(f"pre_interpreted_b{bs_main}", t_interp, "per-stage dispatch baseline")
    emit(
        f"pre_naive_jit_b{bs_main}", t_naive, f"vs_interpreted={t_interp / t_naive:.2f}x"
    )

    # fused-chain static metrics + HLO op delta (fused chains collapse stage
    # boundaries, so the lowered program shrinks) — measured once at b64
    hlo_batch = {k: v[:64] for k, v in ltr_rows(64, seed=11).items()}
    hlo_batch.pop("label_click")
    ops_planned_hlo = hlo_op_count(plan.lower(hlo_batch))
    ops_fused_hlo = hlo_op_count(plan_fused.lower(hlo_batch))
    fstats = plan_fused.fusion_stats

    for bs in (16, 64):
        batch = {k: v[:bs] for k, v in ltr_rows(max(bs, 2), seed=11).items()}
        batch.pop("label_click")
        t_planned = time_fn(plan, batch, iters=iters)
        t_fused = time_fn(plan_fused, batch, iters=iters)
        derived = f"vs_naive_jit={t_naive / t_planned:.2f}x " if bs == bs_main else ""
        if bs == bs_main:
            derived = (
                f"vs_interpreted={t_interp / t_planned:.2f}x " + derived
            )
        emit(
            f"pre_planned_b{bs}",
            t_planned,
            derived
            + f"hash_shared={plan.cse_stats['hash_shared']} "
            f"coerce_shared={plan.cse_stats['coerce_shared']}",
        )
        emit(
            f"pre_fused_b{bs}",
            t_fused,
            f"vs_planned={t_planned / t_fused:.2f}x "
            f"fused_chains={fstats['fused_chains']} "
            f"fused_stages={fstats['fused_stages']} "
            f"hlo_ops_delta={ops_planned_hlo - ops_fused_hlo}",
        )

    # trace time + HLO op count: fresh wrappers so nothing is pre-traced
    t0 = time.perf_counter()
    low_naive = jax.jit(fitted.transform).lower(batch_main)
    trace_naive = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    low_planned = plan.lower(batch_main)
    trace_planned = (time.perf_counter() - t0) * 1e6
    ops_naive = hlo_op_count(low_naive)
    ops_planned = hlo_op_count(low_planned)
    emit("pre_trace_naive_jit", trace_naive, f"hlo_ops={ops_naive}")
    emit(
        "pre_trace_planned",
        trace_planned,
        f"hlo_ops={ops_planned} trace_speedup={trace_naive / trace_planned:.2f}x "
        f"hlo_ops_saved={ops_naive - ops_planned}",
    )
