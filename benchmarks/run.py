# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   preprocessing    — paper §3: fused vs unfused vs interpreted serve latency
#                      + planned-vs-interpreted-vs-naive-jit transform path
#   serving          — ServingGateway under open-loop load: p50/p99,
#                      throughput, shed rate at fixed arrival rates
#   indexing         — paper §2: string/hash/bloom indexing variants
#   fit_throughput   — Spark-role streaming fit + transform throughput
#   decode           — serve_step latency for the LM substrate (smoke scale)
#   roofline         — dry-run-derived roofline terms per (arch, shape, mesh)
#
# ``--smoke`` runs the preprocessing comparison (including the streaming
# rows/s metrics) at tiny sizes and writes the collected rows to
# BENCH_preprocessing.json — cheap enough for CI, so the perf trajectory
# (planned vs interpreted, streamed vs per-batch, trace time, HLO op count)
# is recorded on every PR.  A benchmark that raises fails the run loudly
# (full traceback + non-zero exit) — never a silent skip.
import argparse
import json
import pathlib
import sys
import time
import traceback


def _write_json(path: str) -> None:
    """Append this run's rows to the longitudinal record.

    The file holds EVERY recorded run (rows tagged with a monotonically
    increasing ``run`` id; pre-longitudinal rows read as run 0), so the
    bench trajectory across PRs lives in the repo instead of being
    overwritten each time.  Consumers wanting only the latest run filter on
    ``max(run)``."""
    from . import common

    p = pathlib.Path(path)
    history: list = []
    if p.exists():
        try:
            history = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            print(f"warning: could not parse {path}; starting fresh", file=sys.stderr)
    run_id = max((r.get("run", 0) for r in history), default=-1) + 1
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    rows = [dict(r, run=run_id, ts=stamp) for r in common.RESULTS]
    p.write_text(json.dumps(history + rows, indent=2) + "\n")
    print(
        f"wrote {path} (+{len(rows)} rows as run {run_id}; "
        f"{len(history) + len(rows)} total)",
        file=sys.stderr,
    )


def _loud(name: str, fn, failures: list, **kwargs) -> None:
    try:
        fn(**kwargs)
    except Exception:
        print(f"\nBENCHMARK FAILED: {name}", file=sys.stderr)
        traceback.print_exc()
        failures.append(name)


def _run_analyze(failures: list) -> None:
    """``python -m repro.analyze --strict`` as a CI gate: the smoke run
    fails loudly on any error-severity finding (plan skew, fusion
    illegality, lock misuse, unregistered knob), and the finding counts are
    recorded as the ``analyze_repo_clean`` row — wall time as us_per_call,
    counts in ``derived`` — so the analyzer's own cost and the suppressed-
    site inventory trend in BENCH_preprocessing.json alongside the perf
    rows."""
    import os
    import subprocess
    import tempfile

    from . import common

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        report_path = tf.name
    try:
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analyze", "--strict", "--json", report_path],
            env=env, cwd=str(root), capture_output=True, text=True, timeout=600,
        )
        wall_us = (time.perf_counter() - t0) * 1e6
        try:
            rep = json.loads(pathlib.Path(report_path).read_text())
        except (OSError, json.JSONDecodeError):
            rep = {"errors": -1, "warnings": -1, "suppressed": -1}
        common.emit(
            "analyze_repo_clean",
            wall_us,
            f"errors={rep['errors']} warnings={rep['warnings']} "
            f"suppressed={rep['suppressed']} exit={proc.returncode}",
        )
        if proc.returncode != 0:
            print("\nBENCHMARK FAILED: analyze --strict found errors:", file=sys.stderr)
            print(proc.stdout, file=sys.stderr)
            print(proc.stderr, file=sys.stderr)
            failures.append("analyze-strict")
    finally:
        try:
            pathlib.Path(report_path).unlink()
        except OSError:
            pass


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes, preprocessing table only, write BENCH_preprocessing.json",
    )
    ap.add_argument(
        "--json",
        default="BENCH_preprocessing.json",
        help="output path for the JSON record (written in --smoke mode)",
    )
    args = ap.parse_args()

    from . import preprocessing

    failures: list = []
    print("name,us_per_call,derived")
    from . import serving

    if args.smoke:
        _loud("preprocessing", preprocessing.run, failures, smoke=True)
        # short CPU-only gateway load run: seconds, and loud on
        # regression-shaped output (zero completed / all shed / cost-model
        # hit-rate below the launch-time-only baseline)
        _loud("serving", serving.run, failures, smoke=True)
        # 2-process fake-device multi-host smoke: per-host shard-fed stream
        # (bit-identity cross-checked in the bench itself) + routed gateway
        from . import multihost

        _loud("multihost", multihost.run, failures, smoke=True)
        # static analysis is part of the smoke gate: a skewed plan or a new
        # lock misuse fails CI exactly like a perf regression
        _run_analyze(failures)
        # the cost-aware and multi-host rows are the record of the
        # finish-time-feasibility and cross-process guarantees; a refactor
        # that silently stops emitting them must fail CI, mirroring the
        # serve_gw_* guard inside serving.py
        from . import common

        names = {r["name"] for r in common.RESULTS}
        for prefix in (
            "serve_gw_p50",
            "serve_cost_hitrate",
            "serve_cost_shedprec",
            "stream_mh_",
            "serve_mh_",
            "serve_ft_",
            "mh_transport_",
            "analyze_repo_clean",
        ):
            if not any(n.startswith(prefix) for n in names):
                print(f"\nBENCHMARK FAILED: no {prefix}* row emitted", file=sys.stderr)
                failures.append(f"missing-{prefix.rstrip('_')}")
        # fused-chain guard: the pre_fused_* rows must exist AND must not be
        # slower than the staged plan (10% tolerance absorbs CI timer noise;
        # the acceptance intent is fused rows/s >= planned rows/s)
        by_name = {r["name"]: r for r in common.RESULTS}
        for bs in ("b16", "b64"):
            fused = by_name.get(f"pre_fused_{bs}")
            planned = by_name.get(f"pre_planned_{bs}")
            if fused is None or planned is None:
                print(f"\nBENCHMARK FAILED: pre_fused_{bs} row missing", file=sys.stderr)
                failures.append(f"missing-pre_fused_{bs}")
            elif fused["us_per_call"] > planned["us_per_call"] * 1.10:
                print(
                    f"\nBENCHMARK FAILED: pre_fused_{bs} "
                    f"({fused['us_per_call']}us) slower than "
                    f"pre_planned_{bs} ({planned['us_per_call']}us)",
                    file=sys.stderr,
                )
                failures.append(f"pre_fused_{bs}-regression")
        # shm-transport guard: the zero-copy data plane must never lose to
        # inline pickle on the wide batch it exists for (the acceptance
        # target is >=2x; the CI floor is parity, absorbing timer noise on
        # loaded runners — the row's derived field records the real ratio)
        shm = by_name.get("mh_transport_shm_wide")
        pickled = by_name.get("mh_transport_pickle_wide")
        if shm is None or pickled is None:
            print(
                "\nBENCHMARK FAILED: mh_transport_{shm,pickle}_wide row missing",
                file=sys.stderr,
            )
            failures.append("missing-mh_transport_wide")
        elif shm["us_per_call"] > pickled["us_per_call"]:
            print(
                f"\nBENCHMARK FAILED: shm transport ({shm['us_per_call']}us) "
                f"slower than pickle ({pickled['us_per_call']}us) on the wide "
                f"batch",
                file=sys.stderr,
            )
            failures.append("mh_transport_shm-regression")
        # observability must stay cheap enough to be on by default: the
        # serving benchmark measures tracing on vs off at equal load and
        # this guard fails the run if the row is missing or the overhead
        # exceeds 5% (the obs acceptance bound)
        obs_row = by_name.get("serve_obs_overhead_pct")
        if obs_row is None:
            print(
                "\nBENCHMARK FAILED: serve_obs_overhead_pct row missing",
                file=sys.stderr,
            )
            failures.append("missing-serve_obs_overhead_pct")
        elif obs_row["us_per_call"] > 5.0:
            print(
                f"\nBENCHMARK FAILED: tracing overhead "
                f"{obs_row['us_per_call']}% > 5% ({obs_row['derived']})",
                file=sys.stderr,
            )
            failures.append("obs-overhead-regression")
        _write_json(args.json)  # partial rows still recorded on failure
        if failures:
            sys.exit(f"benchmark(s) failed: {', '.join(failures)}")
        return

    from . import fit_throughput, indexing, roofline

    _loud("preprocessing", preprocessing.run, failures)
    _loud("serving", serving.run, failures)

    from . import multihost

    _loud("multihost", multihost.run, failures)
    _loud("indexing", indexing.run, failures)
    _loud("fit_throughput", fit_throughput.run, failures)

    def _decode():
        from . import decode

        decode.run()

    _loud("decode", _decode, failures)
    _loud("roofline", roofline.run, failures)
    # NB: no JSON here — BENCH_preprocessing.json is the smoke-mode record
    # CI trends on; a full run's mixed tables would not be comparable.
    if failures:
        sys.exit(f"benchmark(s) failed: {', '.join(failures)}")


if __name__ == "__main__":
    main()
