# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   preprocessing    — paper §3: fused vs unfused vs interpreted serve latency
#                      + planned-vs-interpreted-vs-naive-jit transform path
#   serving          — ServingGateway under open-loop load: p50/p99,
#                      throughput, shed rate at fixed arrival rates
#   indexing         — paper §2: string/hash/bloom indexing variants
#   fit_throughput   — Spark-role streaming fit + transform throughput
#   decode           — serve_step latency for the LM substrate (smoke scale)
#   roofline         — dry-run-derived roofline terms per (arch, shape, mesh)
#
# ``--smoke`` runs the preprocessing comparison (including the streaming
# rows/s metrics) at tiny sizes and writes the collected rows to
# BENCH_preprocessing.json — cheap enough for CI, so the perf trajectory
# (planned vs interpreted, streamed vs per-batch, trace time, HLO op count)
# is recorded on every PR.  A benchmark that raises fails the run loudly
# (full traceback + non-zero exit) — never a silent skip.
import argparse
import json
import pathlib
import sys
import traceback


def _write_json(path: str) -> None:
    from . import common

    pathlib.Path(path).write_text(json.dumps(common.RESULTS, indent=2) + "\n")
    print(f"wrote {path} ({len(common.RESULTS)} rows)", file=sys.stderr)


def _loud(name: str, fn, failures: list, **kwargs) -> None:
    try:
        fn(**kwargs)
    except Exception:
        print(f"\nBENCHMARK FAILED: {name}", file=sys.stderr)
        traceback.print_exc()
        failures.append(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes, preprocessing table only, write BENCH_preprocessing.json",
    )
    ap.add_argument(
        "--json",
        default="BENCH_preprocessing.json",
        help="output path for the JSON record (written in --smoke mode)",
    )
    args = ap.parse_args()

    from . import preprocessing

    failures: list = []
    print("name,us_per_call,derived")
    from . import serving

    if args.smoke:
        _loud("preprocessing", preprocessing.run, failures, smoke=True)
        # short CPU-only gateway load run: seconds, and loud on
        # regression-shaped output (zero completed / all shed / cost-model
        # hit-rate below the launch-time-only baseline)
        _loud("serving", serving.run, failures, smoke=True)
        # the cost-aware rows are the record of the finish-time-feasibility
        # guarantee; a refactor that silently stops emitting them must fail
        # CI, mirroring the serve_gw_* guard inside serving.py
        from . import common

        names = {r["name"] for r in common.RESULTS}
        for prefix in ("serve_gw_p50", "serve_cost_hitrate", "serve_cost_shedprec"):
            if not any(n.startswith(prefix) for n in names):
                print(f"\nBENCHMARK FAILED: no {prefix}_* row emitted", file=sys.stderr)
                failures.append(f"missing-{prefix}")
        _write_json(args.json)  # partial rows still recorded on failure
        if failures:
            sys.exit(f"benchmark(s) failed: {', '.join(failures)}")
        return

    from . import fit_throughput, indexing, roofline

    _loud("preprocessing", preprocessing.run, failures)
    _loud("serving", serving.run, failures)
    _loud("indexing", indexing.run, failures)
    _loud("fit_throughput", fit_throughput.run, failures)

    def _decode():
        from . import decode

        decode.run()

    _loud("decode", _decode, failures)
    _loud("roofline", roofline.run, failures)
    # NB: no JSON here — BENCH_preprocessing.json is the smoke-mode record
    # CI trends on; a full run's mixed tables would not be comparable.
    if failures:
        sys.exit(f"benchmark(s) failed: {', '.join(failures)}")


if __name__ == "__main__":
    main()
