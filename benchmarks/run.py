# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   preprocessing    — paper §3: fused vs unfused vs interpreted serve latency
#   indexing         — paper §2: string/hash/bloom indexing variants
#   fit_throughput   — Spark-role streaming fit + transform throughput
#   decode           — serve_step latency for the LM substrate (smoke scale)
#   roofline         — dry-run-derived roofline terms per (arch, shape, mesh)
import sys


def main() -> None:
    from . import fit_throughput, indexing, preprocessing, roofline

    print("name,us_per_call,derived")
    preprocessing.run()
    indexing.run()
    fit_throughput.run()
    try:
        from . import decode

        decode.run()
    except Exception as e:  # decode bench is optional on very slow hosts
        print(f"decode_bench,0,skipped:{type(e).__name__}")
    roofline.run()


if __name__ == "__main__":
    main()
