# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   preprocessing    — paper §3: fused vs unfused vs interpreted serve latency
#                      + planned-vs-interpreted-vs-naive-jit transform path
#   indexing         — paper §2: string/hash/bloom indexing variants
#   fit_throughput   — Spark-role streaming fit + transform throughput
#   decode           — serve_step latency for the LM substrate (smoke scale)
#   roofline         — dry-run-derived roofline terms per (arch, shape, mesh)
#
# ``--smoke`` runs the preprocessing comparison at tiny sizes and writes the
# collected rows to BENCH_preprocessing.json — cheap enough for CI, so the
# perf trajectory (planned vs interpreted, trace time, HLO op count) is
# recorded on every PR.
import argparse
import json
import pathlib
import sys


def _write_json(path: str) -> None:
    from . import common

    pathlib.Path(path).write_text(json.dumps(common.RESULTS, indent=2) + "\n")
    print(f"wrote {path} ({len(common.RESULTS)} rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes, preprocessing table only, write BENCH_preprocessing.json",
    )
    ap.add_argument(
        "--json",
        default="BENCH_preprocessing.json",
        help="output path for the JSON record (written in --smoke mode)",
    )
    args = ap.parse_args()

    from . import preprocessing

    print("name,us_per_call,derived")
    if args.smoke:
        preprocessing.run(smoke=True)
        _write_json(args.json)
        return

    from . import fit_throughput, indexing, roofline

    preprocessing.run()
    indexing.run()
    fit_throughput.run()
    try:
        from . import decode

        decode.run()
    except Exception as e:  # decode bench is optional on very slow hosts
        print(f"decode_bench,0,skipped:{type(e).__name__}")
    roofline.run()
    # NB: no JSON here — BENCH_preprocessing.json is the smoke-mode record
    # CI trends on; a full run's mixed tables would not be comparable.


if __name__ == "__main__":
    main()
