"""Benchmark harness utilities: timed closures, CSV emission, JSON capture."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

#: Every emit() lands here too, so run.py can persist a BENCH_*.json record.
RESULTS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 3, iters: int = 20) -> float:
    """Median wall time per call in microseconds (device-synchronised)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 1), "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
