"""Online serving tier benchmark: an open-loop load generator driving the
ServingGateway at fixed arrival rates.

Closed-loop clients (wait for a reply, then send the next request) hide
overload: the offered rate collapses to whatever the server sustains and the
latency distribution looks healthy even when capacity is exceeded.  An
OPEN-loop generator emits request i at ``t0 + i/rate`` no matter what came
back — the paper's production setting (~200 req/s of user traffic does not
slow down because the server is busy) — so queueing delay, shedding, and
backpressure appear in the measurements instead of being absorbed by the
generator.

The schedule is replayable: request rows come from a seeded generator and
arrival times are a fixed grid, so two runs offer byte-identical load.

Per rate, four rows land in BENCH_preprocessing.json:

  serve_gw_p50_r<rate>         gateway end-to-end p50 (from the DDSketch)
  serve_gw_p99_r<rate>         ... p99, plus queue-wait/execute quantiles
  serve_gw_throughput_r<rate>  completed rows/s over the run window
  serve_gw_shed_r<rate>        shed+rejected fraction of offered load

A second experiment replays ONE deadline-carrying load (mixed feasible and
never-feasible budgets) against a launch-time-only gateway and a cost-model
gateway, and records the finish-time-feasibility rows:

  serve_cost_hitrate_r<rate>   deadline-hit-rate (finished inside budget /
                               offered) with the cost model, vs baseline
  serve_cost_shedprec_r<rate>  shed precision: fraction of shed requests
                               that truly could not have finished (remaining
                               budget at shed < the model's known execute
                               time — exact ground truth, the model is
                               synthetic with a fixed cost)

A regression-shaped result — nothing completed, everything shed, or a
cost-model hit-rate materially below the launch-time baseline — raises
(benchmarks/run.py turns that into a loud failure).
"""
from __future__ import annotations

import concurrent.futures as cf
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    StandardScaleEstimator,
)
from repro.serve import (
    DeadlineExceededError,
    FusedModel,
    GatewayError,
    ServingGateway,
)

from .common import emit


def _build_fused() -> FusedModel:
    """A small but real request pipeline: hash-indexed id + log/scaled
    numerical, fused with a linear head."""
    rng = np.random.default_rng(0)
    lake = {
        "user_id": jnp.asarray(rng.integers(1, 1_000_000, 512), jnp.int64),
        "price": jnp.asarray(rng.lognormal(3, 2, 512), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="user_id", outputCol="uh", inputDtype="string",
                numBins=4096,
            ),
            LogTransformer(inputCol="price", outputCol="pl", alpha=1.0),
            StandardScaleEstimator(inputCol="pl", outputCol="ps"),
        ]
    )
    export = pipe.fit(lake).export(outputs=["uh", "ps"])

    def fwd(params, feats):
        return feats["ps"] * params["w"] + feats["uh"] % 97

    return FusedModel(export, fwd, {"w": jnp.float32(0.5)}, donate=True)


def _request_rows(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    return [
        {
            "user_id": np.int64(rng.integers(1, 1_000_000)),
            "price": np.float32(rng.lognormal(3, 2)),
        }
        for _ in range(n)
    ]


def run(smoke: bool = False) -> None:
    _run_gateway(smoke)
    _run_cost(smoke)
    _run_obs_overhead(smoke)


def _run_gateway(smoke: bool) -> None:
    fm = _build_fused()
    rates = [400] if smoke else [200, 800]
    seconds = 1.5 if smoke else 4.0
    for rate in rates:
        # fresh gateway per rate: the latency sketches are cumulative, and a
        # p99 row labelled r800 must not average in the unloaded r200 run
        # (the fused executables persist on fm, so re-warmup is trace-free
        # after the first rate).  cost_model=False pins this series to the
        # launch-time-only configuration it has always measured — the
        # longitudinal serve_gw_* rows must stay comparable across PRs, and
        # the cost-model configuration has its own serve_cost_* rows below
        gw = ServingGateway(
            max_pending=256, max_wait_ms=2.0, workers=2, cost_model=False
        )
        gw.register(
            "ranker",
            fm,
            example=_request_rows(1)[0],
            buckets=(1, 2, 4, 8, 16, 32),
            max_batch=32,
        )
        gw.warmup()
        try:
            _drive(gw, fm, rate, seconds, fm.trace_count)
        finally:
            gw.close()


def _drive(gw, fm, rate: int, seconds: float, traces_after_warmup: int) -> None:
    n = int(rate * seconds)
    rows = _request_rows(n, seed=100 + rate)
    completed, shed, rejected = [], [], []

    def client(i):
        try:
            gw.submit("ranker", rows[i], deadline_ms=250.0, timeout=10.0)
            completed.append(i)
        except DeadlineExceededError:
            shed.append(i)
        except GatewayError:
            rejected.append(i)

    batches_before = gw.snapshot()["stats"]["batches"]
    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=64) as pool:
        futs = []
        for i in range(n):  # open loop: dispatch at t0 + i/rate, no matter what
            target = t0 + i / rate
            lag = target - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            futs.append(pool.submit(client, i))
        for f in futs:
            f.result()
    elapsed = time.perf_counter() - t0

    snap = gw.snapshot()["models"]["ranker"]
    shed_rate = (len(shed) + len(rejected)) / n
    if not completed or shed_rate >= 1.0:
        raise RuntimeError(
            f"regression-shaped serving result at rate={rate}: "
            f"{len(completed)}/{n} completed, shed_rate={shed_rate:.2f}"
        )
    emit(
        f"serve_gw_p50_r{rate}",
        snap["e2e"]["p50_us"],
        f"queue_p50={snap['queue']['p50_us']}us exec_p50={snap['execute']['p50_us']}us",
    )
    emit(
        f"serve_gw_p99_r{rate}",
        snap["e2e"]["p99_us"],
        f"queue_p99={snap['queue']['p99_us']}us exec_p99={snap['execute']['p99_us']}us",
    )
    n_batches = gw.snapshot()["stats"]["batches"] - batches_before
    emit(
        f"serve_gw_throughput_r{rate}",
        1e6 * elapsed / max(len(completed), 1),
        f"rows_per_s={len(completed) / elapsed:.0f} offered={rate}/s "
        f"batches={n_batches}",
    )
    emit(
        f"serve_gw_shed_r{rate}",
        0.0,
        f"shed_rate={shed_rate:.3f} shed={len(shed)} rejected={len(rejected)} "
        f"completed={len(completed)}/{n} "
        f"traces_since_warmup={fm.trace_count - traces_after_warmup}",
    )


# ---------------------------------------------------------------------------
# Cost-aware scheduling: deadline-hit-rate and shed-precision vs the
# launch-time-only baseline, at the same offered load
# ---------------------------------------------------------------------------

_COST_EXEC_MS = 6.0  # synthetic model: KNOWN execute cost = exact feasibility
#                      ground truth for the shed-precision metric


def _sleepy_ranker():
    def fn(batch):
        time.sleep(_COST_EXEC_MS / 1e3)
        return {"y": np.asarray(batch["x"]) * 2.0}

    return fn


def _run_cost(smoke: bool) -> None:
    rate = 140 if smoke else 160
    seconds = 1.5 if smoke else 4.0
    out = {}
    for label, enabled in (("base", False), ("cost", True)):
        # serial single-slot server near saturation: wasted slots (doomed
        # requests the baseline launches anyway) visibly delay feasible ones
        gw = ServingGateway(
            max_pending=256, max_wait_ms=1.0, workers=1, cost_model=enabled
        )
        gw.register(
            "m",
            _sleepy_ranker(),
            example={"x": np.float32(0.0)},
            buckets=(1,),
            max_batch=1,
        )
        gw.warmup()
        try:
            out[label] = _drive_deadlines(gw, rate, seconds)
        finally:
            gw.close()
    base, cost = out["base"], out["cost"]
    if not base["completed"] or not cost["completed"]:
        raise RuntimeError(
            f"regression-shaped cost-serving result: completed "
            f"base={base['completed']} cost={cost['completed']}"
        )
    if not cost["shed"]:
        raise RuntimeError(
            "regression-shaped cost-serving result: the cost model shed "
            "nothing although half the offered load can never finish"
        )
    if cost["hit_rate"] + 0.05 < base["hit_rate"]:
        raise RuntimeError(
            f"regression-shaped cost-serving result: hit_rate "
            f"cost={cost['hit_rate']:.3f} < base={base['hit_rate']:.3f}"
        )
    # rates, not latencies: us_per_call stays 0.0 (the serve_gw_shed
    # convention) and the measured fractions live in `derived`
    emit(
        f"serve_cost_hitrate_r{rate}",
        0.0,
        f"cost={cost['hit_rate']:.3f} base={base['hit_rate']:.3f} "
        f"offered={rate}/s completed={cost['completed']} "
        f"late={cost['late']} base_late={base['late']} shed={cost['shed']}",
    )
    emit(
        f"serve_cost_shedprec_r{rate}",
        0.0,
        f"shed_precision={cost['shed_precision']:.3f} "
        f"truly_infeasible={cost['shed_true']}/{cost['shed']} "
        f"base_shed={base['shed']} exec_ms={_COST_EXEC_MS}",
    )


def _drive_deadlines(gw, rate: int, seconds: float) -> dict:
    """One replayable open-loop run: even requests carry a feasible 60ms
    budget, odd ones a 4ms budget that can NEVER finish (execute is 6ms).
    Hits are measured client-side: reply in hand inside the budget."""
    n = int(rate * seconds)
    exec_s = _COST_EXEC_MS / 1e3
    outcomes = [None] * n

    def client(i):
        deadline_ms = 4.0 if i % 2 else 60.0
        t_sub = time.perf_counter()
        try:
            gw.submit("m", {"x": np.float32(i)}, deadline_ms=deadline_ms, timeout=10.0)
            late = (time.perf_counter() - t_sub) * 1e3 > deadline_ms
            outcomes[i] = ("late" if late else "hit", None)
        except DeadlineExceededError:
            remaining = deadline_ms / 1e3 - (time.perf_counter() - t_sub)
            outcomes[i] = ("shed", remaining)
        except GatewayError:
            outcomes[i] = ("rejected", None)

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=64) as pool:
        futs = []
        for i in range(n):  # open loop: dispatch at t0 + i/rate, no matter what
            target = t0 + i / rate
            lag = target - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            futs.append(pool.submit(client, i))
        for f in futs:
            f.result()

    kinds = [o[0] for o in outcomes]
    shed_budgets = [o[1] for o in outcomes if o[0] == "shed"]
    # ground truth: a shed request truly could not have finished iff its
    # remaining budget at shed time was below the (known) execute time
    shed_true = sum(1 for b in shed_budgets if b < exec_s)
    n_shed = len(shed_budgets)
    return {
        "hit_rate": kinds.count("hit") / n,
        "completed": kinds.count("hit") + kinds.count("late"),
        "late": kinds.count("late"),
        "shed": n_shed,
        "shed_true": shed_true,
        "shed_precision": (shed_true / n_shed) if n_shed else float("nan"),
    }


# ---------------------------------------------------------------------------
# Observability overhead: tracing ON vs OFF at equal load.  The obs layer's
# contract is "on by default because it is cheap" — this row is the proof,
# and benchmarks/run.py --smoke fails the run if it goes missing or >5%.
# ---------------------------------------------------------------------------


def _run_obs_overhead(smoke: bool) -> None:
    from repro.obs import trace as obs_trace

    fm = _build_fused()
    rec = obs_trace.TraceRecorder(capacity=4096, enabled=True, sample=1.0)
    prev = obs_trace.get_recorder()
    obs_trace.set_recorder(rec)
    try:
        gw = ServingGateway(
            max_pending=512, max_wait_ms=1.0, workers=2, cost_model=False
        )
        gw.register(
            "ranker",
            fm,
            example=_request_rows(1)[0],
            buckets=(1, 2, 4, 8, 16, 32),
            max_batch=32,
        )
        gw.warmup()
        block_n = 16
        blocks = 30 if smoke else 50  # per mode, per rep
        reps = 3
        rows = _request_rows(block_n, seed=555)

        def one_block() -> float:
            """Wall time for block_n SEQUENTIAL requests.  Sequential on
            purpose: each request forms exactly one bucket-1 batch, so both
            modes execute an identical batch structure and the difference is
            the obs layer itself.  Concurrent load makes batch formation
            timing-sensitive — a microsecond perturbation can split a batch
            and the discrete extra execute dwarfs the per-span cost being
            measured."""
            t0 = time.perf_counter()
            for i in range(block_n):
                gw.submit("ranker", rows[i], timeout=10.0)
            return time.perf_counter() - t0

        for _ in range(6):  # warm both paths (executables, sketches)
            one_block()
        # fine-grained interleave: modes alternate every few ms, so drift
        # (thermal, allocator, GC, noisy neighbours) hits both modes equally
        # instead of biasing whole passes; min-of-reps on the summed wall
        # time then discards noise spikes rather than averaging them in
        on = [0.0] * reps
        off = [0.0] * reps
        for rep in range(reps):
            for b in range(2 * blocks):
                enabled = b % 2 == 0
                rec.enabled = enabled
                dt = one_block()
                if enabled:
                    on[rep] += dt
                else:
                    off[rep] += dt
        gw.close()
        best_on, best_off = min(on), min(off)
        n_req = blocks * block_n
        pct = max(0.0, (best_on - best_off) / best_off * 100.0)
        emit(
            "serve_obs_overhead_pct",
            pct,
            f"on_wall={best_on * 1e3:.1f}ms off_wall={best_off * 1e3:.1f}ms "
            f"delta_per_req={(best_on - best_off) / n_req * 1e6:.1f}us "
            f"blocks={blocks}x{block_n}req reps={reps} spans={rec.recorded}",
        )
    finally:
        obs_trace.set_recorder(prev)
