"""Multi-host smoke benchmark: the fake-device N-process job, measured.

Real multi-host numbers need a pod; this records that the multi-host MACHINERY
works and what it costs on the CPU harness, every PR:

  stream_mh_1p        1-process baseline of the differential stream payload
  stream_mh_2p        2-process per-host shard feeding (same logical stream,
                      each process staging/computing only its row block);
                      derived carries aggregate rows/s and the bit-identity
                      cross-check against the 1-process run
  serve_mh_p50_2p     2-process routed gateway replay: e2e p50 (+p99), with
                      per-shard round-trip p50s in `derived`
  serve_mh_shed_2p    completed/offered accounting of the routed replay
                      (everything must complete; sheds here are a failure)
  serve_ft_hitrate_faulty   deadline hit rate under an injected straggler,
                      hedging ON (value) vs OFF (in derived); ON must be
                      STRICTLY higher or the row itself raises
  serve_ft_kill_recover_ms  detection -> first degraded-mesh answer latency
                      after a worker kill -9, with zero failed requests
  mh_transport_pickle_wide  routed round-trip latency of a wide row-local
                      batch on the inline-pickle data plane (2 processes)
  mh_transport_shm_wide     the same batch over the shared-memory ring
                      transport; derived carries the speedup vs pickle and
                      the bit-identity cross-check against BOTH the pickle
                      leg and the 1-process in-process reference

``benchmarks/run.py --smoke`` fails loudly when these rows are missing —
a refactor that silently stops exercising multi-host (or its fault
tolerance) must fail CI.
"""
from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np

from .common import emit

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _launcher():
    path = os.path.join(_REPO, "tests", "multihost.py")
    spec = importlib.util.spec_from_file_location("mh_launcher", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("mh_launcher", mod)
    spec.loader.exec_module(mod)
    return mod


def run(smoke: bool = False) -> None:
    mh = _launcher()
    _stream(mh, smoke)
    _serve(mh, smoke)
    _serve_ft(mh, smoke)
    _transport(mh, smoke)


def _stream(mh, smoke: bool) -> None:
    sizes = [256] * (4 if smoke else 12)
    payload = {"seed": 21, "sizes": sizes, "pack": 2}
    ref = mh.launch("stream_plan", 1, payload)
    parts = mh.launch("stream_plan", 2, payload)
    n_rows = sum(sizes)

    def rate(results):
        secs = max(r["stats"]["seconds"] for r in results)
        rows = sum(r["stats"]["local_rows"] for r in results)
        return rows / max(secs, 1e-9), secs

    r1, s1 = rate(ref)
    r2, s2 = rate(parts)
    # bit-identity cross-check rides along with the measurement: the bench
    # must never record a number for a wrong answer
    for i in range(len(sizes)):
        for k in ref[0]["outputs"][i]:
            joined = np.concatenate(
                [p["outputs"][i][k] for p in parts], axis=0
            )
            np.testing.assert_array_equal(ref[0]["outputs"][i][k], joined)
    emit(
        "stream_mh_1p",
        1e6 * s1 / len(sizes),
        f"rows_per_s={r1:.0f} rows={n_rows}",
    )
    emit(
        "stream_mh_2p",
        1e6 * s2 / len(sizes),
        f"rows_per_s={r2:.0f} vs_1p={r2 / max(r1, 1e-9):.2f}x "
        f"rows={n_rows} bit_identical=yes",
    )


def _serve(mh, smoke: bool) -> None:
    payload = {
        "seed": 22,
        "requests": 64 if smoke else 256,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "cost_model": False,
    }
    res = mh.launch("gateway_replay", 2, payload)
    coord, worker = res[0], res[1]
    n = payload["requests"]
    if coord["stats"]["completed"] != n or worker["batches"] == 0:
        raise RuntimeError(
            f"regression-shaped multi-host serve: completed="
            f"{coord['stats']['completed']}/{n}, worker_batches={worker['batches']}"
        )
    shard_p50 = " ".join(
        f"{k}_p50={v.get('p50_us')}us" for k, v in sorted(coord["shard_us"].items())
    )
    emit(
        "serve_mh_p50_2p",
        coord["e2e_us"]["p50_us"],
        f"p99={coord['e2e_us']['p99_us']}us exec_p50={coord['execute_us']['p50_us']}us "
        f"{shard_p50}",
    )
    emit(
        "serve_mh_shed_2p",
        0.0,
        f"completed={coord['stats']['completed']}/{n} "
        f"worker_batches={worker['batches']} shards={coord['shards']} "
        f"traces_since_warmup={coord['traces_since_warmup']}",
    )


def _serve_ft(mh, smoke: bool) -> None:
    """Fault-tolerance rows, measured under injected faults (chaos entry).

    Both rows carry their own acceptance gates: hedging ON must beat OFF
    STRICTLY on deadline hit rate under the same straggler, and the kill
    schedule must answer every request (zero surfaced failures) — a bench
    row is never recorded for a wrong or degraded-into-failure answer."""
    base = {
        "seed": 23,
        "requests": 32 if smoke else 96,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "heartbeat_s": 0.5,
        "cost_model": False,
        "traffic": "stream",
        "clients": 4,
    }
    straggle = dict(
        base,
        deadline_ms=400.0,
        faults=[
            {"process": 1, "type": "delay", "delay_s": 0.5, "batches": (0, 1 << 30)}
        ],
    )
    off = mh.launch(
        "gateway_chaos", 2, dict(straggle, hedge=False), devices_per_proc=1
    )[0]
    on = mh.launch(
        "gateway_chaos", 2, dict(straggle, hedge=True), devices_per_proc=1
    )[0]
    if on["worker_failed"] or off["worker_failed"]:
        raise RuntimeError(
            f"straggler schedule surfaced worker failures: on={on['errors']} "
            f"off={off['errors']}"
        )
    if not on["hit_rate"] > off["hit_rate"]:
        raise RuntimeError(
            f"regression-shaped hedging: hit rate on={on['hit_rate']:.3f} "
            f"not strictly above off={off['hit_rate']:.3f}"
        )
    emit(
        "serve_ft_hitrate_faulty",
        100.0 * on["hit_rate"],
        f"hedge_off={100.0 * off['hit_rate']:.1f}% "
        f"hedges={on['ft'].get('hedges', 0)} "
        f"busy_skips={on['ft'].get('busy_skips', 0)} "
        f"deadline_ms={straggle['deadline_ms']:.0f}",
    )

    kill = dict(
        base,
        # past the warmup batches: the kill must land in client traffic
        faults=[{"process": 1, "type": "kill", "after_batches": 4}],
    )
    coord = mh.launch(
        "gateway_chaos", 2, kill, devices_per_proc=1, expendable=[1]
    )[0]
    n = kill["requests"]
    if coord["completed"] != n or coord["worker_failed"]:
        raise RuntimeError(
            f"regression-shaped kill recovery: completed={coord['completed']}/{n} "
            f"errors={coord['errors']}"
        )
    recover_ms = coord["ft"].get("kill_recover_ms", 0.0)
    if not recover_ms > 0:
        raise RuntimeError(
            f"kill schedule recorded no recovery latency: ft={coord['ft']}"
        )
    emit(
        "serve_ft_kill_recover_ms",
        recover_ms * 1e3,  # emit() values are microseconds repo-wide
        f"recover_ms={recover_ms:.1f} deaths={coord['ft']['worker_deaths']} "
        f"reshards={coord['ft']['reshards']} completed={coord['completed']}/{n} "
        f"failed=0",
    )


def _transport(mh, smoke: bool) -> None:
    """Data-plane comparison on a wide LTR-shaped batch: the same routed
    round-trip over inline pickle and over the shared-memory rings.  The
    bit-identity cross-check (shm == pickle == 1-process, exact) rides
    along with the measurement, and the shm leg must genuinely have used
    the ring (negotiated kind, frames flowed, zero inline fallbacks) — a
    silently-declined negotiation would otherwise record pickle's number
    under shm's name."""
    payload = {
        "rows": 128 if smoke else 256,
        "width": 16384,  # wide LTR feature block: 64 KiB per row
        "iters": 8 if smoke else 16,
        "seed": 24,
        "narrow_out": True,  # scores come back, not features
    }
    ref = mh.launch("transport_roundtrip", 1, payload)[0]
    legs = {}
    for kind in ("pickle", "shm"):
        legs[kind] = mh.launch(
            "transport_roundtrip", 2, payload,
            extra_env={
                "REPRO_MH_TRANSPORT": kind,
                # the per-worker half block is up to 8 MiB: two slots that
                # size per direction (request + reply in flight at once is
                # all the strict request/reply order ever needs)
                "REPRO_MH_SHM_SLOTS": "2",
                "REPRO_MH_SHM_SLOT_MB": "16",
            },
        )[0]
        for k in ref["outputs"]:
            np.testing.assert_array_equal(
                legs[kind]["outputs"][k], ref["outputs"][k]
            )
    wt = legs["shm"]["ft"]["workers"]["process1"]["transport"]
    if wt["kind"] != "shm" or wt["frames"] == 0 or wt["inline"]:
        raise RuntimeError(
            f"shm leg did not ride the ring: transport={wt}"
        )
    if legs["shm"]["leaked_shm"]:
        raise RuntimeError(
            f"shm segments outlived the executor: {legs['shm']['leaked_shm']}"
        )
    # the row value is the SHARD round-trip p50 (dispatch -> reply
    # consumed): the path the transport owns.  Coordinator-local compute
    # and output concat are identical across transports and would only
    # dilute the comparison; wall time rides along in derived.
    pickle_us = legs["pickle"]["shard_us"]["process1"]["p50_us"]
    shm_us = legs["shm"]["shard_us"]["process1"]["p50_us"]
    mb = legs["shm"]["bytes_per_call"] / 2**20
    emit(
        "mh_transport_pickle_wide",
        pickle_us,
        f"wall_us={legs['pickle']['us_per_call']:.0f} "
        f"rows={payload['rows']} width={payload['width']} mb_in={mb:.1f}",
    )
    emit(
        "mh_transport_shm_wide",
        shm_us,
        f"vs_pickle={pickle_us / max(shm_us, 1e-9):.2f}x "
        f"wall_us={legs['shm']['us_per_call']:.0f} "
        f"frames={wt['frames']} inline=0 rows={payload['rows']} "
        f"width={payload['width']} bit_identical=yes",
    )
