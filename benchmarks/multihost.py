"""Multi-host smoke benchmark: the fake-device N-process job, measured.

Real multi-host numbers need a pod; this records that the multi-host MACHINERY
works and what it costs on the CPU harness, every PR:

  stream_mh_1p        1-process baseline of the differential stream payload
  stream_mh_2p        2-process per-host shard feeding (same logical stream,
                      each process staging/computing only its row block);
                      derived carries aggregate rows/s and the bit-identity
                      cross-check against the 1-process run
  serve_mh_p50_2p     2-process routed gateway replay: e2e p50 (+p99), with
                      per-shard round-trip p50s in `derived`
  serve_mh_shed_2p    completed/offered accounting of the routed replay
                      (everything must complete; sheds here are a failure)

``benchmarks/run.py --smoke`` fails loudly when these rows are missing —
a refactor that silently stops exercising multi-host must fail CI.
"""
from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np

from .common import emit

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _launcher():
    path = os.path.join(_REPO, "tests", "multihost.py")
    spec = importlib.util.spec_from_file_location("mh_launcher", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("mh_launcher", mod)
    spec.loader.exec_module(mod)
    return mod


def run(smoke: bool = False) -> None:
    mh = _launcher()
    _stream(mh, smoke)
    _serve(mh, smoke)


def _stream(mh, smoke: bool) -> None:
    sizes = [256] * (4 if smoke else 12)
    payload = {"seed": 21, "sizes": sizes, "pack": 2}
    ref = mh.launch("stream_plan", 1, payload)
    parts = mh.launch("stream_plan", 2, payload)
    n_rows = sum(sizes)

    def rate(results):
        secs = max(r["stats"]["seconds"] for r in results)
        rows = sum(r["stats"]["local_rows"] for r in results)
        return rows / max(secs, 1e-9), secs

    r1, s1 = rate(ref)
    r2, s2 = rate(parts)
    # bit-identity cross-check rides along with the measurement: the bench
    # must never record a number for a wrong answer
    for i in range(len(sizes)):
        for k in ref[0]["outputs"][i]:
            joined = np.concatenate(
                [p["outputs"][i][k] for p in parts], axis=0
            )
            np.testing.assert_array_equal(ref[0]["outputs"][i][k], joined)
    emit(
        "stream_mh_1p",
        1e6 * s1 / len(sizes),
        f"rows_per_s={r1:.0f} rows={n_rows}",
    )
    emit(
        "stream_mh_2p",
        1e6 * s2 / len(sizes),
        f"rows_per_s={r2:.0f} vs_1p={r2 / max(r1, 1e-9):.2f}x "
        f"rows={n_rows} bit_identical=yes",
    )


def _serve(mh, smoke: bool) -> None:
    payload = {
        "seed": 22,
        "requests": 64 if smoke else 256,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "cost_model": False,
    }
    res = mh.launch("gateway_replay", 2, payload)
    coord, worker = res[0], res[1]
    n = payload["requests"]
    if coord["stats"]["completed"] != n or worker["batches"] == 0:
        raise RuntimeError(
            f"regression-shaped multi-host serve: completed="
            f"{coord['stats']['completed']}/{n}, worker_batches={worker['batches']}"
        )
    shard_p50 = " ".join(
        f"{k}_p50={v.get('p50_us')}us" for k, v in sorted(coord["shard_us"].items())
    )
    emit(
        "serve_mh_p50_2p",
        coord["e2e_us"]["p50_us"],
        f"p99={coord['e2e_us']['p99_us']}us exec_p50={coord['execute_us']['p50_us']}us "
        f"{shard_p50}",
    )
    emit(
        "serve_mh_shed_2p",
        0.0,
        f"completed={coord['stats']['completed']}/{n} "
        f"worker_batches={worker['batches']} shards={coord['shards']} "
        f"traces_since_warmup={coord['traces_since_warmup']}",
    )
