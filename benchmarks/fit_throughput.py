"""Spark-role analogue: streaming fit throughput of the full LTR pipeline
(rows/s through all estimator statistics) and transform throughput."""
from __future__ import annotations

import time

import jax

from repro.apps.ltr_pipeline import build_ltr_pipeline
from repro.core import KamaeSparkPipeline
from repro.apps.ltr_pipeline import build_ltr_stages
from repro.data import ltr_rows

from .common import emit


def run() -> None:
    n = 1024
    batches = [ltr_rows(n, seed=s) for s in range(4)]

    stages, _ = build_ltr_stages()
    pipe = KamaeSparkPipeline(stages=stages)
    t0 = time.perf_counter()
    fitted = pipe.fit(lambda: iter(batches))
    dt = time.perf_counter() - t0
    rows = n * len(batches)
    emit("fit_ltr_pipeline", dt * 1e6 / rows, f"rows_per_s={rows/dt:.0f} passes={fitted.n_passes}")

    tf = jax.jit(fitted.transform)
    out = tf(batches[0])
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for b in batches:
        out = tf(b)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    emit("transform_ltr_pipeline", dt * 1e6 / rows, f"rows_per_s={rows/dt:.0f}")
