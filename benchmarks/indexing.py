"""Paper §2 "Indexing" analogue: string- vs hash- vs bloom-indexing cost and
memory, incl. the Pallas bloom_hash kernel path (interpret mode on CPU)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import types as T
from repro.core import (
    BloomEncodeTransformer,
    HashIndexTransformer,
    StringIndexEstimator,
)

from .common import emit, time_fn


def run() -> None:
    rng = np.random.default_rng(0)
    n = 4096
    words = [f"item_{rng.integers(0, 2000)}" for _ in range(n)]
    s = jnp.asarray(T.encode_strings(words, 16))
    batch = {"s": s}

    est = StringIndexEstimator(inputCol="s", outputCol="y", numOOVIndices=1)
    fitted = est.fit_batch(batch)
    import jax

    t = time_fn(jax.jit(fitted.transform), batch)
    vocab_bytes = sum(int(np.prod(v.shape)) * v.dtype.itemsize for v in fitted.weights().values())
    emit("index_string_vocab2k", t, f"state_bytes={vocab_bytes}")

    hasher = HashIndexTransformer(inputCol="s", outputCol="y", numBins=1 << 16)
    t = time_fn(jax.jit(hasher.transform), batch)
    emit("index_hash_64k", t, "state_bytes=0")

    bloom = BloomEncodeTransformer(inputCol="s", outputCol="y", numBins=4096, numHashes=3)
    t = time_fn(jax.jit(bloom.transform), batch)
    emit("index_bloom_4kx3", t, "state_bytes=0 embeds=4096-rows (vs 64k)")

    bloomk = BloomEncodeTransformer(
        inputCol="s", outputCol="y", numBins=4096, numHashes=3, useKernel=True
    )
    t = time_fn(jax.jit(bloomk.transform), batch)
    emit("index_bloom_pallas_interpret", t, "bit-exact with jnp path")
