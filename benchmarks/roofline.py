"""Roofline table: reads the dry-run artifacts (benchmarks/artifacts/dryrun)
and prints the per-(arch x shape x mesh) terms — the §Roofline source — plus
analytic arithmetic-intensity rows for the fused preprocessing chains (bytes
the VMEM-resident intermediates keep off HBM vs the staged plan)."""
from __future__ import annotations

import json
import pathlib

import numpy as np

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def rows(mesh_filter=None):
    out = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(p.read_text())
        if "roofline" not in r:
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        r["_name"] = p.stem  # distinguishes hillclimb _iterN artifacts
        out.append(r)
    return out


def _chain_rows(batch_rows: int = 64) -> None:
    """Arithmetic-intensity rows for the fused transform chains of the LTR
    pipeline, derived analytically from each chain's op program: the staged
    plan round-trips every stage boundary through HBM (read operands + write
    result per op), the megakernel touches HBM only for the chain's external
    inputs and emitted outputs — intermediates stay in VMEM.  Per-op avals
    come from ``jax.eval_shape`` on the exact op bodies, so byte counts are
    shape/dtype-true, not estimates."""
    import jax

    from repro.apps.ltr_pipeline import build_ltr_pipeline
    from repro.core.plan import _FusedNode
    from repro.data import ltr_rows
    from repro.kernels.fused_transform import ops as fops

    train = ltr_rows(96, seed=0)
    fitted, _ = build_ltr_pipeline(train)
    batch = {k: v[:batch_rows] for k, v in ltr_rows(batch_rows, seed=5).items()}
    plan = fitted.plan(fuse=True)

    captured = []
    orig = fops.execute_chain

    def spy(program, inputs):
        captured.append((program, [jax.eval_shape(lambda: x) for x in inputs]))
        return orig(program, inputs)

    fops.execute_chain = spy
    try:
        plan.eager(batch)
    finally:
        fops.execute_chain = orig

    if not any(isinstance(n, _FusedNode) for n in plan._nodes):
        print("roofline_prechain,0.0,no fused chains in the LTR plan")
        return
    for i, (program, in_avals) in enumerate(captured):
        env = dict(zip(program.inputs, in_avals))
        nbytes = lambda a: int(np.prod(a.shape)) * a.dtype.itemsize  # noqa: E731
        staged = 0
        flops = 0
        for op in program.ops:
            args = [env[s] for s in op.inputs]
            out = jax.eval_shape(
                lambda *a, op=op: fops.apply_op(op.kind, op.params, list(a)), *args
            )
            env[op.output] = out
            staged += sum(nbytes(a) for a in args) + nbytes(out)
            flops += int(np.prod(out.shape))
        fused = sum(nbytes(a) for a in in_avals) + sum(
            nbytes(env[c]) for c in program.outputs
        )
        saved = staged - fused
        derived = (
            f"sig={program.signature()} ops={len(program.ops)} "
            f"bytes_row_staged={staged // batch_rows} "
            f"bytes_row_fused={fused // batch_rows} "
            f"traffic_saved={saved / staged:.0%} "
            f"ai_staged={flops / staged:.3f} ai_fused={flops / fused:.3f} "
            f"ai_gain={(flops / fused) / (flops / staged):.2f}x"
        )
        print(f"roofline_prechain_{i},{saved / batch_rows:.1f},{derived}")


def run() -> None:
    _chain_rows()
    rs = rows()
    if not rs:
        print("roofline,0,no dry-run artifacts yet — run repro.launch.dryrun")
        return
    for r in rs:
        t = r["roofline"]
        name = f"roofline_{r['_name']}"
        dominant_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        derived = (
            f"dom={t['dominant']} frac={t['roofline_fraction']:.3f} "
            f"comp={t['compute_s']:.3f}s mem={t['memory_s']:.3f}s "
            f"coll={t['collective_s']:.3f}s useful={t['useful_flops_ratio']:.2f} "
            f"peakGiB={r['memory']['peak_est_bytes_per_dev']/2**30:.2f}"
        )
        print(f"{name},{dominant_s*1e6:.1f},{derived}")
