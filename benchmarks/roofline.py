"""Roofline table: reads the dry-run artifacts (benchmarks/artifacts/dryrun)
and prints the per-(arch x shape x mesh) terms — the §Roofline source."""
from __future__ import annotations

import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def rows(mesh_filter=None):
    out = []
    for p in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(p.read_text())
        if "roofline" not in r:
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        r["_name"] = p.stem  # distinguishes hillclimb _iterN artifacts
        out.append(r)
    return out


def run() -> None:
    rs = rows()
    if not rs:
        print("roofline,0,no dry-run artifacts yet — run repro.launch.dryrun")
        return
    for r in rs:
        t = r["roofline"]
        name = f"roofline_{r['_name']}"
        dominant_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        derived = (
            f"dom={t['dominant']} frac={t['roofline_fraction']:.3f} "
            f"comp={t['compute_s']:.3f}s mem={t['memory_s']:.3f}s "
            f"coll={t['collective_s']:.3f}s useful={t['useful_flops_ratio']:.2f} "
            f"peakGiB={r['memory']['peak_est_bytes_per_dev']/2**30:.2f}"
        )
        print(f"{name},{dominant_s*1e6:.1f},{derived}")
