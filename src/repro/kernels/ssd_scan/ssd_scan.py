"""Pallas TPU kernel: Mamba2 chunked SSD scan.

Grid (B, H, n_chunks) with chunks innermost: the (N, P) f32 state scratch
persists across a head's chunks (TPU grids run sequentially per core).
Per chunk: the quadratic-in-Q intra-chunk attention-like term runs on the
MXU (three (Q,Q)/(Q,N)/(Q,P) dots), the inter-chunk term is one rank-N
update — exactly the state-space-duality decomposition from the paper
(arXiv:2405.21060), tiled so a chunk's working set (Q=128: ~0.4 MB) sits in
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, y_ref, state_scr, *, q_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = A_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)

    dA = dt * A  # (Q,) negative
    dA_cs = jnp.cumsum(dA)  # (Q,)

    # intra-chunk: y_diag[i] = sum_{j<=i} C_i.B_j * exp(cs_i - cs_j) * dt_j * x_j
    diff = dA_cs[:, None] - dA_cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q_len, q_len), 1
    )
    L = jnp.where(tri, jnp.exp(diff), 0.0)  # (Q, Q)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    M = CB * L * dt[None, :]
    y_diag = jax.lax.dot(M, x)  # (Q, P)

    # inter-chunk: y_off = C @ state_in, decayed to each position
    state_in = state_scr[...]  # (N, P)
    y_off = jax.lax.dot(Cm, state_in) * jnp.exp(dA_cs)[:, None]

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S <- exp(sum dA) * S + (B * decay_to_end * dt)^T @ x
    decay_end = jnp.exp(dA_cs[-1] - dA_cs)  # (Q,)
    wB = Bm * (decay_end * dt)[:, None]  # (Q, N)
    state_scr[...] = state_in * jnp.exp(dA_cs[-1]) + jax.lax.dot_general(
        wB, x, (((0,), (0,)), ((), ()))
    )


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32
    A: jax.Array,  # (H,) f32 (negative)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, "sequence must be a multiple of the chunk"
    nc = S // Q

    out = pl.pallas_call(
        functools.partial(_kernel, q_len=Q),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return out
