"""jit'd wrapper for the SSD kernel (interpret off-TPU; seq padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd(x, dt, A, Bm, Cm, chunk: int = 128):
    S = x.shape[1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=Q, interpret=_interpret())
    return out[:, :S]
