"""Sequential-recurrence oracle for the SSD kernel:

    S_t = exp(dt_t * A) S_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t S_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(x, dt, A, Bm, Cm):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P),(B,H),(B,H,N),(B,H,N)
        da = jnp.exp(dtt * A[None, :])  # (B,H)
        state = state * da[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtt, bt, xt
        )
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        state0,
        (
            jnp.moveaxis(xf, 1, 0),
            jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)
