"""Fused-chain execution: kernel dispatch + the XLA chain executor.

:func:`execute_chain` runs one :class:`~repro.core.fusion.ChainProgram` over
its external input columns and returns the chain's output columns.  Two
routes, selected by ``tune.kernel_route()`` (TPU, or ``REPRO_FUSED_KERNEL=1``
for interpret-mode testing):

* **Pallas megakernel** (``fused_transform.chain_call``) — one grid over row
  blocks, the whole op program executed per block with intermediates living
  in VMEM, in-chain string hashing via the bloom_hash 32-bit-limb FNV.  Only
  layout-eligible programs qualify (see :func:`kernel_plan`).
* **XLA chain executor** (:func:`execute_chain_xla`) — the whole chain as one
  jit-traceable jnp expression.  This is the semantic reference: every op
  replays the EXACT primitives of the stage it was lowered from, so fused
  output is bit-identical to the staged plan.

Both routes are traced inside the plan's jitted program; only autotuning
(:mod:`.tune`) needs concrete arrays and happens exclusively under
``tune.tuning()`` driven by ``TransformPlan.warm_fused``.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import fusion, hashing
from repro.core import types as T

from . import tune


# ---------------------------------------------------------------------------
# XLA chain executor — the bit-exact semantic reference for every route
# ---------------------------------------------------------------------------


def apply_op(kind: str, params: tuple, args: List[jax.Array]) -> jax.Array:
    """One ChainOp with the exact jnp semantics of the source stage (same
    primitives, same python-scalar weak-type promotion).  Shared with the
    megakernel body for every op that Mosaic can lower directly."""
    if kind == "cast":
        (x,) = args
        (d,) = params
        if T.is_string_col(x):
            # the staged path would run string_to_number here; not replayable
            # as an elementwise cast -> whole chain falls back stage-by-stage
            raise fusion.ChainFallback(f"cast({d}) on string column")
        return x.astype(jnp.dtype(d))
    if kind == "log":
        (x,) = args
        alpha, base = params
        y = jnp.log(x + alpha)
        if base is not None:
            y = y / jnp.log(jnp.asarray(base, y.dtype))
        return y
    if kind == "exp":
        return jnp.exp(args[0])
    if kind == "power":
        return jnp.power(args[0], params[0])
    if kind == "abs":
        return jnp.abs(args[0])
    if kind == "clip":
        return jnp.clip(args[0], params[0], params[1])
    if kind == "round":
        return {"round": jnp.round, "floor": jnp.floor, "ceil": jnp.ceil}[params[0]](args[0])
    if kind == "scale":
        mult, off = params
        return args[0] * mult + off
    if kind == "std_score":
        mean, std = params
        return (args[0] - mean) / std
    if kind == "binary_const":
        op, const = params
        x = args[0]
        return _binary()[op](x, jnp.asarray(const, x.dtype))
    if kind == "binary":
        return _binary()[params[0]](args[0], args[1])
    if kind == "cmp_const":
        op, const = params
        return _cmp()[op](args[0], const)
    if kind == "cmp":
        return _cmp()[params[0]](args[0], args[1])
    if kind == "logical":
        op = params[0]
        if op == "not":
            return ~args[0].astype(bool)
        x, y = (a.astype(bool) for a in args)
        return {"and": jnp.logical_and, "or": jnp.logical_or, "xor": jnp.logical_xor}[op](x, y)
    if kind == "where":
        c, t, e = args
        return jnp.where(c.astype(bool), t, e)
    if kind == "is_null":
        (x,) = args
        (sent,) = params
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.isnan(x)
        if sent is None:
            return jnp.zeros(x.shape, bool)
        return x == sent
    if kind == "coalesce":
        (x,) = args
        fill, sent = params
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.where(jnp.isnan(x), jnp.asarray(fill, x.dtype), x)
        if sent is None:
            return x
        return jnp.where(x == sent, jnp.asarray(int(fill), x.dtype), x)
    if kind == "impute":
        (x,) = args
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return jnp.where(jnp.isnan(x), jnp.asarray(params[0], x.dtype), x)
    if kind in ("std_scale", "minmax_scale"):
        (x,) = args
        a, b = params  # (mean, std) / (min, span)
        dt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float64
        return (x.astype(dt) - jnp.asarray(a, dt)) / jnp.asarray(b, dt)
    if kind == "bucketize":
        x = args[0]
        splits = jnp.asarray(list(params), jnp.float64)
        return jnp.searchsorted(splits, x.astype(jnp.float64), side="right").astype(jnp.int64)
    if kind == "hash_index":
        (x,) = args
        nb, seed, off = params
        if T.is_string_col(x):
            idx = hashing.hash_to_bins_routed(x, nb, seed)
        else:
            idx = hashing.int_to_bins(x, nb, seed)
        return idx + off
    raise fusion.ChainFallback(f"unknown chain op kind: {kind}")


def _binary():
    from repro.core.transformers.math import _BINARY

    return _BINARY


def _cmp():
    from repro.core.transformers.logical import _CMP

    return _CMP


def execute_chain_xla(program: fusion.ChainProgram, inputs: List[jax.Array]) -> List[jax.Array]:
    """Run the whole chain as one jnp expression (XLA fuses it into a single
    computation when jitted — the CPU/GPU payoff of the fusion pass)."""
    env = dict(zip(program.inputs, inputs))
    for op in program.ops:
        env[op.output] = apply_op(op.kind, op.params, [env[s] for s in op.inputs])
    return [env[c] for c in program.outputs]


# ---------------------------------------------------------------------------
# kernel eligibility + dispatch
# ---------------------------------------------------------------------------


def kernel_plan(program: fusion.ChainProgram, inputs: List[jax.Array]):
    """Partition the chain into shape-homogeneous subprograms and lay each
    out on a row grid, or return None when the megakernel cannot host it.

    A plan mixes lead shapes freely (e.g. LTR's query-level ``(B,)`` columns
    next to item-level ``(B, K)``); elementwise ops never cross shapes, so
    ops group by their output's lead shape and each group becomes one
    pallas_call with its own tuned config.  Eligibility per group:

    * byte (string) inputs may ONLY feed ``hash_index`` ops, and every
      ``hash_index`` must consume an external byte input (in-kernel hashing
      is the 32-bit-limb string path; integer hashing stays on XLA);
    * every op's non-byte inputs share the group's lead shape exactly (no
      cross-shape broadcasting), with at least one row.

    Returns a list of ``(subprogram, layout)`` with layout carrying
    ``byte_slots`` / ``lead`` / ``out_avals``.
    """
    shapes = {s: x.shape for s, x in zip(program.inputs, inputs)}
    is_bytes = {s: T.is_string_col(x) for s, x in zip(program.inputs, inputs)}
    groups: dict = {}
    order: List[tuple] = []
    for op in program.ops:
        if op.kind == "hash_index":
            b = op.inputs[0]
            if not is_bytes.get(b, False) or len(shapes[b]) < 2:
                return None
            gshape = shapes[b][:-1]
        else:
            if any(is_bytes.get(s, False) for s in op.inputs):
                return None
            in_shapes = [shapes[s] for s in op.inputs]
            gshape = in_shapes[0]
            if any(sh != gshape for sh in in_shapes):
                return None
        if not gshape:
            return None  # scalar columns: nothing to grid over
        shapes[op.output] = gshape
        is_bytes[op.output] = False
        if gshape not in groups:
            groups[gshape] = []
            order.append(gshape)
        groups[gshape].append(op)
    if not order:
        return None

    env_in = dict(zip(program.inputs, inputs))
    plans = []
    for gshape in order:
        ops_g = groups[gshape]
        written = {op.output for op in ops_g}
        ins: List[str] = []
        for op in ops_g:
            for s in op.inputs:
                if s not in written and s not in ins:
                    ins.append(s)
        outs = [c for c in program.outputs if c in written]
        sub = fusion.ChainProgram(ops_g, ins, outs)
        try:
            avals = jax.eval_shape(
                lambda *xs, sub=sub: tuple(execute_chain_xla(sub, list(xs))),
                *[env_in[s] for s in ins],
            )
        except fusion.ChainFallback:
            raise
        except Exception:
            return None
        if any(a.shape != gshape for a in avals):
            return None
        byte_slots = {s for s in ins if is_bytes.get(s, False)}
        plans.append((sub, {"byte_slots": byte_slots, "lead": gshape, "out_avals": list(avals)}))
    return plans


def execute_chain(program: fusion.ChainProgram, inputs: List[jax.Array]) -> List[jax.Array]:
    """Dispatch one fused chain: Pallas megakernel when routed + eligible,
    XLA chain executor otherwise.  Raises ChainFallback (for the plan to
    replay member stages) only for runtime-dtype mismatches."""
    if tune.kernel_route() and program.kernel_ok:
        plans = kernel_plan(program, inputs)
        if plans is not None:
            return _execute_kernel(program, inputs, plans)
    return execute_chain_xla(program, inputs)


def _execute_kernel(program, inputs, plans) -> List[jax.Array]:
    from . import fused_transform as ft

    env = dict(zip(program.inputs, inputs))
    concrete = not any(isinstance(x, jax.core.Tracer) for x in inputs)
    outs: dict = {}
    for sub, layout in plans:
        xs = [env[s] for s in sub.inputs]
        rows = 1
        for d in layout["lead"]:
            rows *= int(d)
        key = tune.key_for(sub.signature(), rows, [str(x.dtype) for x in xs])
        if tune.is_tuning() and concrete:
            config = tune.ensure_tuned(
                key,
                has_bytes=bool(layout["byte_slots"]),
                run_fn=lambda cfg, sub=sub, xs=xs, layout=layout: jax.block_until_ready(
                    ft.chain_call(sub, xs, layout, cfg)
                ),
            )
        else:
            config = tune.get_config(key)
        outs.update(zip(sub.outputs, ft.chain_call(sub, xs, layout, config)))
    return [outs[c] for c in program.outputs]
