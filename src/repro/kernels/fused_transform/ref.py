"""Pure-numpy reference for fused chains — ground truth for the fuzz tests.

Implements the same op set as ``ops.apply_op`` with numpy only (python-int
hashing, no JAX), so both the Pallas megakernel (interpret mode) and the XLA
chain executor can be checked bit-exact against an implementation that
shares no code with either.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

_M64 = (1 << 64) - 1
_FNV_OFFSET = 14695981039346656037
_FNV_PRIME = 1099511628211
_M1 = 0xFF51AFD7ED558CCD
_M2 = 0xC4CEB9FE1A85EC53


def ref_avalanche(h: int) -> int:
    h ^= h >> 33
    h = (h * _M1) & _M64
    h ^= h >> 33
    h = (h * _M2) & _M64
    h ^= h >> 33
    return h


def ref_fnv1a64(row: Sequence[int], seed: int = 0) -> int:
    """FNV-1a-64 over one row of bytes; zero bytes never update the state."""
    h = _FNV_OFFSET ^ (seed & _M64)
    for b in row:
        if int(b) != 0:
            h = ((h ^ int(b)) * _FNV_PRIME) & _M64
    return ref_avalanche(h)


def ref_hash_int64(v: int, seed: int = 0) -> int:
    h = ((int(v) & _M64) + 0x9E3779B97F4A7C15 * (seed + 1)) & _M64
    return ref_avalanche(h)


def ref_fold32(h: int) -> int:
    return (h ^ (h >> 32)) & 0xFFFFFFFF


def _is_bytes(x: np.ndarray) -> bool:
    return x.dtype == np.uint8


def ref_op(kind: str, params: tuple, args: List[np.ndarray]) -> np.ndarray:
    if kind == "cast":
        return args[0].astype(np.dtype(params[0]))
    if kind == "log":
        alpha, base = params
        y = np.log(args[0] + alpha)
        if base is not None:
            y = y / np.asarray(np.log(base), y.dtype)
        return y
    if kind == "exp":
        return np.exp(args[0])
    if kind == "power":
        return np.power(args[0], params[0])
    if kind == "abs":
        return np.abs(args[0])
    if kind == "clip":
        lo, hi = params
        return np.clip(args[0], lo, hi)
    if kind == "round":
        f = {"round": np.round, "floor": np.floor, "ceil": np.ceil}[params[0]]
        return f(args[0])
    if kind == "scale":
        return args[0] * params[0] + params[1]
    if kind == "std_score":
        return (args[0] - params[0]) / params[1]
    if kind == "bucketize":
        splits = np.asarray(list(params), np.float64)
        return np.searchsorted(splits, args[0].astype(np.float64), side="right").astype(
            np.int64
        )
    if kind == "hash_index":
        nb, seed, off = params
        x = args[0]
        if _is_bytes(x):
            hashes = [ref_fnv1a64(row, seed) for row in x.reshape(-1, x.shape[-1])]
            shape = x.shape[:-1]
        else:
            hashes = [ref_hash_int64(v, seed) for v in x.reshape(-1)]
            shape = x.shape
        bins = np.asarray([ref_fold32(h) % nb for h in hashes], np.int64)
        return bins.reshape(shape) + off
    raise NotImplementedError(f"no numpy reference for chain op {kind!r}")


def ref_chain(program, inputs: List[np.ndarray]) -> List[np.ndarray]:
    """Numpy ground truth for ``ops.execute_chain`` on a ChainProgram."""
    env: Dict[str, np.ndarray] = dict(
        zip(program.inputs, [np.asarray(x) for x in inputs])
    )
    for op in program.ops:
        env[op.output] = ref_op(op.kind, op.params, [env[s] for s in op.inputs])
    return [env[c] for c in program.outputs]
