"""Block-config autotuner + persisted tuned-config store for fused chains.

The megakernel's throughput depends on (block_rows, block_cols, chunk) —
rows per grid step, lane width of the elementwise tile, and the byte-loop
width for in-chain hashing.  Good values depend on the chain, the batch
shape, the dtypes and the backend, so (mirroring how aiter ships tuned
fused-MoE configs as a JSON table) winners are swept once and persisted:

* store file: ``~/.cache/repro/tuned_configs.json`` (override with
  ``REPRO_TUNE_CACHE``), merged over the repo-shipped defaults in
  ``default_configs.json`` next to this module;
* key: ``<chain signature>|r<pow2 row bucket>|<input dtypes>|<backend>``;
* entry: ``{"block_rows": .., "block_cols": .., "chunk": .., "us": ..,
  "swept": ..}``.

Tuning only happens inside an explicit :func:`tuning` scope driven with
CONCRETE arrays — :meth:`repro.core.plan.TransformPlan.warm_fused` runs the
plan eagerly under it, and ``registry.warmup`` calls that before AOT
precompilation so serving never tunes on the request path.  At trace time
dispatch only *reads* the store (pure Python, no sweeps).  A cache hit is
therefore exactly zero sweeps — asserted by the tests via :func:`stats`.

``REPRO_TUNE_BUDGET`` caps the number of candidate configs timed per sweep
(default 8; 0 disables sweeping, falling back to the default config).
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.obs import envknobs

#: fallback when no tuned entry exists (also the sweep's first candidate):
#: 512x8 elementwise tiles, 32-byte hash chunks.
DEFAULT_CONFIG = {"block_rows": 512, "block_cols": 8, "chunk": 32}

_DEFAULTS_FILE = os.path.join(os.path.dirname(__file__), "default_configs.json")

_store: Optional[Dict[str, dict]] = None
_tuning = False
_sweeps = 0
_hits = 0


# ---------------------------------------------------------------------------
# routing / env knobs
# ---------------------------------------------------------------------------


def kernel_route() -> bool:
    """Whether fused chains should route to the Pallas megakernel.

    ``REPRO_FUSED_KERNEL=1`` forces it (interpret mode off-TPU — how the
    tests drive it), ``=0`` forces the XLA chain executor, unset = kernel on
    TPU only."""
    flag = envknobs.env_tristate("REPRO_FUSED_KERNEL")
    if flag is not None:
        return flag
    return jax.default_backend() == "tpu"


def backend_tag() -> str:
    return "tpu" if jax.default_backend() == "tpu" else "interpret"


def budget() -> int:
    return envknobs.env_int("REPRO_TUNE_BUDGET", 8)


def cache_path() -> str:
    p = envknobs.env_str("REPRO_TUNE_CACHE")
    if p:
        return p
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tuned_configs.json"
    )


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


def _read_json(path: str) -> Dict[str, dict]:
    try:
        with open(path) as f:
            payload = json.load(f)
        return dict(payload.get("configs", {}))
    except (OSError, ValueError):
        return {}


def _load_store() -> Dict[str, dict]:
    global _store
    if _store is None:
        merged = _read_json(_DEFAULTS_FILE)  # repo-shipped defaults first
        merged.update(_read_json(cache_path()))  # user cache wins
        _store = merged
    return _store


def _save_store() -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "configs": _load_store()}, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only home: tuning still works, it just won't persist


def reload() -> None:
    """Drop the in-memory store so the next lookup re-reads the JSON files
    (tests use this to prove the cache genuinely round-trips via disk)."""
    global _store
    _store = None


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def key_for(signature: str, rows: int, dtypes: List[str]) -> str:
    """Rows are bucketed to the next power of two: one tuned config covers a
    range of batch sizes instead of re-sweeping per exact shape."""
    return f"{signature}|r{_pow2(rows)}|{'+'.join(dtypes)}|{backend_tag()}"


def get_config(key: str) -> dict:
    cfg = _load_store().get(key)
    if cfg is None:
        return dict(DEFAULT_CONFIG)
    return {**DEFAULT_CONFIG, **cfg}


# ---------------------------------------------------------------------------
# tuning scope + sweep
# ---------------------------------------------------------------------------


def is_tuning() -> bool:
    return _tuning


@contextlib.contextmanager
def tuning():
    global _tuning
    prev = _tuning
    _tuning = True
    try:
        yield
    finally:
        _tuning = prev


def candidates(has_bytes: bool) -> List[dict]:
    """Deterministic sweep order, best-guess first.  Hash chains sweep the
    byte-loop chunk too; elementwise chains only the tile geometry."""
    out = [dict(DEFAULT_CONFIG)]
    rows_opts = (512, 256, 1024, 2048, 128)
    cols_opts = (8, 1, 4, 16)
    chunk_opts = (32, 16, 64) if has_bytes else (32,)
    for chunk in chunk_opts:
        for br in rows_opts:
            for bc in cols_opts:
                cfg = {"block_rows": br, "block_cols": bc, "chunk": chunk}
                if cfg not in out:
                    out.append(cfg)
    return out


def ensure_tuned(
    key: str, has_bytes: bool, run_fn: Callable[[dict], None]
) -> dict:
    """Sweep ``run_fn`` over candidate configs for ``key`` unless the store
    already holds a winner (zero sweeps on a hit).  ``run_fn`` executes the
    chain once with the given config; each candidate is timed over a warmup
    call plus 2 measured calls."""
    global _sweeps, _hits
    store = _load_store()
    if key in store:
        _hits += 1
        return get_config(key)
    cap = budget()
    if cap <= 0:
        return dict(DEFAULT_CONFIG)
    best_cfg, best_us, swept = dict(DEFAULT_CONFIG), float("inf"), 0
    for cfg in candidates(has_bytes)[:cap]:
        try:
            run_fn(cfg)  # warmup: pays compile/lowering cost
            t0 = time.perf_counter()
            run_fn(cfg)
            run_fn(cfg)
            us = (time.perf_counter() - t0) / 2 * 1e6
        except Exception:
            continue  # config invalid for this shape (e.g. tile > rows)
        _sweeps += 1
        swept += 1
        if us < best_us:
            best_cfg, best_us = cfg, us
    store[key] = {**best_cfg, "us": round(best_us, 2), "swept": swept}
    _save_store()
    return best_cfg


def stats() -> dict:
    return {
        "sweeps": _sweeps,
        "hits": _hits,
        "entries": len(_load_store()),
        "path": cache_path(),
    }


def reset_stats() -> None:
    global _sweeps, _hits
    _sweeps = 0
    _hits = 0
