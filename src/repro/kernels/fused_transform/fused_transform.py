"""Pallas megakernel: one fused transform chain in one kernel launch.

The staged plan pays one HBM round-trip per stage boundary; here the whole
op program runs per row block with every intermediate living in VMEM, so a
k-op chain moves ``inputs + outputs`` bytes instead of ``~2k`` column
round-trips (the roofline win ``benchmarks/roofline.py`` tabulates).

Two layouts, picked by whether the chain hashes string columns:

* **rows mode** (byte inputs present): grid over row blocks of the flattened
  lead axis.  Numeric columns arrive as (block_rows, 1) VMEM blocks, byte
  columns as (block_rows, Lp) int32 blocks (uint8 widened, L padded to a
  multiple of ``chunk``).  In-chain hashing reuses the bloom_hash 32-bit-limb
  FNV-1a-64 (`_hash_init`/`_hash_update`/`_fmix64`) — bit-exact with
  ``repro.core.hashing`` — looping ``chunk``-wide byte slices via
  ``fori_loop`` so long strings don't blow up the unrolled program.
* **flat mode** (elementwise only): every column flattened to one axis and
  retiled (block_rows, block_cols); the grid walks row tiles.

Op bodies are shared with the XLA executor (``ops.apply_op``) except:

* ``bucketize`` — ``searchsorted`` doesn't map onto the VPU; the kernel
  computes ``n_splits - sum(x < split_i)``, which equals searchsorted's
  side="right" insertion index for every input INCLUDING NaN (all compares
  false -> index n_splits, exactly where searchsorted puts NaN).
* ``hash_index`` — the limb path above (program-invalid off the kernel for
  seeds >= 2**32, enforced by ``ChainProgram.kernel_ok``).

Zero row padding flows through as garbage rows and is sliced off after the
call; zero byte padding never updates the FNV state (same invariant the
bloom_hash kernel relies on).  int64/float64 slots are fine in interpret
mode (how non-TPU tests run); on real TPUs Mosaic lowers them as 32-bit
pairs, which the autotuner's timing sweep prices in per backend.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fusion
from repro.kernels.bloom_hash.bloom_hash import (
    _fmix64,
    _hash_init,
    _hash_update,
    _u32,
)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _hash_bytes(seed: int, b: jax.Array, chunk: int):
    """(n, Lp) int32 zero-padded bytes -> avalanched (h_hi, h_lo) limbs."""
    n, Lp = b.shape
    h_hi, h_lo = _hash_init(_u32(seed), n)
    if not chunk or Lp <= chunk:
        h_hi, h_lo = _hash_update(h_hi, h_lo, b, Lp)
        return _fmix64(h_hi, h_lo)

    def body(c, state):
        hh, hl = state
        blk = jax.lax.dynamic_slice(b, (0, c * chunk), (n, chunk))
        return _hash_update(hh, hl, blk, chunk)

    h_hi, h_lo = jax.lax.fori_loop(0, Lp // chunk, body, (h_hi, h_lo))
    return _fmix64(h_hi, h_lo)


def _kernel_op(kind: str, params: tuple, args: List[jax.Array]) -> jax.Array:
    from . import ops as _ops

    if kind == "bucketize":
        x = args[0].astype(jnp.float64)
        acc = jnp.zeros(x.shape, jnp.int32)
        for s in params:
            acc += (x < jnp.float64(s)).astype(jnp.int32)
        return (jnp.int32(len(params)) - acc).astype(jnp.int64)
    return _ops.apply_op(kind, params, args)


def _chain_kernel(*refs, program: fusion.ChainProgram, byte_slots: frozenset, chunk: int):
    n_in = len(program.inputs)
    env = {}
    for name, ref in zip(program.inputs, refs[:n_in]):
        env[name] = ref[...]
    for op in program.ops:
        if op.kind == "hash_index":
            nb, seed, off = op.params
            h_hi, h_lo = _hash_bytes(seed, env[op.inputs[0]], chunk)
            folded = h_hi ^ h_lo
            env[op.output] = ((folded % _u32(nb)).astype(jnp.int64) + off)[:, None]
        else:
            env[op.output] = _kernel_op(op.kind, op.params, [env[s] for s in op.inputs])
    for name, ref in zip(program.outputs, refs[n_in:]):
        ref[...] = env[name]


def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _pad_rows(x: jax.Array, rp: int) -> jax.Array:
    if x.shape[0] == rp:
        return x
    return jnp.pad(x, ((0, rp - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def chain_call(
    program: fusion.ChainProgram,
    inputs: List[jax.Array],
    plan: dict,
    config: dict,
) -> List[jax.Array]:
    """Execute ``program`` via one pallas_call, per the layout ``plan`` from
    ``ops.kernel_plan`` and a (block_rows, block_cols, chunk) ``config``."""
    lead, byte_slots = plan["lead"], frozenset(plan["byte_slots"])
    rows = 1
    for d in lead:
        rows *= int(d)
    if byte_slots:
        return _call_rows(program, inputs, plan, config, rows, byte_slots)
    return _call_flat(program, inputs, plan, config, rows)


def _call_rows(program, inputs, plan, config, rows, byte_slots):
    chunk = int(config["chunk"])
    br = min(int(config["block_rows"]), _pow2ceil(rows))
    rp = -(-rows // br) * br
    lead = plan["lead"]

    ins, in_specs = [], []
    for name, x in zip(program.inputs, inputs):
        if name in byte_slots:
            L = x.shape[-1]
            lp = -(-L // chunk) * chunk if L > chunk else L
            b = x.astype(jnp.int32).reshape(rows, L)
            if lp != L:
                b = jnp.pad(b, ((0, 0), (0, lp - L)))
            ins.append(_pad_rows(b, rp))
            in_specs.append(pl.BlockSpec((br, lp), lambda i: (i, 0)))
        else:
            ins.append(_pad_rows(x.reshape(rows, 1), rp))
            in_specs.append(pl.BlockSpec((br, 1), lambda i: (i, 0)))

    out_avals = plan["out_avals"]
    outs = pl.pallas_call(
        functools.partial(
            _chain_kernel, program=program, byte_slots=byte_slots, chunk=chunk
        ),
        grid=(rp // br,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((br, 1), lambda i: (i, 0)) for _ in out_avals],
        out_shape=[jax.ShapeDtypeStruct((rp, 1), a.dtype) for a in out_avals],
        interpret=_interpret(),
    )(*ins)
    return [o[:rows, 0].reshape(lead) for o in outs]


def _call_flat(program, inputs, plan, config, total):
    bc = int(config["block_cols"])
    br = min(int(config["block_rows"]), _pow2ceil(-(-total // bc)))
    tile = br * bc
    tp = -(-total // tile) * tile
    lead = plan["lead"]

    ins = []
    for x in inputs:
        flat = x.reshape(total)
        if tp != total:
            flat = jnp.pad(flat, (0, tp - total))
        ins.append(flat.reshape(tp // bc, bc))

    out_avals = plan["out_avals"]
    spec = pl.BlockSpec((br, bc), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(
            _chain_kernel, program=program, byte_slots=frozenset(), chunk=0
        ),
        grid=(tp // tile,),
        in_specs=[spec for _ in ins],
        out_specs=[spec for _ in out_avals],
        out_shape=[jax.ShapeDtypeStruct((tp // bc, bc), a.dtype) for a in out_avals],
        interpret=_interpret(),
    )(*ins)
    return [o.reshape(tp)[:total].reshape(lead) for o in outs]
