"""Fused transform-chain megakernel: one Pallas call per fused stage chain,
with a block-config autotuner and a persisted tuned-config store.

Public surface:

* ``ops.execute_chain(program, inputs)`` — dispatch (kernel / XLA executor)
* ``tune`` — autotuner, config store, ``REPRO_FUSED_KERNEL`` routing
* ``ref.ref_chain`` — pure-numpy ground truth for tests
"""
from . import ops, ref, tune  # noqa: F401
from .fused_transform import chain_call  # noqa: F401
