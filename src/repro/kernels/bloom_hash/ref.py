"""Pure-jnp oracle for the bloom_hash kernel: seeded FNV-1a-64 + fmix64
avalanche + 32-bit fold + modulo binning, identical to
``repro.core.hashing`` (single source of truth — the oracle simply calls it).
"""
from __future__ import annotations

import jax

from repro.core import hashing


def bloom_indices(strings: jax.Array, num_bins: int, num_hashes: int) -> jax.Array:
    return hashing.bloom_indices(strings, num_bins, num_hashes)
