"""jit'd wrapper for the bloom_hash kernel: rank-polymorphic dispatch,
uint8 -> int32 widening, interpret-mode selection off-TPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bloom_hash import bloom_hash_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bloom_indices(strings: jax.Array, num_bins: int, num_hashes: int) -> jax.Array:
    """(..., L) uint8 -> (..., num_hashes) int64 bloom bin indices."""
    lead = strings.shape[:-1]
    L = strings.shape[-1]
    flat = strings.reshape(-1, L).astype(jnp.int32)
    out = bloom_hash_kernel(flat, num_bins, num_hashes, interpret=_interpret())
    return out.reshape(lead + (num_hashes,)).astype(jnp.int64)


def hash_indices(strings: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    """Single-seed hash indexing through the same kernel (seed 0 only in the
    kernel grid; other seeds use the jnp path)."""
    if seed != 0:
        from repro.core import hashing

        return hashing.hash_to_bins(strings, num_bins, seed)
    return bloom_indices(strings, num_bins, 1)[..., 0]
