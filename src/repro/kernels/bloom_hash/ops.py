"""jit'd wrapper for the bloom_hash kernel: rank-polymorphic dispatch,
uint8 -> int32 widening, interpret-mode selection off-TPU.

``REPRO_HASH_CHUNK`` overrides the byte-chunk width of the long-string grid
(0 forces the historical full unroll; unset = auto, chunking above 64
bytes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.obs import envknobs

from .bloom_hash import bloom_hash_kernel, bloom_hash_kernel_raw


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _chunk_override():
    # env_str keeps "" (unset) distinct from "0" (force the full unroll)
    v = envknobs.env_str("REPRO_HASH_CHUNK")
    return int(v) if v else None


def _flat(strings: jax.Array):
    lead = strings.shape[:-1]
    return strings.reshape(-1, strings.shape[-1]).astype(jnp.int32), lead


def bloom_indices(strings: jax.Array, num_bins: int, num_hashes: int) -> jax.Array:
    """(..., L) uint8 -> (..., num_hashes) int64 bloom bin indices."""
    flat, lead = _flat(strings)
    out = bloom_hash_kernel(
        flat, num_bins, num_hashes, interpret=_interpret(), chunk_len=_chunk_override()
    )
    return out.reshape(lead + (num_hashes,)).astype(jnp.int64)


def hash_indices(strings: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    """Single-seed hash indexing through the same kernel."""
    return hash_indices_seeded(strings, num_bins, seed)


def hash_indices_seeded(strings: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    """(..., L) uint8 -> (...,) int64 hash-bin indices for one arbitrary
    uint32 seed (the kernel folds the seed into the low hash limb)."""
    if not 0 <= seed < 2**32:
        from repro.core import hashing

        return hashing.hash_to_bins(strings, num_bins, seed)
    flat, lead = _flat(strings)
    seeds = jnp.asarray([seed], jnp.uint32)
    out = bloom_hash_kernel(
        flat, num_bins, 1, interpret=_interpret(), seeds=seeds,
        chunk_len=_chunk_override(),
    )
    return out[..., 0].reshape(lead).astype(jnp.int64)


def fnv1a64_raw(strings: jax.Array, seed: int = 0) -> jax.Array:
    """(..., L) uint8 -> (...,) uint64 raw avalanched hash via the kernel.

    Bit-exact with ``repro.core.hashing.fnv1a64``: the kernel emits the two
    uint32 limbs and they are recombined here (x64 mode is enabled by
    ``repro.core.types``)."""
    flat, lead = _flat(strings)
    seeds = jnp.asarray([seed], jnp.uint32)
    hi, lo = bloom_hash_kernel_raw(
        flat, 1, interpret=_interpret(), seeds=seeds, chunk_len=_chunk_override()
    )
    h = (hi[:, 0].astype(jnp.uint64) << jnp.uint64(32)) | lo[:, 0].astype(jnp.uint64)
    return h.reshape(lead)
