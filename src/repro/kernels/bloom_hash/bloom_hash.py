"""Pallas TPU kernel: multi-seed FNV-1a-64 string hashing (bloom encoding).

TPU vector units have no 64-bit integers, so the 64-bit hash state is carried
as two uint32 limbs and the 64x64->low-64 multiply is synthesised from
16-bit sublimb products (each fits uint32 exactly).  The result is bit-exact
with the uint64 reference in ``repro.core.hashing`` — asserted by the kernel
tests — which is what guarantees the paper's offline/online parity when the
hot serving path runs this kernel while the Spark-role fit used the jnp path.

Grid: (num_hashes, N / BLOCK_N) for short strings — each program hashes
BLOCK_N strings for one seed, and the L loop is a static unroll of
elementwise ops, which Mosaic maps straight onto the VPU.  For long strings
(L > chunk_len) the grid grows a trailing byte-chunk dimension:
(num_hashes, N / BLOCK_N, L / chunk_len).  TPU grids iterate the minor
dimension sequentially per core, so the running 64-bit state (two uint32
limb vectors) is carried across chunk steps in VMEM scratch — initialised at
chunk 0, avalanched and written to the output block at the last chunk.  Only
chunk_len bytes are ever unrolled into the traced program, so max_len=256
costs the same trace/compile as max_len=64 while computing the identical
hash (asserted bit-exact against the unrolled kernel by the tests).

Bytes arrive as int32 (widened by ops.py: uint8 VREG lanes are wasted on TPU
anyway) in (BLOCK_N, chunk) VMEM blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

FNV_OFFSET = 14695981039346656037
FNV_PRIME_HI = 0x00000100  # 0x100000001B3 >> 32
FNV_PRIME_LO = 0x000001B3

_M1 = 0xFF51AFD7ED558CCD
_M2 = 0xC4CEB9FE1A85EC53


def _u32(x):
    return jnp.uint32(x)


def _mul32_lohi(a, b):
    """32x32 -> (lo32, hi32) via 16-bit sublimbs (all intermediates < 2^32)."""
    a0 = a & _u32(0xFFFF)
    a1 = a >> _u32(16)
    b0 = b & _u32(0xFFFF)
    b1 = b >> _u32(16)
    t0 = a0 * b0
    t1 = a1 * b0 + (t0 >> _u32(16))
    t2 = a0 * b1 + (t1 & _u32(0xFFFF))
    lo = (t2 << _u32(16)) | (t0 & _u32(0xFFFF))
    hi = a1 * b1 + (t1 >> _u32(16)) + (t2 >> _u32(16))
    return lo, hi


def _mul64(ah, al, bh, bl):
    """(ah:al) * (bh:bl) mod 2^64 -> (hi, lo)."""
    lo, carry = _mul32_lohi(al, bl)
    hi = carry + al * bh + ah * bl  # mod 2^32 wraparound is exactly right
    return hi, lo


def _xor64(ah, al, bh, bl):
    return ah ^ bh, al ^ bl


def _shr64(ah, al, n: int):
    if n >= 32:
        return _u32(0), ah >> _u32(n - 32)
    return ah >> _u32(n), (al >> _u32(n)) | (ah << _u32(32 - n))


def _fmix64(h_hi, h_lo):
    for mult in (_M1, _M2, None):
        s_hi, s_lo = _shr64(h_hi, h_lo, 33)
        h_hi, h_lo = _xor64(h_hi, h_lo, s_hi, s_lo)
        if mult is not None:
            h_hi, h_lo = _mul64(h_hi, h_lo, _u32(mult >> 32), _u32(mult & 0xFFFFFFFF))
    return h_hi, h_lo


def _hash_init(seed, n):
    """Fresh (h_hi, h_lo) uint32 limb vectors for ``n`` strings."""
    h_hi = jnp.full((n,), _u32(FNV_OFFSET >> 32), jnp.uint32)
    h_lo = jnp.full((n,), _u32(FNV_OFFSET & 0xFFFFFFFF), jnp.uint32) ^ seed
    return h_hi, h_lo


def _hash_update(h_hi, h_lo, b, nbytes: int):
    """Advance the FNV state over ``nbytes`` byte lanes of (BLOCK_N, nbytes)."""
    p_hi, p_lo = _u32(FNV_PRIME_HI), _u32(FNV_PRIME_LO)
    for i in range(nbytes):
        byte = b[:, i].astype(jnp.uint32)
        x_lo = h_lo ^ byte
        n_hi, n_lo = _mul64(h_hi, x_lo, p_hi, p_lo)
        live = byte != 0  # zero padding leaves the state untouched
        h_hi = jnp.where(live, n_hi, h_hi)
        h_lo = jnp.where(live, n_lo, h_lo)
    return h_hi, h_lo


def _hash_block(seed, b, max_len: int):
    """(BLOCK_N, L) int32 bytes -> avalanched (h_hi, h_lo) uint32 limbs."""
    h_hi, h_lo = _hash_init(seed, b.shape[0])
    h_hi, h_lo = _hash_update(h_hi, h_lo, b, max_len)
    return _fmix64(h_hi, h_lo)


def _kernel(seeds_ref, bytes_ref, out_ref, *, num_bins: int, max_len: int):
    seed = seeds_ref[0]  # uint32 seed for this program (seeds < 2^32 here)
    h_hi, h_lo = _hash_block(seed, bytes_ref[...], max_len)
    folded = h_hi ^ h_lo
    out_ref[...] = (folded % _u32(num_bins)).astype(jnp.int32)[None, :]


def _kernel_raw(seeds_ref, bytes_ref, hi_ref, lo_ref, *, max_len: int):
    """Raw-hash variant: emits the 64-bit hash as uint32 limbs (no fold/mod),
    for consumers that need the full hash (vocab searchsorted lookup)."""
    seed = seeds_ref[0]
    h_hi, h_lo = _hash_block(seed, bytes_ref[...], max_len)
    hi_ref[...] = h_hi[None, :]
    lo_ref[...] = h_lo[None, :]


# ---------------------------------------------------------------------------
# chunked variants: grid (num_hashes, N/BLOCK_N, L/chunk); the minor chunk
# axis runs sequentially, carrying the running limbs in VMEM scratch so only
# chunk_len bytes are unrolled into the program
# ---------------------------------------------------------------------------

def _chunk_step(seeds_ref, bytes_ref, state_hi, state_lo, chunk_len: int):
    """Shared chunk body: (possibly init,) advance state over this chunk."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _():
        h_hi, h_lo = _hash_init(seeds_ref[0], state_hi.shape[1])
        state_hi[0, :] = h_hi
        state_lo[0, :] = h_lo

    h_hi, h_lo = _hash_update(
        state_hi[0, :], state_lo[0, :], bytes_ref[...], chunk_len
    )
    state_hi[0, :] = h_hi
    state_lo[0, :] = h_lo


def _kernel_chunked(
    seeds_ref, bytes_ref, out_ref, state_hi, state_lo, *, num_bins: int, chunk_len: int
):
    _chunk_step(seeds_ref, bytes_ref, state_hi, state_lo, chunk_len)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        h_hi, h_lo = _fmix64(state_hi[0, :], state_lo[0, :])
        folded = h_hi ^ h_lo
        out_ref[...] = (folded % _u32(num_bins)).astype(jnp.int32)[None, :]


def _kernel_raw_chunked(
    seeds_ref, bytes_ref, hi_ref, lo_ref, state_hi, state_lo, *, chunk_len: int
):
    _chunk_step(seeds_ref, bytes_ref, state_hi, state_lo, chunk_len)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        h_hi, h_lo = _fmix64(state_hi[0, :], state_lo[0, :])
        hi_ref[...] = h_hi[None, :]
        lo_ref[...] = h_lo[None, :]


#: Byte width above which the byte-chunk grid replaces the full unroll.
#: 64 bytes unrolled traces fast and keeps the VPU busy; beyond that the
#: chunked grid holds trace/compile cost flat in max_len.
DEFAULT_CHUNK_LEN = 64


def _padded(byte_tensor: jax.Array, block_n: int, chunk_len: int = 0):
    N, L = byte_tensor.shape
    pad_n = (-N) % block_n
    pad_l = (-L) % chunk_len if chunk_len else 0
    if pad_n or pad_l:
        # zero padding never updates the hash state, so widening L is free
        byte_tensor = jnp.pad(byte_tensor, ((0, pad_n), (0, pad_l)))
    return byte_tensor, N


def _resolve_seeds(num_hashes: int, seeds) -> jax.Array:
    if seeds is None:
        return jnp.arange(num_hashes, dtype=jnp.uint32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    assert seeds.shape == (num_hashes,)
    return seeds


def _resolve_chunk(L: int, chunk_len) -> int:
    """0 = unrolled single-shot kernel; >0 = chunked grid of that width."""
    if chunk_len is None:
        chunk_len = DEFAULT_CHUNK_LEN if L > DEFAULT_CHUNK_LEN else 0
    if chunk_len and chunk_len >= L:
        chunk_len = 0
    return chunk_len


def bloom_hash_kernel(
    byte_tensor: jax.Array,  # (N, L) int32
    num_bins: int,
    num_hashes: int,
    block_n: int = 1024,
    interpret: bool = True,
    seeds=None,  # optional (num_hashes,) uint32; default arange(num_hashes)
    chunk_len=None,  # None = auto; 0 forces full unroll; >0 forces that chunk
) -> jax.Array:
    chunk = _resolve_chunk(byte_tensor.shape[1], chunk_len)
    byte_tensor, N = _padded(byte_tensor, block_n, chunk)
    Np, L = byte_tensor.shape
    seeds = _resolve_seeds(num_hashes, seeds)
    if chunk:
        out = pl.pallas_call(
            functools.partial(_kernel_chunked, num_bins=num_bins, chunk_len=chunk),
            grid=(num_hashes, Np // block_n, L // chunk),
            in_specs=[
                pl.BlockSpec((1,), lambda k, i, c: (k,)),
                pl.BlockSpec((block_n, chunk), lambda k, i, c: (i, c)),
            ],
            out_specs=pl.BlockSpec((1, block_n), lambda k, i, c: (k, i)),
            out_shape=jax.ShapeDtypeStruct((num_hashes, Np), jnp.int32),
            scratch_shapes=[
                pltpu.VMEM((1, block_n), jnp.uint32),
                pltpu.VMEM((1, block_n), jnp.uint32),
            ],
            interpret=interpret,
        )(seeds, byte_tensor)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel, num_bins=num_bins, max_len=L),
            grid=(num_hashes, Np // block_n),
            in_specs=[
                pl.BlockSpec((1,), lambda k, i: (k,)),
                pl.BlockSpec((block_n, L), lambda k, i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_n), lambda k, i: (k, i)),
            out_shape=jax.ShapeDtypeStruct((num_hashes, Np), jnp.int32),
            interpret=interpret,
        )(seeds, byte_tensor)
    return out[:, :N].T  # (N, num_hashes)


def bloom_hash_kernel_raw(
    byte_tensor: jax.Array,  # (N, L) int32
    num_hashes: int,
    block_n: int = 1024,
    interpret: bool = True,
    seeds=None,
    chunk_len=None,
):
    """Like :func:`bloom_hash_kernel` but returns the raw 64-bit hashes as
    ``(hi, lo)`` uint32 arrays of shape (N, num_hashes)."""
    chunk = _resolve_chunk(byte_tensor.shape[1], chunk_len)
    byte_tensor, N = _padded(byte_tensor, block_n, chunk)
    Np, L = byte_tensor.shape
    seeds = _resolve_seeds(num_hashes, seeds)
    out_shape = [
        jax.ShapeDtypeStruct((num_hashes, Np), jnp.uint32),
        jax.ShapeDtypeStruct((num_hashes, Np), jnp.uint32),
    ]
    if chunk:
        spec = pl.BlockSpec((1, block_n), lambda k, i, c: (k, i))
        hi, lo = pl.pallas_call(
            functools.partial(_kernel_raw_chunked, chunk_len=chunk),
            grid=(num_hashes, Np // block_n, L // chunk),
            in_specs=[
                pl.BlockSpec((1,), lambda k, i, c: (k,)),
                pl.BlockSpec((block_n, chunk), lambda k, i, c: (i, c)),
            ],
            out_specs=[spec, spec],
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.VMEM((1, block_n), jnp.uint32),
                pltpu.VMEM((1, block_n), jnp.uint32),
            ],
            interpret=interpret,
        )(seeds, byte_tensor)
    else:
        spec = pl.BlockSpec((1, block_n), lambda k, i: (k, i))
        hi, lo = pl.pallas_call(
            functools.partial(_kernel_raw, max_len=L),
            grid=(num_hashes, Np // block_n),
            in_specs=[
                pl.BlockSpec((1,), lambda k, i: (k,)),
                pl.BlockSpec((block_n, L), lambda k, i: (i, 0)),
            ],
            out_specs=[spec, spec],
            out_shape=out_shape,
            interpret=interpret,
        )(seeds, byte_tensor)
    return hi[:, :N].T, lo[:, :N].T
