"""Pallas TPU kernel: multi-seed FNV-1a-64 string hashing (bloom encoding).

TPU vector units have no 64-bit integers, so the 64-bit hash state is carried
as two uint32 limbs and the 64x64->low-64 multiply is synthesised from
16-bit sublimb products (each fits uint32 exactly).  The result is bit-exact
with the uint64 reference in ``repro.core.hashing`` — asserted by the kernel
tests — which is what guarantees the paper's offline/online parity when the
hot serving path runs this kernel while the Spark-role fit used the jnp path.

Grid: (num_hashes, N / BLOCK_N).  Each program hashes BLOCK_N strings for one
seed.  Bytes arrive as int32 (widened by ops.py: uint8 VREG lanes are wasted
on TPU anyway) in a (BLOCK_N, L) VMEM block; the L loop is a static unroll of
elementwise ops, which Mosaic maps straight onto the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FNV_OFFSET = 14695981039346656037
FNV_PRIME_HI = 0x00000100  # 0x100000001B3 >> 32
FNV_PRIME_LO = 0x000001B3

_M1 = 0xFF51AFD7ED558CCD
_M2 = 0xC4CEB9FE1A85EC53


def _u32(x):
    return jnp.uint32(x)


def _mul32_lohi(a, b):
    """32x32 -> (lo32, hi32) via 16-bit sublimbs (all intermediates < 2^32)."""
    a0 = a & _u32(0xFFFF)
    a1 = a >> _u32(16)
    b0 = b & _u32(0xFFFF)
    b1 = b >> _u32(16)
    t0 = a0 * b0
    t1 = a1 * b0 + (t0 >> _u32(16))
    t2 = a0 * b1 + (t1 & _u32(0xFFFF))
    lo = (t2 << _u32(16)) | (t0 & _u32(0xFFFF))
    hi = a1 * b1 + (t1 >> _u32(16)) + (t2 >> _u32(16))
    return lo, hi


def _mul64(ah, al, bh, bl):
    """(ah:al) * (bh:bl) mod 2^64 -> (hi, lo)."""
    lo, carry = _mul32_lohi(al, bl)
    hi = carry + al * bh + ah * bl  # mod 2^32 wraparound is exactly right
    return hi, lo


def _xor64(ah, al, bh, bl):
    return ah ^ bh, al ^ bl


def _shr64(ah, al, n: int):
    if n >= 32:
        return _u32(0), ah >> _u32(n - 32)
    return ah >> _u32(n), (al >> _u32(n)) | (ah << _u32(32 - n))


def _fmix64(h_hi, h_lo):
    for mult in (_M1, _M2, None):
        s_hi, s_lo = _shr64(h_hi, h_lo, 33)
        h_hi, h_lo = _xor64(h_hi, h_lo, s_hi, s_lo)
        if mult is not None:
            h_hi, h_lo = _mul64(h_hi, h_lo, _u32(mult >> 32), _u32(mult & 0xFFFFFFFF))
    return h_hi, h_lo


def _hash_block(seed, b, max_len: int):
    """(BLOCK_N, L) int32 bytes -> avalanched (h_hi, h_lo) uint32 limbs."""
    n = b.shape[0]
    h_hi = jnp.full((n,), _u32(FNV_OFFSET >> 32), jnp.uint32)
    h_lo = jnp.full((n,), _u32(FNV_OFFSET & 0xFFFFFFFF), jnp.uint32) ^ seed
    p_hi, p_lo = _u32(FNV_PRIME_HI), _u32(FNV_PRIME_LO)
    for i in range(max_len):
        byte = b[:, i].astype(jnp.uint32)
        x_lo = h_lo ^ byte
        n_hi, n_lo = _mul64(h_hi, x_lo, p_hi, p_lo)
        live = byte != 0  # zero padding leaves the state untouched
        h_hi = jnp.where(live, n_hi, h_hi)
        h_lo = jnp.where(live, n_lo, h_lo)
    return _fmix64(h_hi, h_lo)


def _kernel(seeds_ref, bytes_ref, out_ref, *, num_bins: int, max_len: int):
    seed = seeds_ref[0]  # uint32 seed for this program (seeds < 2^32 here)
    h_hi, h_lo = _hash_block(seed, bytes_ref[...], max_len)
    folded = h_hi ^ h_lo
    out_ref[...] = (folded % _u32(num_bins)).astype(jnp.int32)[None, :]


def _kernel_raw(seeds_ref, bytes_ref, hi_ref, lo_ref, *, max_len: int):
    """Raw-hash variant: emits the 64-bit hash as uint32 limbs (no fold/mod),
    for consumers that need the full hash (vocab searchsorted lookup)."""
    seed = seeds_ref[0]
    h_hi, h_lo = _hash_block(seed, bytes_ref[...], max_len)
    hi_ref[...] = h_hi[None, :]
    lo_ref[...] = h_lo[None, :]


def _padded(byte_tensor: jax.Array, block_n: int):
    N = byte_tensor.shape[0]
    pad = (-N) % block_n
    if pad:
        byte_tensor = jnp.pad(byte_tensor, ((0, pad), (0, 0)))
    return byte_tensor, N


def _resolve_seeds(num_hashes: int, seeds) -> jax.Array:
    if seeds is None:
        return jnp.arange(num_hashes, dtype=jnp.uint32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    assert seeds.shape == (num_hashes,)
    return seeds


def bloom_hash_kernel(
    byte_tensor: jax.Array,  # (N, L) int32
    num_bins: int,
    num_hashes: int,
    block_n: int = 1024,
    interpret: bool = True,
    seeds=None,  # optional (num_hashes,) uint32; default arange(num_hashes)
) -> jax.Array:
    byte_tensor, N = _padded(byte_tensor, block_n)
    Np, L = byte_tensor.shape
    seeds = _resolve_seeds(num_hashes, seeds)
    out = pl.pallas_call(
        functools.partial(_kernel, num_bins=num_bins, max_len=L),
        grid=(num_hashes, Np // block_n),
        in_specs=[
            pl.BlockSpec((1,), lambda k, i: (k,)),
            pl.BlockSpec((block_n, L), lambda k, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda k, i: (k, i)),
        out_shape=jax.ShapeDtypeStruct((num_hashes, Np), jnp.int32),
        interpret=interpret,
    )(seeds, byte_tensor)
    return out[:, :N].T  # (N, num_hashes)


def bloom_hash_kernel_raw(
    byte_tensor: jax.Array,  # (N, L) int32
    num_hashes: int,
    block_n: int = 1024,
    interpret: bool = True,
    seeds=None,
):
    """Like :func:`bloom_hash_kernel` but returns the raw 64-bit hashes as
    ``(hi, lo)`` uint32 arrays of shape (N, num_hashes)."""
    byte_tensor, N = _padded(byte_tensor, block_n)
    Np, L = byte_tensor.shape
    seeds = _resolve_seeds(num_hashes, seeds)
    spec = pl.BlockSpec((1, block_n), lambda k, i: (k, i))
    hi, lo = pl.pallas_call(
        functools.partial(_kernel_raw, max_len=L),
        grid=(num_hashes, Np // block_n),
        in_specs=[
            pl.BlockSpec((1,), lambda k, i: (k,)),
            pl.BlockSpec((block_n, L), lambda k, i: (i, 0)),
        ],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((num_hashes, Np), jnp.uint32),
            jax.ShapeDtypeStruct((num_hashes, Np), jnp.uint32),
        ],
        interpret=interpret,
    )(seeds, byte_tensor)
    return hi[:, :N].T, lo[:, :N].T
