"""jit'd wrapper: model layout (B,S,H,hd) -> kernel layout, interpret-mode
selection off-TPU, and a custom VJP that pairs this Pallas forward with the
rematerialising flash backward from ``repro.models.flash``."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import flash as jflash

from .flash_attention import flash_attention_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fa(q, k, v, scale, causal, window):
    # kernel layout: (B,H,S,hd) / (B,KV,T,hd)
    qk = jnp.swapaxes(q, 1, 2)
    kk = jnp.swapaxes(k, 1, 2)
    vk = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(
        qk, kk, vk, scale, causal=causal, window=window, interpret=_interpret()
    )
    return jnp.swapaxes(out, 1, 2)  # back to (B,S,H,hd)


def _fa_fwd(q, k, v, scale, causal, window):
    return _fa(q, k, v, scale, causal, window), (q, k, v)


def _fa_bwd(scale, causal, window, res, do):
    q, k, v = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd)

    # reuse the jnp flash custom-vjp backward (identical math)
    _, vjp = jax.vjp(
        lambda qq, kk, vv: jflash.flash_attention_grouped(
            qq, kk, vv, scale, causal, window, min(256, k.shape[1]), 0, k.shape[1]
        ),
        qg, k, v,
    )
    dq, dk, dv = vjp(do.reshape(qg.shape[:4] + (v.shape[-1],)))
    return dq.reshape(q.shape), dk, dv


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(
    q: jax.Array,  # (B,S,H,hd)
    k: jax.Array,  # (B,T,KV,hd)
    v: jax.Array,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    return _fa(q, k, v, scale, causal, window)
