"""Pure-jnp oracle for the flash_attention kernel: naive full-softmax
attention in the kernel's (B, H, S, hd) layout."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KV, T, hd)
    v: jax.Array,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32) * jnp.float32(scale)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", w, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)
