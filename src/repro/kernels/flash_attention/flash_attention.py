"""Pallas TPU kernel: blockwise flash attention (forward).

Grid (B, H, nQ, nK) with the KV axis innermost: TPU grids execute
sequentially per core, so the f32 VMEM scratch accumulators (m, l, acc)
persist across the nK steps of one (b, h, qi) triple — the classic TPU
flash-attention pattern.  BlockSpecs tile Q and KV into (BQ, hd) / (BK, hd)
VMEM blocks with MXU-aligned BQ/BK (multiples of 128 at production sizes);
the GQA mapping happens in the K/V index_map (kv head = h // group).

Masking (causal / local window / KV validity) is computed from
broadcasted_iota inside the kernel — no (S, S) mask tensor ever exists.
Backward runs via the jnp flash custom-VJP (``repro.models.flash``), which
the ops wrapper installs around this forward.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(-1e30)


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int], bq: int, bk: int,
    valid_len: int, n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * jnp.float32(scale)  # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < valid_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, jnp.float32(NEG_INF))

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KV, T, hd)
    v: jax.Array,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = q.shape[2], k.shape[2]
    n_k = Tp // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, valid_len=T, n_k=n_k,
        ),
        grid=(B, H, Sp // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            # m, l, acc persist across the innermost (KV) grid axis
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
