"""Pallas TPU kernel: flash-decode — single-token attention against a long
KV cache.

Grid (B, H, nK), KV blocks innermost with (1,)/(1, hd) f32 scratch carrying
the online-softmax state.  The query row is tiny; the work is streaming the
KV cache through VMEM at HBM bandwidth — this kernel exists because decode
attention is memory-bound and must not materialise (W,) score tensors in f32
HBM round-trips.  A validity mask handles rolling-window caches and
not-yet-written slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(-1e30)


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * jnp.float32(scale)  # (hd,)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = valid_ref[...] != 0  # (BK,)

    s = jnp.sum(k * q[None, :], axis=1)  # (BK,)
    s = jnp.where(valid, s, jnp.float32(NEG_INF))
    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[0] = l_scr[0] * corr + jnp.sum(p)
    acc_scr[...] = acc_scr[...] * corr + jnp.sum(p[:, None] * v, axis=0)[None]
    m_scr[0] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[0] / jnp.maximum(l_scr[0], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array,  # (B, H, hd)
    k: jax.Array,  # (B, KV, W, hd)
    v: jax.Array,
    valid: jax.Array,  # (W,) int32
    scale: float,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, H, hd = q.shape
    KV, W = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, W)
    pad = (-W) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad),))
    Wp = k.shape[2]
    n_k = Wp // bk
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_k=n_k),
        grid=(B, H, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((bk,), lambda b, h, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ki: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int32))
    return out
