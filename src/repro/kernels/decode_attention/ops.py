"""jit'd wrapper: model layout (B,1,H,hd)/(B,W,KV,hd) -> kernel layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention(q, k, v, valid, scale):
    """q (B,1,H,hd), k/v (B,W,KV,hd), valid (W,) bool -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    out = decode_attention_kernel(
        q[:, 0],
        jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2),
        valid,
        scale,
        interpret=_interpret(),
    )
    return out[:, None]
