"""Full-softmax oracle for flash-decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q, k, v, valid, scale):
    """q (B,H,hd), k/v (B,KV,W,hd), valid (W,) bool."""
    B, H, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * jnp.float32(scale)
    s = jnp.einsum("bkgh,bkwh->bkgw", qg, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bkwh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
