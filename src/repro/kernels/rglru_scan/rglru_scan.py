"""Pallas TPU kernel: RG-LRU linear recurrence  h_t = a_t h_{t-1} + x_t.

Grid (B, n_width_blocks, n_chunks), chunks innermost; the (BR,) carry scratch
persists across a row-block's chunks.  Within a chunk the recurrence is a
rolled loop of (BR,)-wide VPU ops — the GPU paper's custom linear-scan kernel
maps onto TPU as this memory-bound vector loop (see DESIGN.md hardware
adaptation notes; training uses the parallel associative scan instead, this
kernel serves chunked prefill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, h_ref, carry_scr, *, q_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0].astype(jnp.float32)  # (Q, BR)
    x = x_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + x[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, q_len, step, carry_scr[...])
    carry_scr[...] = h


def rglru_scan(
    a: jax.Array,  # (B, S, R) f32 decay in (0,1)
    x: jax.Array,  # (B, S, R) f32 pre-scaled input
    chunk: int = 128,
    block_r: int = 512,
    interpret: bool = True,
) -> jax.Array:
    B, S, R = a.shape
    Q = min(chunk, S)
    BR = min(block_r, R)
    assert S % Q == 0 and R % BR == 0
    out = pl.pallas_call(
        functools.partial(_kernel, q_len=Q),
        grid=(B, R // BR, S // Q),
        in_specs=[
            pl.BlockSpec((1, Q, BR), lambda b, r, c: (b, c, r)),
            pl.BlockSpec((1, Q, BR), lambda b, r, c: (b, c, r)),
        ],
        out_specs=pl.BlockSpec((1, Q, BR), lambda b, r, c: (b, c, r)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BR,), jnp.float32)],
        interpret=interpret,
    )(a, x)
    return out
