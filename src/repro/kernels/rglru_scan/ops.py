"""jit'd wrapper for the RG-LRU kernel (interpret off-TPU, seq padding with
identity decay so padded steps don't perturb the carry)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rglru_scan import rglru_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def rglru(a: jax.Array, x: jax.Array, chunk: int = 128) -> jax.Array:
    B, S, R = a.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    br = 512
    while R % br:
        br //= 2
    out = rglru_scan(a.astype(jnp.float32), x.astype(jnp.float32), chunk=Q, block_r=br, interpret=_interpret())
    return out[:, :S]
