"""Sequential oracle for the RG-LRU recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_sequential(a: jax.Array, x: jax.Array) -> jax.Array:
    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    h0 = jnp.zeros(a.shape[::2][0:1] + a.shape[2:], jnp.float32)  # (B, R)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
