"""Fault tolerance: heartbeats, supervised restart, straggler detection."""
from .supervisor import Heartbeat, Supervisor
from .straggler import StragglerMonitor

__all__ = ["Heartbeat", "Supervisor", "StragglerMonitor"]
