"""Fault tolerance: heartbeats, supervised restart, straggler detection."""
from .supervisor import Heartbeat, Liveness, Supervisor
from .straggler import StragglerMonitor

__all__ = ["Heartbeat", "Liveness", "Supervisor", "StragglerMonitor"]
