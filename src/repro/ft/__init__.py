"""Fault tolerance: heartbeats, supervised restart, straggler detection,
death-time resource reclamation."""
from .reclaim import DeathReclaimer
from .supervisor import Heartbeat, Liveness, Supervisor
from .straggler import StragglerMonitor

__all__ = ["DeathReclaimer", "Heartbeat", "Liveness", "Supervisor", "StragglerMonitor"]
