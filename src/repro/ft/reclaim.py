"""Death-time resource reclamation hooks, keyed by worker.

The multi-host executor owns per-worker resources beyond the socket — a
shared-memory transport's slot rings and segment — whose cleanup must run
on EVERY path a worker leaves the fleet by (ping timeout, send failure,
EOF mid-gather, rejoin replacing a silently-dead connection, orderly
close).  :class:`DeathReclaimer` centralises that: each resource owner
registers a callback under the worker's key, and the death paths call
:meth:`reclaim` exactly once per death without knowing what is behind it.

Reclaim callbacks run with error containment — a failing hook must never
abort the recovery path that invoked it (recovery is already handling one
fault; it cannot afford a second) — and reclamation is idempotent: the
callback is popped before it runs, so racing death paths (sweep vs
gather) reclaim once.  What a callback should do for a shm transport:
free the dead worker's in-flight slots (so a wedged ring never blocks a
rejoin's warmup) and unlink the pair's segment (the dead peer cannot; a
leaked name outlives both processes).  Resharding needs no slot motion —
re-homed row blocks are dispatched through the *surviving* workers'
transports, whose rings are untouched.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Hashable, Optional


class DeathReclaimer:
    """Registry of per-key cleanup callbacks, fired once on death."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hooks: Dict[Hashable, Callable[[], Any]] = {}
        self.reclaims = 0
        self.errors = 0

    def register(self, key: Hashable, hook: Callable[[], Any]) -> None:
        """(Re-)register ``key``'s cleanup; a rejoined worker's new
        transport simply replaces the old entry."""
        with self._lock:
            self._hooks[key] = hook

    def forget(self, key: Hashable) -> None:
        """Drop ``key`` without running its hook (ownership transferred,
        e.g. an orderly close that already tore the resource down)."""
        with self._lock:
            self._hooks.pop(key, None)

    def reclaim(self, key: Hashable) -> Optional[Any]:
        """Run and drop ``key``'s hook.  Returns the hook's result, or None
        when no hook is registered (already reclaimed, or nothing to do) or
        the hook itself failed — reclamation is best-effort by design."""
        with self._lock:
            hook = self._hooks.pop(key, None)
        if hook is None:
            return None
        try:
            out = hook()
        except Exception:
            self.errors += 1
            return None
        self.reclaims += 1
        return out

    def reclaim_all(self) -> int:
        """Run every remaining hook (executor shutdown); returns how many
        ran."""
        with self._lock:
            keys = list(self._hooks)
        for k in keys:
            self.reclaim(k)
        return len(keys)

    def snapshot(self) -> dict:
        with self._lock:
            registered = len(self._hooks)
        return {
            "registered": registered,
            "reclaims": self.reclaims,
            "errors": self.errors,
        }
