"""Straggler detection: per-step wall-time EWMA with outlier flagging.

On a real pod every host reports its step time; the controller flags hosts
whose EWMA exceeds the fleet median by a threshold factor (then drains or
deprioritises them).  The monitor below implements the statistics and the
policy hook; the launcher wires it to per-step timings (and, multi-host, to
per-host heartbeat metadata).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional


class StragglerMonitor:
    def __init__(
        self,
        alpha: float = 0.1,
        threshold: float = 1.5,
        warmup_steps: int = 5,
        on_straggler: Optional[Callable[[str, float, float], None]] = None,
    ):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Dict[str, float] = {}
        self.count: Dict[str, int] = {}
        self.flagged: List[str] = []
        self._t0: Optional[float] = None

    # -- single-host convenience: time the local step -------------------
    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self, rank: str = "rank0") -> float:
        dt = time.perf_counter() - (self._t0 or time.perf_counter())
        self.report(rank, dt)
        return dt

    # -- fleet interface --------------------------------------------------
    def report(self, rank: str, step_time: float) -> None:
        prev = self.ewma.get(rank)
        self.ewma[rank] = step_time if prev is None else (
            self.alpha * step_time + (1 - self.alpha) * prev
        )
        self.count[rank] = self.count.get(rank, 0) + 1
        self._check(rank)

    def clear(self, rank: str) -> None:
        """Un-flag ``rank`` (it caught up, or its hedge lost the race).  Its
        EWMA keeps accumulating; a still-slow rank re-flags on its next
        report."""
        if rank in self.flagged:
            self.flagged.remove(rank)

    def forget(self, rank: str) -> None:
        """Drop ``rank`` entirely (a restarted worker is a NEW population:
        its old EWMA must not seed the fresh process's statistics, and a
        stale flag must not hedge against a healthy restart)."""
        self.ewma.pop(rank, None)
        self.count.pop(rank, None)
        self.clear(rank)

    def _warm_ranks(self) -> List[str]:
        return [r for r in self.ewma if self.count.get(r, 0) >= self.warmup]

    def _median(self) -> float:
        """Fleet median over WARM ranks only.  Warmup is counted per rank, so
        in a heterogeneous fleet a late joiner's first (cold, typically slow:
        compile + cache fill) EWMA must not enter the reference statistic —
        mixing it in skewed the median and could false-flag healthy peers.
        Even counts take the true median (mean of the middle two): the old
        upper-middle shortcut made a 2-rank fleet's median equal to its
        slowest member, so a 2-rank fleet could never flag anything."""
        vals = sorted(self.ewma[r] for r in self._warm_ranks())
        if not vals:
            return 0.0
        mid = len(vals) // 2
        if len(vals) % 2:
            return vals[mid]
        return 0.5 * (vals[mid - 1] + vals[mid])

    def _check(self, rank: str) -> None:
        if self.count[rank] < self.warmup or len(self.ewma) == 0:
            return
        med = self._median()
        if med > 0 and self.ewma[rank] > self.threshold * med and rank not in self.flagged:
            self.flagged.append(rank)
            if self.on_straggler:
                self.on_straggler(rank, self.ewma[rank], med)

    def summary(self) -> dict:
        return {
            "ewma": dict(self.ewma),
            "median": self._median(),
            "warm": self._warm_ranks(),
            "flagged": list(self.flagged),
        }
