"""Straggler detection: per-step wall-time EWMA with outlier flagging.

On a real pod every host reports its step time; the controller flags hosts
whose EWMA exceeds the fleet median by a threshold factor (then drains or
deprioritises them).  The monitor below implements the statistics and the
policy hook; the launcher wires it to per-step timings (and, multi-host, to
per-host heartbeat metadata).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional


class StragglerMonitor:
    def __init__(
        self,
        alpha: float = 0.1,
        threshold: float = 1.5,
        warmup_steps: int = 5,
        on_straggler: Optional[Callable[[str, float, float], None]] = None,
    ):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Dict[str, float] = {}
        self.count: Dict[str, int] = {}
        self.flagged: List[str] = []
        self._t0: Optional[float] = None

    # -- single-host convenience: time the local step -------------------
    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self, rank: str = "rank0") -> float:
        dt = time.perf_counter() - (self._t0 or time.perf_counter())
        self.report(rank, dt)
        return dt

    # -- fleet interface --------------------------------------------------
    def report(self, rank: str, step_time: float) -> None:
        prev = self.ewma.get(rank)
        self.ewma[rank] = step_time if prev is None else (
            self.alpha * step_time + (1 - self.alpha) * prev
        )
        self.count[rank] = self.count.get(rank, 0) + 1
        self._check(rank)

    def _median(self) -> float:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def _check(self, rank: str) -> None:
        if self.count[rank] < self.warmup or len(self.ewma) == 0:
            return
        med = self._median()
        if med > 0 and self.ewma[rank] > self.threshold * med and rank not in self.flagged:
            self.flagged.append(rank)
            if self.on_straggler:
                self.on_straggler(rank, self.ewma[rank], med)

    def summary(self) -> dict:
        return {
            "ewma": dict(self.ewma),
            "median": self._median(),
            "flagged": list(self.flagged),
        }
