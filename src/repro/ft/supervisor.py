"""Supervised training with heartbeat-based failure detection and automatic
restart-from-latest-checkpoint.

At pod scale the control plane watches per-host heartbeats and reschedules the
job on failure; this module implements that control plane faithfully at
process granularity: the trainer stamps a heartbeat file every step, the
supervisor kills/restarts the trainer when the heartbeat goes stale or the
process dies, and the trainer resumes from the newest committed checkpoint
(see repro.ckpt — atomic manifests make "newest" always loadable).
"""
from __future__ import annotations

import json
import os
import pathlib
import signal
import subprocess
import sys
import time
from typing import List, Optional


class Heartbeat:
    """Trainer side: stamp liveness + step metadata."""

    def __init__(self, path: str):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int, **info) -> None:
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"t": time.time(), "step": step, **info}))
        tmp.rename(self.path)

    def read(self) -> Optional[dict]:
        try:
            return json.loads(self.path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None


class Liveness:
    """In-memory heartbeat staleness tracker — the socket-tier analogue of
    :class:`Heartbeat`'s file stamps, with the same semantics the supervisor
    applies to them: a beat refreshes liveness, and staleness beyond the
    timeout means the peer is presumed down.

    The serving coordinator keeps one per shard worker: every shard reply
    (and every answered idle ping) calls :meth:`beat`; :meth:`state` derives
    ``healthy`` (age <= timeout), ``suspect`` (one missed window — the peer
    may merely be slow) or ``dead`` (two missed windows) so callers can
    distinguish "hedge against it" from "reshard around it".  Clock is
    injectable for fake-clock tests.
    """

    def __init__(self, timeout_s: float, clock=time.monotonic):
        self.timeout_s = float(timeout_s)
        self._clock = clock
        self.last = clock()

    def beat(self) -> None:
        self.last = self._clock()

    def age(self) -> float:
        return self._clock() - self.last

    def state(self) -> str:
        age = self.age()
        if age <= self.timeout_s:
            return "healthy"
        if age <= 2 * self.timeout_s:
            return "suspect"
        return "dead"


class Supervisor:
    """Run a trainer command under failure supervision.

    Restarts on: process exit with non-zero status, or heartbeat older than
    ``timeout_s``.  Gives up after ``max_restarts`` (a real deployment would
    also drain/replace the node here).
    """

    def __init__(
        self,
        cmd: List[str],
        heartbeat_path: str,
        timeout_s: float = 60.0,
        max_restarts: int = 3,
        env: Optional[dict] = None,
    ):
        self.cmd = cmd
        self.hb = Heartbeat(heartbeat_path)
        self.timeout_s = timeout_s
        self.max_restarts = max_restarts
        self.env = env
        self.restarts = 0
        self.log: List[str] = []

    def _spawn(self) -> subprocess.Popen:
        env = dict(os.environ)
        if self.env:
            env.update(self.env)
        return subprocess.Popen(self.cmd, env=env)

    def run(self, poll_s: float = 1.0) -> int:
        """Supervise until clean exit (0) or restart budget exhausted."""
        while True:
            proc = self._spawn()
            self.log.append(f"spawned pid={proc.pid} (restart {self.restarts})")
            failed = False
            while True:
                rc = proc.poll()
                if rc is not None:
                    if rc == 0:
                        self.log.append("clean exit")
                        return 0
                    self.log.append(f"process died rc={rc}")
                    failed = True
                    break
                hb = self.hb.read()
                if hb is not None and time.time() - hb["t"] > self.timeout_s:
                    self.log.append(
                        f"heartbeat stale ({time.time() - hb['t']:.1f}s) — killing pid={proc.pid}"
                    )
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    failed = True
                    break
                time.sleep(poll_s)
            if failed:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    self.log.append("restart budget exhausted")
                    return 1
