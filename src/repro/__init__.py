"""repro — Kamae-on-JAX: train/serve-parity preprocessing + multi-pod LM framework.

x64 is enabled globally: the core preprocessing layer hashes strings with
64-bit FNV-1a (collision-free vocabularies at data-lake cardinalities).
All model/training code passes explicit dtypes and is unaffected.
"""
import jax as _jax

_jax.config.update("jax_enable_x64", True)
