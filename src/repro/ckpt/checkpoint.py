"""Fault-tolerant checkpointing for sharded train state.

Design (scaled-down faithfully from multi-host practice to this single-process
environment; the host-sharding seams are kept explicit):

* one ``.npy`` file per pytree leaf (per host-shard in multi-host: each host
  writes ``<leaf>.shard<k>`` of its addressable shards — here k=0 covers all);
* a msgpack+zstd MANIFEST holding the tree structure, dtypes, shapes, step and
  integrity digests; written LAST and committed by atomic rename, so a crash
  mid-save can never yield a manifest pointing at missing leaves;
* ``save_async`` runs device_get + file IO on a background thread, overlapping
  the next training steps (standard async-checkpoint overlap trick);
* ``load`` takes target SHARDINGS, enabling ELASTIC restarts: a checkpoint
  written on one mesh restores onto a different mesh/device count — leaves are
  read on host and device_put with the new sharding;
* retention: keep the newest ``keep`` checkpoints, never deleting the one a
  restore just came from.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np
import zlib

_MANIFEST = "MANIFEST.zst"

# ``zstandard``/``msgpack`` are optional: lazy-import with a stdlib
# (zlib+json) fallback, flagged by a 2-byte header so either build can read
# manifests written by the other.  Headerless blobs are legacy zstd+msgpack.
_MAN_MAGIC_ZSTD = b"\x01Z"
_MAN_MAGIC_ZLIB = b"\x01G"


def _pack_manifest(manifest: dict) -> bytes:
    try:
        import msgpack
        import zstandard

        return _MAN_MAGIC_ZSTD + zstandard.ZstdCompressor().compress(
            msgpack.packb(manifest)
        )
    except ImportError:
        return _MAN_MAGIC_ZLIB + zlib.compress(
            json.dumps(manifest).encode("utf-8"), 6
        )


def _unpack_manifest(blob: bytes) -> dict:
    if blob[:2] == _MAN_MAGIC_ZLIB:
        return json.loads(zlib.decompress(blob[2:]).decode("utf-8"))
    if blob[:2] == _MAN_MAGIC_ZSTD:
        blob = blob[2:]
    import msgpack
    import zstandard

    return msgpack.unpackb(zstandard.ZstdDecompressor().decompress(blob))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous sharded save; returns the committed checkpoint path."""
    root = pathlib.Path(directory)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.shard0.npy"
        np.save(tmp / fn, arr, allow_pickle=False)
        manifest["leaves"].append(
            {
                "file": fn,
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "digest": hashlib.sha1(arr.tobytes()).hexdigest()[:16],
            }
        )
    blob = _pack_manifest(manifest)
    with open(tmp / _MANIFEST, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    _retain(root, keep)
    return str(final)


def _retain(root: pathlib.Path, keep: int):
    ckpts = sorted(p for p in root.glob("step_*") if p.is_dir())
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    root = pathlib.Path(directory)
    ckpts = sorted(root.glob("step_*"))
    for p in reversed(ckpts):
        if (p / _MANIFEST).exists():
            return int(p.name.split("_")[1])
    return None


def load_checkpoint(
    directory: str,
    tree_like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    verify: bool = True,
) -> Any:
    """Restore onto the CURRENT mesh (elastic: shardings may differ from the
    ones the checkpoint was written with)."""
    root = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = root / f"step_{step:08d}"
    manifest = _unpack_manifest((path / _MANIFEST).read_bytes())
    leaves_meta = manifest["leaves"]
    ref_leaves, treedef = _flatten(tree_like)
    if len(ref_leaves) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves, target tree {len(ref_leaves)}"
        )
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(ref_leaves)
    )
    out = []
    for meta, ref, sh in zip(leaves_meta, ref_leaves, shard_leaves):
        arr = np.load(path / meta["file"], allow_pickle=False)
        if verify and hashlib.sha1(arr.tobytes()).hexdigest()[:16] != meta["digest"]:
            raise IOError(f"digest mismatch in {meta['file']} (corrupt checkpoint)")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpointing with bounded in-flight saves."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def worker():
            try:
                save_checkpoint(self.directory, step, host_tree, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, tree_like, shardings=None):
        return load_checkpoint(self.directory, tree_like, shardings=shardings)
