"""Shared model substrate: parameter definitions with logical sharding axes,
norms, rotary embeddings, embedding tables and dtype policy.

Parameters live in a FLAT dict ``{"path/to/param": array}`` (a valid pytree).
Each model declares :class:`ParamDef`s carrying *logical* axis names
("embed", "heads", "mlp", "vocab", "expert", "layer", ...); mesh rules map
logical axes to mesh axes, giving every param a PartitionSpec.  This is the
MaxText-style logical-axis pattern, chosen so one model definition serves the
single-CPU tests, the 16x16 pod and the 2x16x16 multi-pod mesh unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, jax.Array]


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` where available (jax >= 0.5),
    falling back to the private 0.4.x location.  Returns None when no
    abstract mesh is active (0.4.x exposes the raw thread-local, whose unset
    value is not an AbstractMesh)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh

    m = _mesh.get_abstract_mesh()
    return m if isinstance(m, _mesh.AbstractMesh) else None

# logical axis -> mesh axis (None = replicated).  "embed"-like axes use the
# data axis as an FSDP axis; head/mlp/vocab/expert axes are tensor-parallel.
DEFAULT_RULES: Dict[str, Any] = {
    "embed": "data",     # FSDP
    "heads": "model",    # TP
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",   # EP
    "expert_mlp": None,
    "conv": None,
    "state": "model",
    "layer": None,       # scan axis, never sharded
    None: None,
}


# activation logical axes (mutable: the launcher widens "batch" to
# ("pod","data") on the multi-pod mesh)
ACT_RULES: Dict[str, Any] = {"batch": ("data",), "act_model": "model"}


def set_batch_axes(axes) -> None:
    ACT_RULES["batch"] = tuple(axes) if not isinstance(axes, str) else (axes,)


def constrain(x: jax.Array, *logical: Any) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op outside a mesh."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or not mesh.axis_names:
        return x
    spec = []
    for a in logical:
        r = ACT_RULES.get(a, DEFAULT_RULES.get(a, None)) if isinstance(a, str) else a
        spec.append(r)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | embed | truncated_fan_in
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Defs = Dict[str, ParamDef]


def _fan_in(shape: Tuple[int, ...]) -> int:
    # weights are stored (in_dim..., out_dim); fan-in = prod of all but last
    return max(int(jnp.prod(jnp.asarray(shape[:-1]))), 1) if len(shape) > 1 else shape[0]


def init_params(defs: Defs, seed: int = 0) -> Params:
    """Deterministic per-param init: rng folded from the param path hash."""
    out: Params = {}
    root = jax.random.PRNGKey(seed)
    for path in sorted(defs):
        d = defs[path]
        key = jax.random.fold_in(root, hash(path) & 0x7FFFFFFF)
        if d.init == "zeros":
            v = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, d.dtype)
        elif d.init == "embed":
            v = jax.random.normal(key, d.shape, d.dtype) * (d.scale or 1.0)
        else:  # fan-in scaled normal
            std = d.scale / math.sqrt(_fan_in(d.shape))
            v = jax.random.normal(key, d.shape, d.dtype) * std
        out[path] = v
    return out


def abstract_params(defs: Defs) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins — used by the dry-run (no allocation)."""
    return {p: jax.ShapeDtypeStruct(d.shape, d.dtype) for p, d in defs.items()}


def param_pspecs(defs: Defs, rules: Optional[Dict[str, Any]] = None) -> Dict[str, P]:
    rules = {**DEFAULT_RULES, **(rules or {})}
    out = {}
    for path, d in defs.items():
        out[path] = P(*[rules.get(a, None) for a in d.axes])
    return out


def legalize_pspec(shape, spec: P, mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (jit in_shardings
    require divisibility; inside the graph WSC re-applies padded sharding)."""
    sizes = dict(mesh.shape)
    out = []
    for i, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(ax if shape[i] % n == 0 else None)
    return P(*out)


def legalize_tree(abstract_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda a, s: legalize_pspec(a.shape, s, mesh), abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def stack_defs(defs: Defs, n: int, prefix: str) -> Defs:
    """Stack per-layer defs along a leading scan ('layer') axis."""
    return {
        f"{prefix}/{p}": ParamDef((n,) + d.shape, ("layer",) + d.axes, d.init, d.scale, d.dtype)
        for p, d in defs.items()
    }


def subtree(params: Params, prefix: str) -> Params:
    pre = prefix + "/"
    return {p[len(pre):]: v for p, v in params.items() if p.startswith(pre)}


def layer_slice(stacked: Params) -> Params:
    """Inside lax.scan: stacked params arrive already sliced (leading axis
    consumed by scan); identity helper for readability."""
    return stacked


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0, rotary_dim: Optional[int] = None
) -> jax.Array:
    """Rotary embedding; x: (..., seq, heads, head_dim), positions: (..., seq)."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    half = rd // 2
    freq = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rd].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if rd < hd:
        out = jnp.concatenate([out, x[..., rd:]], axis=-1)
    return out


def embed_lookup(table: jax.Array, ids: jax.Array, compute_dtype) -> jax.Array:
    """Vocab-sharded embedding lookup (gather; SPMD turns it into
    dynamic-slice + all-reduce under a "vocab"->model sharding)."""
    return table.astype(compute_dtype)[ids]


def unembed_logits(x: jax.Array, table: jax.Array, valid_vocab: Optional[int] = None) -> jax.Array:
    """Tied unembedding: (..., D) x (V, D)^T -> (..., V), fp32 logits.
    Rows beyond ``valid_vocab`` (vocab padding for TP divisibility) are
    masked to -inf so they never receive probability mass."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
    V = table.shape[0]
    if valid_vocab is not None and valid_vocab < V:
        logits = logits + jnp.where(jnp.arange(V) < valid_vocab, 0.0, -1e30).astype(jnp.float32)
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions; logits fp32 (possibly vocab-sharded)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def causal_mask(q_len: int, kv_len: int, q_offset=0, window: Optional[int] = None) -> jax.Array:
    """(q_len, kv_len) bool mask; optionally banded for local attention."""
    q_pos = jnp.arange(q_len) + q_offset
    k_pos = jnp.arange(kv_len)
    m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m
