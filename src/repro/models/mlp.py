"""Feed-forward blocks: SwiGLU / GELU MLPs and fine-grained MoE
(DeepSeek-style shared + routed experts, top-k softmax gating, sort-based
capacity dispatch — the TPU-native, static-shape formulation).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import common as C
from .common import ParamDef as PD


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------

def swiglu_defs(cfg, d_ff: Optional[int] = None) -> C.Defs:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wg": PD((D, F), ("embed", "mlp")),
        "wu": PD((D, F), ("embed", "mlp")),
        "wd": PD((F, D), ("mlp", "embed")),
    }


def swiglu(p: C.Params, x: jax.Array) -> jax.Array:
    g = C.dense(x, p["wg"])
    u = C.dense(x, p["wu"])
    return C.dense(jax.nn.silu(g) * u, p["wd"])


def gelu_mlp_defs(cfg, d_ff: Optional[int] = None) -> C.Defs:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": PD((D, F), ("embed", "mlp")),
        "b1": PD((F,), ("mlp",), init="zeros"),
        "w2": PD((F, D), ("mlp", "embed")),
        "b2": PD((D,), ("embed",), init="zeros"),
    }


def gelu_mlp(p: C.Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(C.dense(x, p["w1"], p["b1"]), approximate=True)
    return C.dense(h, p["w2"], p["b2"])


# ---------------------------------------------------------------------------
# fine-grained MoE (DeepSeekMoE / DeepSeek-V2)
# ---------------------------------------------------------------------------

def moe_defs(cfg) -> C.Defs:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_routed_experts
    defs = {
        "router": PD((D, E), ("embed", None), scale=0.1),
        "wg": PD((E, D, F), ("expert", "embed", "expert_mlp")),
        "wu": PD((E, D, F), ("expert", "embed", "expert_mlp")),
        "wd": PD((E, F, D), ("expert", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs.update(
            {
                "shared/wg": PD((D, Fs), ("embed", "mlp")),
                "shared/wu": PD((D, Fs), ("embed", "mlp")),
                "shared/wd": PD((Fs, D), ("mlp", "embed")),
            }
        )
    return defs


def moe_block(p: C.Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """MoE entry point: picks the shard_map all-to-all path when running
    under a mesh with a "model" axis (the production EP formulation), else
    the single-device sort-based path below."""
    mesh = C.get_abstract_mesh()
    if (
        getattr(cfg, "moe_shard_map", True)
        and mesh is not None
        and not mesh.empty
        and "model" in mesh.axis_names
    ):
        bt = C.ACT_RULES.get("batch", ("data",))
        ndata = 1
        for a in bt:
            ndata *= mesh.shape.get(a, 1)
        tp = mesh.shape["model"]
        if x.shape[0] % ndata == 0 and cfg.n_routed_experts % tp == 0:
            return moe_block_a2a(p, x, cfg, mesh)
    return moe_block_global(p, x, cfg)


def moe_block_global(p: C.Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Sort-based dispatch:

      tokens -> top-k experts -> argsort(expert id) -> capacity-bounded
      scatter into an (E, C, D) buffer sharded over the EP axis -> per-expert
      SwiGLU einsum -> gather back, gate-weighted combine.

    All shapes static; the (tokens->buffer) scatter is where SPMD emits the
    EP all-to-all.  aux_loss is the standard load-balance loss.
    """
    B, S, D = x.shape
    E, K = cfg.n_routed_experts, cfg.moe_top_k
    F = cfg.moe_d_ff
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (T, K)
    if cfg.moe_norm_top_k:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    onehot_top = jnp.zeros((T, E), probs.dtype).at[jnp.arange(T)[:, None], gate_idx].set(1.0)
    fe = jnp.mean(onehot_top, axis=0) / K
    aux = jnp.sum(me * fe) * E * cfg.moe_aux_coef

    # ---- sort-based dispatch -------------------------------------------
    C_cap = int(math.ceil(T * K / E * cfg.moe_capacity_factor))
    flat_e = gate_idx.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    token_of = order // K  # source token per sorted slot
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]
    keep = pos_in_e < C_cap

    buf_idx = sorted_e * C_cap + pos_in_e  # (T*K,)
    buf_idx = jnp.where(keep, buf_idx, E * C_cap)  # dropped
    buf = jnp.zeros((E * C_cap, D), x.dtype).at[buf_idx].set(xt[token_of], mode="drop")
    buf = buf.reshape(E, C_cap, D)
    buf = C.constrain(buf, "expert", None, None)

    # ---- expert computation (einsum over the expert axis) ----------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype))
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wd"].astype(x.dtype))
    eo = C.constrain(eo, "expert", None, None)

    # ---- combine -----------------------------------------------------------
    gathered = eo.reshape(E * C_cap, D)[jnp.clip(buf_idx, 0, E * C_cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_sorted = gate_vals.reshape(-1)[order]  # gate weight per sorted slot
    contrib = gathered * w_sorted[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[token_of].add(contrib)

    if cfg.n_shared_experts:
        out = out + swiglu(C.subtree(p, "shared"), xt)
    return out.reshape(B, S, D), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# EP all-to-all MoE (shard_map): the production formulation
# ---------------------------------------------------------------------------
#
# The pjit/global formulation above leaves dispatch to GSPMD, which lowers the
# cross-sharding sort+scatter as full rematerialisations (measured: 54 TB/dev
# of all-reduce on deepseek-v2 train_4k — see EXPERIMENTS.md §Perf).  The
# fix is the standard expert-parallel schedule, written explicitly:
#
#   per device: local top-k -> local sort -> (E, C_loc, D) buffer
#   all_to_all over the EP ("model") axis      [dispatch]
#   local expert FFN einsum
#   all_to_all back                            [combine]
#   local gate-weighted sum
#
# Tokens never cross the data axis; the only collectives are two A2As of the
# capacity buffer plus one psum for the shared expert.


def _local_dispatch(xt, gate_idx, E, K, cap):
    """Sort-based capacity dispatch over LOCAL tokens (all ops local)."""
    Tl, D = xt.shape
    flat_e = gate_idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    token_of = order // K
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(Tl * K) - starts[sorted_e]
    keep = pos_in_e < cap
    buf_idx = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)
    buf = jnp.zeros((E * cap, D), xt.dtype).at[buf_idx].set(xt[token_of], mode="drop")
    return buf.reshape(E, cap, D), buf_idx, token_of, keep, order


def moe_block_a2a(p: C.Params, x: jax.Array, cfg, mesh) -> Tuple[jax.Array, jax.Array]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    E, K, F = cfg.n_routed_experts, cfg.moe_top_k, cfg.moe_d_ff
    tp = mesh.shape["model"]
    E_loc = E // tp
    bt = C.ACT_RULES.get("batch", ("data",))
    B, S, D = x.shape

    def local_fn(router, wg, wu, wd, sh_g, sh_u, sh_d, x_loc):
        Bl, Sl, _ = x_loc.shape
        Tl = Bl * Sl
        xt = x_loc.reshape(Tl, D)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        if cfg.moe_norm_top_k:
            gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        # load-balance aux (local stats, averaged across the fleet)
        me = jnp.mean(probs, axis=0)
        onehot = jnp.zeros((Tl, E), probs.dtype).at[jnp.arange(Tl)[:, None], gate_idx].set(1.0)
        fe = jnp.mean(onehot, axis=0) / K
        aux = jnp.sum(me * fe) * E * cfg.moe_aux_coef
        for ax in bt + ("model",):
            aux = jax.lax.pmean(aux, ax)

        cap = int(math.ceil(Tl * K / E * cfg.moe_capacity_factor))
        buf, buf_idx, token_of, keep, order = _local_dispatch(xt, gate_idx, E, K, cap)

        # ---- dispatch A2A: (E, cap, D) -> (E_loc, tp*cap, D) --------------
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)

        # ---- local expert FFN ------------------------------------------------
        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(x_loc.dtype))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(x_loc.dtype))
        eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(x_loc.dtype))

        # ---- combine A2A back: (E_loc, tp*cap, D) -> (E, cap, D) -----------
        back = jax.lax.all_to_all(eo, "model", split_axis=1, concat_axis=0, tiled=True)

        # ---- local gate-weighted combine ------------------------------------
        flat = back.reshape(E * cap, D)
        gathered = flat[jnp.clip(buf_idx, 0, E * cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        w_sorted = gate_vals.reshape(-1)[order]
        out = jnp.zeros((Tl, D), x_loc.dtype).at[token_of].add(
            gathered * w_sorted[:, None].astype(x_loc.dtype)
        )

        # ---- shared experts: computed locally on this shard's tokens.
        # (A Megatron partial+psum split of Fs would mix token sets here,
        # because the sequence axis is itself sharded over "model".)
        if cfg.n_shared_experts:
            hg = jnp.einsum("td,df->tf", xt, sh_g.astype(x_loc.dtype))
            hu = jnp.einsum("td,df->tf", xt, sh_u.astype(x_loc.dtype))
            out = out + jnp.einsum(
                "tf,fd->td", jax.nn.silu(hg) * hu, sh_d.astype(x_loc.dtype)
            )
        return out.reshape(Bl, Sl, D), aux

    # batch over data axes; seq over TP (sequence-parallel form) when it
    # divides (training/prefill), else replicated over TP (decode, S=1)
    xspec = P(bt, "model" if S % tp == 0 else None, None)
    shared_specs = (
        (P(None, None), P(None, None), P(None, None))  # replicated at boundary
        if cfg.n_shared_experts
        else (P(), P(), P())
    )
    sh_args = (
        (p["shared/wg"], p["shared/wu"], p["shared/wd"])
        if cfg.n_shared_experts
        else (jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
    )
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(None, None),  # router: replicated (gathered at the boundary)
            P("model", None, None),  # routed experts: EP-sharded
            P("model", None, None),
            P("model", None, None),
            *shared_specs,
            xspec,
        ),
        out_specs=(xspec, P()),
        check_rep=False,
    )
    return fn(p["router"], p["wg"], p["wu"], p["wd"], *sh_args, x)
