"""Mamba2 (state-space duality) block.

Training path = the chunked SSD algorithm in pure einsum form (quadratic
within a chunk, linear across chunks) — this is the REAL algorithm, so the
dry-run's HLO FLOPs are faithful; ``repro.kernels.ssd_scan`` provides the
Pallas-tiled version with identical semantics, and ``ref.py`` the sequential
recurrence oracle.  Decode path = constant-size recurrent state (the whole
point of the architecture for long_500k).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import common as C
from .common import ParamDef as PD


def mamba_defs(cfg) -> C.Defs:
    D = cfg.d_model
    DI = cfg.d_inner
    H = cfg.ssm_nheads
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    conv_dim = DI + 2 * G * N
    return {
        # order: [z (DI), x (DI), B (G*N), C (G*N), dt (H)]
        "in_proj": PD((D, 2 * DI + 2 * G * N + H), ("embed", "mlp")),
        "conv_w": PD((cfg.conv_width, conv_dim), ("conv", "mlp")),
        "conv_b": PD((conv_dim,), ("mlp",), init="zeros"),
        "A_log": PD((H,), ("heads",), init="zeros"),
        "dt_bias": PD((H,), ("heads",), init="zeros"),
        "D": PD((H,), ("heads",), init="ones"),
        "norm": PD((DI,), ("mlp",), init="ones"),
        "out_proj": PD((DI, D), ("mlp", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    DI, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :DI]
    x = zxbcdt[..., DI : 2 * DI]
    Bm = zxbcdt[..., 2 * DI : 2 * DI + G * N]
    Cm = zxbcdt[..., 2 * DI + G * N : 2 * DI + 2 * G * N]
    dt = zxbcdt[..., 2 * DI + 2 * G * N :]
    return z, x, Bm, Cm, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width K: u (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(K):
        out = out + pad[:, i : i + u.shape[1], :] * w[i].astype(u.dtype)
    return jax.nn.silu(out + b.astype(u.dtype))


def _segsum(x: jax.Array) -> jax.Array:
    """L[i,j] = sum_{k=j+1..i} x[k] for i>=j (chunk-local decay exponents)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD (arXiv:2405.21060 Listing 1, einsum form).

    x: (b,s,h,p) dt: (b,s,h) A: (h,) Bm/Cm: (b,s,g,n) with heads h = g*rep.
    Returns y (b,s,h,p).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[-2], Bm.shape[-1]
    Q = chunk
    nc = s // Q
    rep = h // g

    xr = x.reshape(b, nc, Q, h, p)
    dtr = dt.reshape(b, nc, Q, h)
    Br = jnp.repeat(Bm.reshape(b, nc, Q, g, n), rep, axis=3)  # (b,c,q,h,n)
    Cr = jnp.repeat(Cm.reshape(b, nc, Q, g, n), rep, axis=3)

    dA = dtr * A[None, None, None, :]  # (b,c,q,h) negative
    dA_cs = jnp.cumsum(dA, axis=2)  # within-chunk inclusive cumsum

    # 1) intra-chunk (quadratic in Q)
    L = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))  # (b,c,h,q,q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cr, Br)  # (b,c,h,q,k)
    M = CB * L
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtr, xr)

    # 2) per-chunk final states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,q,h)
    S = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchnp", Br, decay_to_end, dtr, xr)

    # 3) inter-chunk recurrence over the (few) chunks
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (b,c,h)

    def step(prev, inp):
        dec, s_c = inp
        new = prev * dec[..., None, None] + s_c
        return new, prev

    _, S_prev = jax.lax.scan(
        step,
        jnp.zeros((b, h, n, p), x.dtype),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S, 1, 0)),
    )
    S_prev = jnp.moveaxis(S_prev, 0, 1)  # (b,c,h,n,p) state entering each chunk

    # 4) inter-chunk contribution
    state_decay = jnp.exp(dA_cs)  # (b,c,q,h)
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Cr, S_prev, state_decay)

    return (y_diag + y_off).reshape(b, s, h, p)


def mamba_block(p: C.Params, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence (training / prefill) Mamba2 block."""
    H, P, N, G = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    B_, S, _ = x.shape
    zxbcdt = C.dense(x, p["in_proj"])
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = (
        conv_out[..., : cfg.d_inner],
        conv_out[..., cfg.d_inner : cfg.d_inner + G * N],
        conv_out[..., cfg.d_inner + G * N :],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B_, S, H, P)
    if cfg.use_pallas:
        from repro.kernels.ssd_scan import ops as sops

        y = sops.ssd(xh, dt, A, Bm.reshape(B_, S, G, N), Cm.reshape(B_, S, G, N), cfg.ssm_chunk)
    else:
        Q = min(cfg.ssm_chunk, S)
        pad = (-S) % Q
        xp, dtp = xh, dt
        Bp, Cp = Bm.reshape(B_, S, G, N), Cm.reshape(B_, S, G, N)
        if pad:  # causal: trailing pad steps never influence real outputs
            xp = jnp.pad(xp, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cp = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y = ssd_chunked(
            xp.astype(jnp.float32),
            dtp,
            A,
            Bp.astype(jnp.float32),
            Cp.astype(jnp.float32),
            Q,
        ).astype(x.dtype)[:, :S]
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, cfg.d_inner)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = C.rms_norm(y * jax.nn.silu(z), p["norm"])
    return C.dense(y, p["out_proj"])


def mamba_cache_init(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.ssm_nheads, N, cfg.ssm_headdim), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def mamba_decode(p, x, cache, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: O(1) state update — no KV growth at 524k context."""
    H, P, N, G = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    B_ = x.shape[0]
    zxbcdt = C.dense(x, p["in_proj"])  # (B,1,*)
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]  # (B,C)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    )
    xs = conv_out[..., : cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner : cfg.d_inner + G * N]
    Cm = conv_out[..., cfg.d_inner + G * N :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)  # (B,H)
    xh = xs.reshape(B_, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, 1, cfg.d_inner).astype(x.dtype)
    y = C.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = C.dense(y, p["out_proj"])
    return out, {"conv": hist[:, 1:], "state": state, "pos": cache["pos"] + 1}
