"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, d_model); the transformer backbone
(24L encoder + 24L decoder for whisper-medium, LayerNorm, GELU MLPs, learned
decoder positions, sinusoidal encoder positions) is implemented fully.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import common as C
from . import mlp as M
from .common import ParamDef as PD
from .lm import _norm, _norm_defs, _prefixed, _sub


def _sinusoid(length: int, channels: int) -> jnp.ndarray:
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _enc_block_defs(cfg) -> C.Defs:
    d: C.Defs = {}
    d.update(_norm_defs(cfg, "ln1"))
    d.update(_norm_defs(cfg, "ln2"))
    d.update(_prefixed(A.cross_defs(cfg), "attn"))  # same shape as full self-attn
    d.update(_prefixed(M.gelu_mlp_defs(cfg), "mlp"))
    return d


def _dec_block_defs(cfg) -> C.Defs:
    d: C.Defs = {}
    for n in ("ln1", "ln2", "ln3"):
        d.update(_norm_defs(cfg, n))
    d.update(_prefixed(A.gqa_defs(cfg), "self"))
    d.update(_prefixed(A.cross_defs(cfg), "cross"))
    d.update(_prefixed(M.gelu_mlp_defs(cfg), "mlp"))
    return d


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------
    def defs(self) -> C.Defs:
        cfg = self.cfg
        self.pv = -(-cfg.vocab // 256) * 256
        d: C.Defs = {
            "embed": PD((self.pv, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02),
            "dec_pos": PD((cfg.max_target_len, cfg.d_model), (None, "embed"), init="embed", scale=0.01),
        }
        d.update(_norm_defs(cfg, "enc_final"))
        d.update(_norm_defs(cfg, "dec_final"))
        d.update(C.stack_defs(_enc_block_defs(cfg), cfg.enc_layers, "enc"))
        d.update(C.stack_defs(_dec_block_defs(cfg), cfg.n_layers, "dec"))
        return d

    def init(self, seed: int = 0) -> C.Params:
        return C.init_params(self.defs(), seed)

    def pspecs(self, rules=None):
        return C.param_pspecs(self.defs(), rules)

    # -- encoder -----------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cfg.compute_dtype)
        x = C.constrain(x, "batch", None, None)
        stacked = C.subtree(params, "enc")

        def body(x, sl):
            x = C.constrain(x, "batch", "act_model", None)
            h = _norm(sl, x, cfg, "ln1")
            x = x + A.cross_attention(_sub(sl, "attn"), h, h, cfg)  # full self-attn
            h = _norm(sl, x, cfg, "ln2")
            return x + M.gelu_mlp(_sub(sl, "mlp"), h), None

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        if cfg.scan_layers and cfg.enc_layers > 1:
            x, _ = jax.lax.scan(body, x, stacked)
        else:
            for li in range(cfg.enc_layers):
                x, _ = body(x, {k: v[li] for k, v in stacked.items()})
        return _norm(params, x, cfg, "enc_final")

    # -- decoder (training) ---------------------------------------------------
    def _dec_body(self, enc_out):
        cfg = self.cfg

        def body(carry, sl):
            x, aux = carry
            x = C.constrain(x, "batch", "act_model", None)
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
            h = _norm(sl, x, cfg, "ln1")
            x = x + A.gqa_attention(_sub(sl, "self"), h, positions, cfg)
            h = _norm(sl, x, cfg, "ln2")
            x = x + A.cross_attention(_sub(sl, "cross"), h, enc_out, cfg)
            h = _norm(sl, x, cfg, "ln3")
            return (x + M.gelu_mlp(_sub(sl, "mlp"), h), aux), None

        return body

    def logits(self, params, tokens, frames):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        S = tokens.shape[1]
        x = C.embed_lookup(params["embed"], tokens, cfg.compute_dtype)
        x = x + params["dec_pos"][:S].astype(cfg.compute_dtype)[None]
        x = C.constrain(x, "batch", None, None)
        body = self._dec_body(enc_out)
        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        stacked = C.subtree(params, "dec")
        if cfg.scan_layers and cfg.n_layers > 1:
            (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
        else:
            for li in range(cfg.n_layers):
                (x, _), _ = body((x, jnp.zeros((), jnp.float32)), {k: v[li] for k, v in stacked.items()})
        x = _norm(params, x, cfg, "dec_final")
        return C.unembed_logits(x, params["embed"], valid_vocab=cfg.vocab), jnp.zeros((), jnp.float32)

    def loss(self, params, batch) -> jax.Array:
        logits, _ = self.logits(params, batch["tokens"], batch["frames"])
        return C.softmax_cross_entropy(logits, batch["labels"])

    # -- decode -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        one = A.gqa_cache_init(cfg, batch, max_len, cfg.compute_dtype)
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )
        # cross K/V are computed once from encoder output at serve-session
        # start; dry-run models the steady state with zero stand-ins.
        H, hd = cfg.n_heads, cfg.head_dim
        cross = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, H, hd), cfg.compute_dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, H, hd), cfg.compute_dtype),
        }
        return {"self": self_c, "cross": cross}

    def prime_cache(self, caches, prefill_len: int):
        return jax.tree_util.tree_map_with_path(
            lambda path, a: a + prefill_len
            if (path and getattr(path[-1], "key", None) == "pos")
            else a,
            caches,
        )

    def warm_cross_cache(self, params, caches, enc_out):
        """Fill the cross-attention cache from a freshly encoded utterance."""
        cfg = self.cfg
        stacked = C.subtree(params, "dec")
        ks, vs = [], []
        B, T = enc_out.shape[:2]
        for li in range(cfg.n_layers):
            sl = {k: v[li] for k, v in stacked.items()}
            cp = _sub(sl, "cross")
            ks.append(C.dense(enc_out, cp["wk"]).reshape(B, T, cfg.n_heads, cfg.head_dim))
            vs.append(C.dense(enc_out, cp["wv"]).reshape(B, T, cfg.n_heads, cfg.head_dim))
        caches = dict(caches)
        caches["cross"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        return caches

    def decode_step(self, params, caches, tokens):
        cfg = self.cfg
        x = C.embed_lookup(params["embed"], tokens, cfg.compute_dtype)
        pos = caches["self"]["pos"][0]
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], jnp.minimum(pos, params["dec_pos"].shape[0] - 1), 1
        ).astype(cfg.compute_dtype)[None]
        x = C.constrain(x, "batch", None, None)
        stacked = C.subtree(params, "dec")

        def body(x, sl_cache):
            sl, csl, ck, cv = sl_cache
            h = _norm(sl, x, cfg, "ln1")
            y, newc = A.gqa_decode(_sub(sl, "self"), h, csl, cfg)
            x = x + y
            h = _norm(sl, x, cfg, "ln2")
            # cross attention against the cached encoder K/V
            cp = _sub(sl, "cross")
            B = h.shape[0]
            q = C.dense(h, cp["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
            mask = jnp.ones((1, 1, 1, 1, ck.shape[1]), bool)
            out = A._sdpa(q, ck, cv, mask, 1.0 / math.sqrt(cfg.head_dim))
            x = x + C.dense(out.reshape(B, 1, -1), cp["wo"])
            h = _norm(sl, x, cfg, "ln3")
            return x + M.gelu_mlp(_sub(sl, "mlp"), h), newc

        if cfg.scan_layers and cfg.n_layers > 1:
            x, new_self = jax.lax.scan(
                body, x, (stacked, caches["self"], caches["cross"]["k"], caches["cross"]["v"])
            )
        else:
            outs = []
            for li in range(cfg.n_layers):
                sl = {k: v[li] for k, v in stacked.items()}
                csl = jax.tree.map(lambda a: a[li], caches["self"])
                x, nc = body(x, (sl, csl, caches["cross"]["k"][li], caches["cross"]["v"][li]))
                outs.append(nc)
            new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x = _norm(params, x, cfg, "dec_final")
        return C.unembed_logits(x, params["embed"], valid_vocab=cfg.vocab), {"self": new_self, "cross": caches["cross"]}
