"""Generic decoder LM assembly covering the dense / MoE / MLA / RG-LRU /
Mamba2 / VLM families via a segment plan.

A config lowers to an ordered list of homogeneous SEGMENTS
(``[("dense", 62)]``, ``[("mla_dense", 1), ("mla_moe", 59)]``,
``[("rg_super", 12), ("rec_tail", 1)]`` ...).  Each segment's layer params
are stacked on a leading "layer" axis and driven by ``lax.scan`` — keeping
the HLO size O(#segments), not O(#layers), which is what makes 62-layer ×
512-device dry-run compiles tractable.  Remat policy wraps the scan body.

Train path returns mean CE loss (+ MoE aux); decode path threads per-layer
caches through the same scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import common as C
from . import mlp as M
from . import rglru as R
from . import ssm as S
from .common import ParamDef as PD


# ---------------------------------------------------------------------------
# block library: defs / train / decode / cache per block type
# ---------------------------------------------------------------------------

def _norm_defs(cfg, name: str) -> C.Defs:
    if cfg.norm == "ln":
        return {
            f"{name}/scale": PD((cfg.d_model,), ("embed",), init="ones"),
            f"{name}/bias": PD((cfg.d_model,), ("embed",), init="zeros"),
        }
    return {f"{name}/scale": PD((cfg.d_model,), ("embed",), init="ones")}


def _norm(p, x, cfg, name: str):
    if cfg.norm == "ln":
        return C.layer_norm(x, p[f"{name}/scale"], p[f"{name}/bias"])
    return C.rms_norm(x, p[f"{name}/scale"])


def _prefixed(defs: C.Defs, prefix: str) -> C.Defs:
    return {f"{prefix}/{k}": v for k, v in defs.items()}


def _sub(p: C.Params, prefix: str) -> C.Params:
    return C.subtree(p, prefix)


@dataclasses.dataclass
class BlockType:
    defs: Any  # cfg -> Defs
    train: Any  # (p, x, positions, cfg) -> (x, aux)
    decode: Any  # (p, x, cache, cfg) -> (x, cache)
    cache_init: Any  # (cfg, batch, max_len, dtype) -> cache pytree


def _mk_attn_mlp_block(attn_kind: str, mlp_kind: str, window=False):
    """Factory for (pre-norm mixer + pre-norm FFN) transformer blocks."""

    def defs(cfg):
        d: C.Defs = {}
        d.update(_norm_defs(cfg, "ln1"))
        d.update(_norm_defs(cfg, "ln2"))
        if attn_kind == "gqa":
            d.update(_prefixed(A.gqa_defs(cfg), "attn"))
        elif attn_kind == "mla":
            d.update(_prefixed(A.mla_defs(cfg), "attn"))
        elif attn_kind == "rec":
            d.update(_prefixed(R.rglru_defs(cfg), "rec"))
        if mlp_kind == "swiglu":
            d.update(_prefixed(M.swiglu_defs(cfg), "mlp"))
        elif mlp_kind == "gelu":
            d.update(_prefixed(M.gelu_mlp_defs(cfg), "mlp"))
        elif mlp_kind == "dense_first":
            d.update(_prefixed(M.swiglu_defs(cfg, cfg.first_dense_ff or cfg.d_ff), "mlp"))
        elif mlp_kind == "moe":
            d.update(_prefixed(M.moe_defs(cfg), "moe"))
        return d

    win = lambda cfg: (cfg.window if window else None)

    def train(p, x, positions, cfg):
        aux = jnp.zeros((), jnp.float32)
        h = _norm(p, x, cfg, "ln1")
        if attn_kind == "gqa":
            mix = A.gqa_attention(_sub(p, "attn"), h, positions, cfg, window=win(cfg))
        elif attn_kind == "mla":
            mix = A.mla_attention(_sub(p, "attn"), h, positions, cfg)
        else:
            mix = R.rec_block(_sub(p, "rec"), h, cfg)
        x = x + mix
        h = _norm(p, x, cfg, "ln2")
        if mlp_kind in ("swiglu", "dense_first"):
            y = M.swiglu(_sub(p, "mlp"), h)
        elif mlp_kind == "gelu":
            y = M.gelu_mlp(_sub(p, "mlp"), h)
        else:
            y, aux = M.moe_block(_sub(p, "moe"), h, cfg)
        return x + y, aux

    def decode(p, x, cache, cfg):
        h = _norm(p, x, cfg, "ln1")
        if attn_kind == "gqa":
            mix, cache = A.gqa_decode(_sub(p, "attn"), h, cache, cfg, window=win(cfg))
        elif attn_kind == "mla":
            mix, cache = A.mla_decode(_sub(p, "attn"), h, cache, cfg)
        else:
            mix, cache = R.rec_decode(_sub(p, "rec"), h, cache, cfg)
        x = x + mix
        h = _norm(p, x, cfg, "ln2")
        if mlp_kind in ("swiglu", "dense_first"):
            y = M.swiglu(_sub(p, "mlp"), h)
        elif mlp_kind == "gelu":
            y = M.gelu_mlp(_sub(p, "mlp"), h)
        else:
            y, _ = M.moe_block(_sub(p, "moe"), h, cfg)
        return x + y, cache

    def cache_init(cfg, batch, max_len, dtype):
        if attn_kind == "gqa":
            return A.gqa_cache_init(cfg, batch, max_len, dtype, window=win(cfg))
        if attn_kind == "mla":
            return A.mla_cache_init(cfg, batch, max_len, dtype)
        return R.rec_cache_init(cfg, batch, dtype)

    return BlockType(defs, train, decode, cache_init)


def _mk_mamba_block():
    def defs(cfg):
        d = _norm_defs(cfg, "ln1")
        d.update(_prefixed(S.mamba_defs(cfg), "ssm"))
        return d

    def train(p, x, positions, cfg):
        h = _norm(p, x, cfg, "ln1")
        return x + S.mamba_block(_sub(p, "ssm"), h, cfg), jnp.zeros((), jnp.float32)

    def decode(p, x, cache, cfg):
        h = _norm(p, x, cfg, "ln1")
        y, cache = S.mamba_decode(_sub(p, "ssm"), h, cache, cfg)
        return x + y, cache

    def cache_init(cfg, batch, max_len, dtype):
        return S.mamba_cache_init(cfg, batch, dtype)

    return BlockType(defs, train, decode, cache_init)


def _mk_super_block(units: Tuple[str, ...]):
    """RecurrentGemma super-block: e.g. (rec, rec, attn_local) scanned as one."""
    subs = {
        "rec": _mk_attn_mlp_block("rec", "gelu"),
        "attn_local": _mk_attn_mlp_block("gqa", "gelu", window=True),
    }

    def defs(cfg):
        d: C.Defs = {}
        for i, u in enumerate(units):
            d.update(_prefixed(subs[u].defs(cfg), f"u{i}"))
        return d

    def train(p, x, positions, cfg):
        aux = jnp.zeros((), jnp.float32)
        for i, u in enumerate(units):
            x, a = subs[u].train(_sub(p, f"u{i}"), x, positions, cfg)
            aux = aux + a
        return x, aux

    def decode(p, x, cache, cfg):
        new = {}
        for i, u in enumerate(units):
            x, new[f"u{i}"] = subs[u].decode(_sub(p, f"u{i}"), x, cache[f"u{i}"], cfg)
        return x, new

    def cache_init(cfg, batch, max_len, dtype):
        return {
            f"u{i}": subs[u].cache_init(cfg, batch, max_len, dtype)
            for i, u in enumerate(units)
        }

    return BlockType(defs, train, decode, cache_init)


BLOCKS: Dict[str, BlockType] = {
    "dense": _mk_attn_mlp_block("gqa", "swiglu"),
    "dense_gelu": _mk_attn_mlp_block("gqa", "gelu"),
    "moe": _mk_attn_mlp_block("gqa", "moe"),
    "moe_first_dense": _mk_attn_mlp_block("gqa", "dense_first"),
    "mla_moe": _mk_attn_mlp_block("mla", "moe"),
    "mla_first_dense": _mk_attn_mlp_block("mla", "dense_first"),
    "rg_super": _mk_super_block(("rec", "rec", "attn_local")),
    "rec_tail": _mk_attn_mlp_block("rec", "gelu"),
    "mamba": _mk_mamba_block(),
}


def layer_plan(cfg) -> List[Tuple[str, int]]:
    """Lower an ArchConfig to ordered homogeneous segments."""
    f = cfg.family
    if f in ("dense", "vlm"):
        bt = "dense_gelu" if cfg.norm == "ln" else "dense"
        return [(bt, cfg.n_layers)]
    if f == "moe":
        plan = []
        if cfg.first_dense_layers:
            plan.append(("moe_first_dense", cfg.first_dense_layers))
        plan.append(("moe", cfg.n_layers - cfg.first_dense_layers))
        return plan
    if f == "mla_moe":
        plan = []
        if cfg.first_dense_layers:
            plan.append(("mla_first_dense", cfg.first_dense_layers))
        plan.append(("mla_moe", cfg.n_layers - cfg.first_dense_layers))
        return plan
    if f == "rglru":
        n_super, rem = divmod(cfg.n_layers, 3)
        plan = [("rg_super", n_super)]
        if rem:
            plan.append(("rec_tail", rem))
        return plan
    if f == "mamba2":
        return [("mamba", cfg.n_layers)]
    raise ValueError(f"unknown family {f!r}")


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class DecoderLM:
    """Decoder-only LM (also hosts the VLM variant via stub patch embeds)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.plan = layer_plan(cfg)
        # vocab padded to a multiple of 256 so the "vocab" TP axis always
        # divides the 16-way mesh; padded logit rows are masked to -inf.
        self.pv = -(-cfg.vocab // 256) * 256

    # -- parameters ---------------------------------------------------------
    def defs(self) -> C.Defs:
        cfg = self.cfg
        d: C.Defs = {
            "embed": PD((self.pv, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02),
        }
        d.update(_norm_defs(cfg, "final_norm"))
        if not cfg.tie_embed:
            d["unembed"] = PD((self.pv, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02)
        if cfg.num_patches:
            d["patch_proj"] = PD((cfg.d_model, cfg.d_model), ("embed", None))
        for si, (bt, n) in enumerate(self.plan):
            d.update(C.stack_defs(BLOCKS[bt].defs(cfg), n, f"seg{si}"))
        return d

    def init(self, seed: int = 0) -> C.Params:
        return C.init_params(self.defs(), seed)

    def pspecs(self, rules=None):
        return C.param_pspecs(self.defs(), rules)

    # -- forward --------------------------------------------------------------
    def _embed_inputs(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = C.embed_lookup(params["embed"], tokens, cfg.compute_dtype)
        if cfg.num_patches and patch_embeds is not None:
            pe = C.dense(patch_embeds.astype(cfg.compute_dtype), params["patch_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        return C.constrain(x, "batch", None, None)

    def _run_segments(self, params, x, positions):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        for si, (bt, n) in enumerate(self.plan):
            blk = BLOCKS[bt]
            stacked = C.subtree(params, f"seg{si}")

            def body(carry, sl):
                x, aux = carry
                # sequence-parallel residual stream: the scan carry (and thus
                # every remat-saved layer input) is sharded over the TP axis
                # along seq; TP blocks all-gather/reduce-scatter internally.
                x = C.constrain(x, "batch", "act_model", None)
                y, a = blk.train(sl, x, positions, cfg)
                y = C.constrain(y, "batch", "act_model", None)
                return (y, aux + a), None

            if cfg.remat != "none":
                policy = (
                    jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                    if cfg.remat == "dots"
                    else jax.checkpoint_policies.nothing_saveable
                )
                body = jax.checkpoint(body, policy=policy, prevent_cse=False)
            if cfg.scan_layers and n > 1:
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
            else:
                for li in range(n):
                    sl = {k: v[li] for k, v in stacked.items()}
                    (x, aux_total), _ = body((x, aux_total), sl)
        return x, aux_total

    def logits(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, patch_embeds)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        x, aux = self._run_segments(params, x, positions)
        x = _norm(params, x, cfg, "final_norm")
        table = params["embed"] if cfg.tie_embed else params["unembed"]
        return C.unembed_logits(x, table, valid_vocab=cfg.vocab), aux

    def loss(self, params, batch) -> jax.Array:
        """Mean next-token CE (+ MoE aux).  VLM: patch positions unlabelled."""
        logits, aux = self.logits(
            params, batch["tokens"], batch.get("patch_embeds")
        )
        if self.cfg.num_patches and "patch_embeds" in batch:
            logits = logits[:, self.cfg.num_patches :]
        ce = C.softmax_cross_entropy(logits, batch["labels"])
        return ce + aux

    # -- decode -----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        caches = {}
        for si, (bt, n) in enumerate(self.plan):
            one = BLOCKS[bt].cache_init(cfg, batch, max_len, cfg.compute_dtype)
            caches[f"seg{si}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), one
            )
        return caches

    def prime_cache(self, caches, prefill_len: int):
        """Mark ``prefill_len`` tokens as present (dry-run decode cells model
        the steady serving state: a full cache of seq_len context)."""
        return jax.tree_util.tree_map_with_path(
            lambda path, a: a + prefill_len
            if (path and getattr(path[-1], "key", None) == "pos")
            else a,
            caches,
        )

    def decode_step(self, params, caches, tokens):
        """tokens (B,1) -> (logits (B,1,V), new caches)."""
        cfg = self.cfg
        x = C.embed_lookup(params["embed"], tokens, cfg.compute_dtype)
        x = C.constrain(x, "batch", None, None)
        new_caches = {}
        for si, (bt, n) in enumerate(self.plan):
            blk = BLOCKS[bt]
            stacked = C.subtree(params, f"seg{si}")
            cache = caches[f"seg{si}"]

            if cfg.scan_layers and n > 1:
                # The cache rides in the scan CARRY and is updated in place
                # with dynamic_update_index — XLA aliases while-loop carries,
                # so exactly ONE cache buffer stays live.  (Passing the cache
                # as scan xs/ys double-buffers the full KV cache: measured
                # +~1x cache bytes on codeqwen decode_32k — see §Perf.)
                def body(carry, sl_li):
                    x, cfull = carry
                    sl, li = sl_li
                    csl = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
                        cfull,
                    )
                    y, newc = blk.decode(sl, x, csl, cfg)
                    cfull = jax.tree.map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd.astype(full.dtype), li, 0
                        ),
                        cfull,
                        newc,
                    )
                    return (y, cfull), None

                (x, newc), _ = jax.lax.scan(
                    body, (x, cache), (stacked, jnp.arange(n, dtype=jnp.int32))
                )
            else:
                outs = []
                for li in range(n):
                    sl = {k: v[li] for k, v in stacked.items()}
                    csl = jax.tree.map(lambda a: a[li], cache)
                    x, nc = blk.decode(sl, x, csl, cfg)
                    outs.append(nc)
                newc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            new_caches[f"seg{si}"] = newc
        x = _norm(params, x, cfg, "final_norm")
        table = params["embed"] if cfg.tie_embed else params["unembed"]
        return C.unembed_logits(x, table, valid_vocab=cfg.vocab), new_caches
