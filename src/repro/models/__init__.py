"""Model zoo: the ten assigned architectures as composable JAX modules.

Families: dense GQA decoders (codeqwen/stablelm/deepseek-coder/qwen2.5),
MLA + fine-grained MoE (deepseek-v2), fine-grained MoE (deepseek-moe),
VLM backbone (pixtral), encoder-decoder (whisper), RG-LRU hybrid
(recurrentgemma) and SSD state-space (mamba2).
"""
from . import common, registry

__all__ = ["common", "registry"]
