"""Chunked (flash) attention in pure jnp with a custom VJP.

Memory-bounded attention is a hard requirement for the 32k prefill and 4k
train cells: materialised (S x T) score tensors at 32k would be terabytes per
device.  This implementation scans over KV chunks with online-softmax
accumulation (forward) and a rematerialising two-pass backward (custom_vjp),
so residency is O(S·d + chunk·S) instead of O(S²).

This is the algorithmic core the Pallas ``flash_attention`` kernel tiles for
VMEM; the kernel tests assert allclose against this function, and this
function's tests assert allclose against the naive softmax reference.

Layout: q (B,S,KV,G,hd), k/v (B,T,KV,hd) — grouped GQA form.  ``q_offset``
supports self-attention where q is a suffix of the kv sequence (prefill
continuation); ``window`` gives banded/local attention.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # python float: safe under lazy import inside a trace


def _chunk_mask(q_pos, k_pos, causal: bool, window: Optional[int], valid_len: int):
    m = jnp.broadcast_to(k_pos[None, :] < valid_len, (q_pos.shape[0], k_pos.shape[0]))
    if causal:
        m = m & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m


def _fwd_scan(q, k, v, scale, causal, window, chunk, q_offset, valid_len):
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    nk = T // chunk
    qf = q.astype(jnp.float32) * jnp.float32(scale)
    kc = jnp.moveaxis(k.reshape(B, nk, chunk, KV, k.shape[-1]), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, chunk, KV, v.shape[-1]), 1, 0)
    q_pos = jnp.arange(S) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgh,btkh->bskgt", qf, kb.astype(jnp.float32))
        msk = _chunk_mask(q_pos, k_pos, causal, window, valid_len)[None, :, None, None, :]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkh->bskgh", p, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    hd_v = v.shape[-1]
    init = (
        jnp.full((B, S, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, S, KV, G), jnp.float32),
        jnp.zeros((B, S, KV, G, hd_v), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, jnp.arange(nk)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_grouped(q, k, v, scale, causal=True, window=None, chunk=256, q_offset=0, valid_len=None):
    out, _ = _fwd_scan(q, k, v, scale, causal, window, chunk, q_offset, valid_len)
    return out.astype(q.dtype)


def _fwd_rule(q, k, v, scale, causal, window, chunk, q_offset, valid_len):
    out, lse = _fwd_scan(q, k, v, scale, causal, window, chunk, q_offset, valid_len)
    return out.astype(q.dtype), (q, k, v, out, lse)


def _bwd_rule(scale, causal, window, chunk, q_offset, valid_len, res, do):
    q, k, v, out, lse = res
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    nk = T // chunk
    qf = q.astype(jnp.float32) * jnp.float32(scale)
    dof = do.astype(jnp.float32)
    # D = rowsum(dO * O)
    Dr = jnp.sum(dof * out, axis=-1)  # (B,S,KV,G)
    kc = jnp.moveaxis(k.reshape(B, nk, chunk, KV, k.shape[-1]), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, chunk, KV, v.shape[-1]), 1, 0)
    q_pos = jnp.arange(S) + q_offset

    def body(dq, inp):
        kb, vb, ci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgh,btkh->bskgt", qf, kb.astype(jnp.float32))
        msk = _chunk_mask(q_pos, k_pos, causal, window, valid_len)[None, :, None, None, :]
        s = jnp.where(msk, s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,S,KV,G,t)
        dp = jnp.einsum("bskgh,btkh->bskgt", dof, vb.astype(jnp.float32))
        ds = p * (dp - Dr[..., None])  # (B,S,KV,G,t)
        dq = dq + jnp.einsum("bskgt,btkh->bskgh", ds, kb.astype(jnp.float32)) * jnp.float32(scale)
        dkb = jnp.einsum("bskgt,bskgh->btkh", ds, qf)
        dvb = jnp.einsum("bskgt,bskgh->btkh", p, dof)
        return dq, (dkb, dvb)

    dq0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)  # hd = qk dim
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (kc, vc, jnp.arange(nk)))
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(B, T, KV, k.shape[-1])
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(B, T, KV, v.shape[-1])
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_grouped.defvjp(_fwd_rule, _bwd_rule)


def flash_attention(
    q: jax.Array,  # (B,S,H,hd)
    k: jax.Array,  # (B,T,KV,hd)
    v: jax.Array,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 256,
    q_offset: int = 0,
) -> jax.Array:
    """Ungrouped wrapper: pads T to a chunk multiple, returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, S, KV, H // KV, hd)
    out = flash_attention_grouped(qg, k, v, scale, causal, window, chunk, q_offset, T)
    return out.reshape(B, S, H, v.shape[-1])
