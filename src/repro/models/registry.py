"""Model registry: ArchConfig -> model object."""
from __future__ import annotations

from .encdec import EncDecLM
from .lm import DecoderLM


def build(cfg):
    if cfg.family == "whisper":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
