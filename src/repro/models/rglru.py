"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    gate branch: g = gelu(W_gate x)
    rnn branch:  u = W_rnn x -> causal conv1d(w=4) -> RG-LRU
    out:         W_out (g * h)

RG-LRU (per channel): r_t = sigmoid(W_r u_t); i_t = sigmoid(W_i u_t)
    a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t u_t)

Training uses ``jax.lax.associative_scan`` (parallel prefix — the TPU-native
formulation; the GPU paper uses a custom linear-scan kernel, see DESIGN.md
hardware-adaptation notes); ``repro.kernels.rglru_scan`` is the Pallas
version; decode is the O(1) recurrence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import common as C
from .common import ParamDef as PD

_C = 8.0


def rglru_defs(cfg) -> C.Defs:
    D, R = cfg.d_model, cfg.lru_width
    return {
        "w_gate": PD((D, R), ("embed", "mlp")),
        "w_rnn": PD((D, R), ("embed", "mlp")),
        "conv_w": PD((cfg.conv_width, R), ("conv", "mlp")),
        "conv_b": PD((R,), ("mlp",), init="zeros"),
        "w_r": PD((R, R), ("mlp", None), scale=0.5),
        "b_r": PD((R,), (None,), init="zeros"),
        "w_i": PD((R, R), ("mlp", None), scale=0.5),
        "b_i": PD((R,), (None,), init="zeros"),
        "lam": PD((R,), (None,), init="ones"),  # Lambda (pre-softplus)
        "w_out": PD((R, D), ("mlp", "embed")),
    }


def _conv1d(u, w, b):
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + u.shape[1], :] * w[i].astype(u.dtype) for i in range(K))
    return out + b.astype(u.dtype)


def _gates(p, u):
    r = jax.nn.sigmoid(C.dense(u, p["w_r"], p["b_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(C.dense(u, p["w_i"], p["b_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r  # (B,S,R)
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, x_in


def rglru_seq(p: C.Params, u: jax.Array, cfg) -> jax.Array:
    """Full-sequence RG-LRU via parallel associative scan over time."""
    a, x_in = _gates(p, u)
    if cfg.use_pallas:
        from repro.kernels.rglru_scan import ops as rops

        h = rops.rglru(a, x_in)
    else:

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    return h.astype(u.dtype)


def rec_block(p: C.Params, x: jax.Array, cfg) -> jax.Array:
    g = jax.nn.gelu(C.dense(x, p["w_gate"]), approximate=True)
    u = C.dense(x, p["w_rnn"])
    u = _conv1d(u, p["conv_w"], p["conv_b"])
    h = rglru_seq(p, u, cfg)
    return C.dense(g * h, p["w_out"])


def rec_cache_init(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    R = cfg.lru_width
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, R), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def rec_decode(p, x, cache, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    g = jax.nn.gelu(C.dense(x, p["w_gate"]), approximate=True)  # (B,1,R)
    u_new = C.dense(x, p["w_rnn"])[:, 0]  # (B,R)
    hist = jnp.concatenate([cache["conv"], u_new[:, None]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    u = (jnp.einsum("bkr,kr->br", hist, w) + p["conv_b"].astype(x.dtype))[:, None]
    a, x_in = _gates(p, u)  # (B,1,R)
    h = a[:, 0] * cache["h"] + x_in[:, 0]
    y = C.dense(g * h[:, None].astype(x.dtype), p["w_out"])
    return y, {"h": h, "conv": hist[:, 1:], "pos": cache["pos"] + 1}
