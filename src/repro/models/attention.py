"""Attention blocks: GQA (optionally banded/local, optional QKV bias),
cross-attention (enc-dec) and DeepSeek-V2 MLA (multi-head latent attention).

Two execution paths share one math definition:
  * training/prefill: full-sequence attention — jnp einsum reference, or the
    Pallas flash kernel (``repro.kernels.flash_attention``) when
    ``cfg.use_pallas`` (TPU target);
  * decode: single-token attention against a KV cache.  GQA caches (k, v);
    windowed attention uses a ROLLING cache (window-sized, O(W) memory at
    524k context); MLA caches the compressed latent + shared rope key and
    uses the absorbed-weight formulation (the paper-faithful inference path).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common as C
from .common import ParamDef as PD


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_defs(cfg) -> C.Defs:
    """QKV/O weights stored with MERGED (heads*head_dim) axes so the TP axis
    always divides (56 or 40 heads x 128 = multiples of 16); the per-head
    split happens post-matmul where GSPMD pads as needed."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": PD((D, H * hd), ("embed", "heads")),
        "wk": PD((D, KV * hd), ("embed", "kv_heads")),
        "wv": PD((D, KV * hd), ("embed", "kv_heads")),
        "wo": PD((H * hd, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = PD((H * hd,), ("heads",), init="zeros")
        defs["bk"] = PD((KV * hd,), ("kv_heads",), init="zeros")
        defs["bv"] = PD((KV * hd,), ("kv_heads",), init="zeros")
    return defs


def _qkv(p, x, cfg):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = C.dense(x, p["wq"], p.get("bq") if cfg.qkv_bias else None)
    k = C.dense(x, p["wk"], p.get("bk") if cfg.qkv_bias else None)
    v = C.dense(x, p["wv"], p.get("bv") if cfg.qkv_bias else None)
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _sdpa(q, k, v, mask, scale) -> jax.Array:
    """Grouped scaled-dot-product attention, fp32 softmax.

    q: (B,S,H,hd), k/v: (B,T,KV,hd); H = KV * G.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def gqa_attention(
    p: C.Params,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    window: Optional[int] = None,
) -> jax.Array:
    """Full-sequence (training / prefill) GQA."""
    q, k, v = _qkv(p, x, cfg)
    rd = int(cfg.head_dim * cfg.rotary_pct) or None
    q = C.rope(q, positions, cfg.rope_theta, rotary_dim=rd)
    k = C.rope(k, positions, cfg.rope_theta, rotary_dim=rd)
    S = x.shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fops

        out = fops.flash_attention(q, k, v, causal=True, window=window, scale=scale)
    elif S > 1024:
        # memory-bounded chunked attention (O(S) residency) — required for
        # the 4k train / 32k prefill shapes
        from . import flash as F

        out = F.flash_attention(q, k, v, scale, causal=True, window=window)
    else:
        mask = C.causal_mask(S, S, window=window)[None, None, None]
        out = _sdpa(q, k, v, mask, scale)
    B, S = x.shape[:2]
    return C.dense(out.reshape(B, S, -1), p["wo"])


def gqa_cache_init(cfg, batch: int, max_len: int, dtype, window: Optional[int] = None):
    W = min(window, max_len) if window else max_len
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, W, KV, hd), dtype),
        "v": jnp.zeros((batch, W, KV, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_decode(
    p: C.Params,
    x: jax.Array,  # (B, 1, D)
    cache: Dict[str, jax.Array],
    cfg,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode with (rolling, if windowed) KV cache."""
    pos = cache["pos"]
    positions = pos[None, None] * jnp.ones((x.shape[0], 1), jnp.int32)
    q, k, v = _qkv(p, x, cfg)
    rd = int(cfg.head_dim * cfg.rotary_pct) or None
    q = C.rope(q, positions, cfg.rope_theta, rotary_dim=rd)
    k = C.rope(k, positions, cfg.rope_theta, rotary_dim=rd)
    W = cache["k"].shape[1]
    # rolling insert (windowed) or append (full)
    ins = (pos % W) if window else pos
    zero = jnp.zeros((), jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (zero, ins, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (zero, ins, zero, zero))

    kv_pos = jnp.arange(W)
    if window:
        # slot s holds the latest position p <= pos with p % W == s; a slot is
        # valid once that position has actually been written (p >= 0) — it is
        # automatically within the window since only one p fits (pos-W, pos].
        entry_pos = pos - (pos - kv_pos) % W
        valid = entry_pos >= 0
    else:
        valid = kv_pos <= pos
    mask = valid[None, None, None, None, :]  # (1,1,1,1,W) over (b,kv,g,s,t)

    if cfg.use_pallas:
        from repro.kernels.decode_attention import ops as dops

        out = dops.decode_attention(q, ck, cv, valid, 1.0 / math.sqrt(cfg.head_dim))
    else:
        out = _sdpa(q, ck, cv, mask, 1.0 / math.sqrt(cfg.head_dim))
    B = x.shape[0]
    y = C.dense(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": ck, "v": cv, "pos": pos + 1}


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_defs(cfg) -> C.Defs:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": PD((D, H * hd), ("embed", "heads")),
        "wk": PD((D, H * hd), ("embed", "heads")),
        "wv": PD((D, H * hd), ("embed", "heads")),
        "wo": PD((H * hd, D), ("heads", "embed")),
    }


def cross_attention(p, x, enc: jax.Array, cfg) -> jax.Array:
    B, S, _ = x.shape
    T = enc.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim
    q = C.dense(x, p["wq"]).reshape(B, S, H, hd)
    k = C.dense(enc, p["wk"]).reshape(B, T, H, hd)
    v = C.dense(enc, p["wv"]).reshape(B, T, H, hd)
    mask = jnp.ones((1, 1, 1, S, T), bool)
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(cfg.head_dim))
    return C.dense(out.reshape(B, S, -1), p["wo"])


# ---------------------------------------------------------------------------
# DeepSeek-V2 MLA
# ---------------------------------------------------------------------------

def mla_defs(cfg) -> C.Defs:
    D, H = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": PD((D, qr), ("embed", None)),
        "q_norm": PD((qr,), (None,), init="ones"),
        "wq_b": PD((qr, H * (dn + dr)), (None, "heads")),
        "wkv_a": PD((D, kr + dr), ("embed", None)),
        "kv_norm": PD((kr,), (None,), init="ones"),
        "wk_b": PD((kr, H * dn), (None, "heads")),
        "wv_b": PD((kr, H * dv), (None, "heads")),
        "wo": PD((H * dv, D), ("heads", "embed")),
    }


def _mla_q(p, x, positions, cfg):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    B, S, _ = x.shape
    q_lat = C.rms_norm(C.dense(x, p["wq_a"]), p["q_norm"])
    # §Perf: down-project on the SEQ-SHARDED stream, then gather the narrow
    # latent (q_lora ≪ d_model) instead of letting SPMD gather x itself —
    # 3.3x fewer bytes on the dominant MLA activation all-gather.
    q_lat = C.constrain(q_lat, "batch", None, None)
    q = C.dense(q_lat, p["wq_b"]).reshape(B, S, cfg.n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = C.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, positions, cfg):
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = C.dense(x, p["wkv_a"])  # (B,S,kr+dr)
    ckv = C.constrain(ckv, "batch", None, None)  # gather 576-dim, not 5120-dim
    c, k_rope = ckv[..., :kr], ckv[..., kr:]
    c = C.rms_norm(c, p["kv_norm"])
    k_rope = C.rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c, k_rope


def mla_attention(p, x, positions, cfg) -> jax.Array:
    """Training/prefill MLA (materialised per-head keys/values).

    Long sequences route through chunked flash attention by merging the
    (nope | rope) key parts into one 192-wide qk head — KV=H, G=1."""
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c, k_rope = _mla_latent(p, x, positions, cfg)
    B, S, _ = x.shape
    k_nope = C.dense(c, p["wk_b"]).reshape(B, S, cfg.n_heads, dn)
    v = C.dense(c, p["wv_b"]).reshape(B, S, cfg.n_heads, dv)
    if S > 1024:
        from . import flash as F

        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        H = q.shape[2]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (cfg.qk_rope_dim,))],
            axis=-1,
        )
        out = F.flash_attention(q, k, v, scale, causal=True)
    else:
        mask = C.causal_mask(S, S)[None, None]
        scores = (
            jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
            + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthk->bshk", w, v)
    return C.dense(out.reshape(B, S, -1), p["wo"])


def mla_cache_init(cfg, batch: int, max_len: int, dtype):
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_decode(p, x, cache, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-weight MLA decode: attention runs in the compressed latent
    space; the cache is (kv_lora + rope) wide — 576 floats/token for V2,
    ~14x smaller than materialised GQA-128 KV. This is the inference
    efficiency the architecture was designed for."""
    pos = cache["pos"]
    positions = pos[None, None] * jnp.ones((x.shape[0], 1), jnp.int32)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _mla_q(p, x, positions, cfg)  # (B,1,H,dn),(B,1,H,dr)
    c_new, kr_new = _mla_latent(p, x, positions, cfg)
    zero = jnp.zeros((), jnp.int32)
    c = jax.lax.dynamic_update_slice(cache["c"], c_new, (zero, pos, zero))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (zero, pos, zero))

    # absorb W^K_b into the query: q_lat = q_nope @ W^K_b  -> latent space
    H, dn, dv, kr = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    B = x.shape[0]
    wk_b = p["wk_b"].astype(x.dtype).reshape(kr, H, dn)
    wv_b = p["wv_b"].astype(x.dtype).reshape(kr, H, dv)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk_b)
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, c)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    valid = (jnp.arange(c.shape[1]) <= pos)[None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", w, c)  # (B,1,H,kr)
    out = jnp.einsum("bshr,rhk->bshk", out_lat, wv_b)
    y = C.dense(out.reshape(B, 1, -1), p["wo"])
    return y, {"c": c, "k_rope": k_rope, "pos": pos + 1}
