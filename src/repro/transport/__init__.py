"""Pluggable shard-frame transport for the multi-host serving tier.

The coordinator↔worker protocol of :mod:`repro.serve.gateway.multihost` is
two planes with very different needs:

* the **control plane** — hellos, pings, clock/trace probes, shutdown acks,
  execute/reply *headers* — is tiny, latency-tolerant and stays on the
  existing ``multiprocessing.connection`` socket (length-prefixed pickle,
  authkey-authenticated, strictly ordered);
* the **data plane** — the numpy column blocks of every routed batch and
  the output pytrees coming back — is the hot path: under the pickle
  transport each shard crosses the socket as a serialized copy (pickle
  buffer → kernel → peer buffer → unpickle allocation), the redundant
  serialize/copy tax the tabular-preprocessing literature identifies as the
  dominant input-pipeline cost.

This package makes the data plane pluggable behind the :class:`Transport`
protocol:

* :class:`PickleTransport` — the default-correct fallback: payloads ride
  inline in the pickled control frame, byte-for-byte the pre-transport wire
  format.  Works across machines.
* :class:`SharedMemoryTransport` — the zero-copy fast path: a
  ``multiprocessing.shared_memory`` segment per worker pair, split into a
  request ring and a reply ring of fixed-size slots.  Numpy columns are
  written **in place** into a slot (one memcpy, no serialization) and the
  control frame carries only a compact :class:`~repro.transport.frames.
  ShmFrame` header (per-leaf dtype/shape/offset + slot coordinates).  The
  receiver maps the slot and reads the columns back in place.  Frames
  larger than a slot (or arriving when the ring is exhausted) fall back to
  inline pickle per frame — bounded, counted, never wrong.

Selection: ``REPRO_MH_TRANSPORT=pickle|shm`` (or the executor's
``transport=`` argument).  The shm path is *negotiated* per worker at
attach: the coordinator creates the segment and sends a ``shm_attach``
control frame; a worker that cannot map it (cross-machine, exhausted
``/dev/shm``) answers with an error and that worker pair silently runs on
pickle — mixed fleets are fine.

Slot lifecycle (see :class:`~repro.transport.ring.SlotRing`): the strict
one-in-flight request/reply discipline of the socket protocol means a
request slot is only reusable once its reply has been consumed (the worker
has necessarily finished reading the request before it replies), and a
reply slot once the next request lands (the coordinator drains every reply
— real, hedged-stale or probe — before the connection carries anything
else).  Every slot write stamps a generation; readers verify it, so a
lifecycle violation surfaces as a loud :class:`TransportDesyncError`
instead of silent corruption.  On worker death the coordinator *reclaims*
the pair's ring — in-flight slots are freed and the segment unlinked via
the :class:`~repro.ft.DeathReclaimer` hook — so a dead worker's in-flight
slot never wedges the ring, and a reshard re-homes that worker's blocks to
survivors whose own rings are untouched.
"""
from .frames import (
    FrameTooLargeError,
    ShmFrame,
    TransportDesyncError,
    WireSpans,
    ascontiguous,
    flatten_payload,
    unflatten_payload,
)
from .ring import SlotRing
from .transports import (
    PickleTransport,
    SharedMemoryTransport,
    Transport,
    transport_kind,
)

__all__ = [
    "Transport",
    "PickleTransport",
    "SharedMemoryTransport",
    "SlotRing",
    "ShmFrame",
    "WireSpans",
    "ascontiguous",
    "flatten_payload",
    "unflatten_payload",
    "transport_kind",
    "TransportDesyncError",
    "FrameTooLargeError",
]
