"""Frame codec: payload pytrees ⇄ flat numpy leaves + a picklable spec.

The shard payloads this tier moves are numpy-column dicts (requests) and
small pytrees of numpy arrays (replies).  The codec here flattens either
into ``(leaves, spec)`` where every leaf is an ndarray and ``spec`` is a
compact picklable structure descriptor — dicts keep sorted-key order, so
encode/decode round-trips bit-identically and deterministically on both
ends of the wire.  Non-array leaves (python scalars, None) ride inside the
spec itself; they are control-plane sized.

Kept numpy-only on purpose: both sides of the multi-host socket import
this before jax is necessarily initialised, and the transport must never
drag a device runtime into a worker that only ships bytes.
"""
from __future__ import annotations

import numpy as np
from typing import Any, List, Optional, Tuple


class TransportDesyncError(RuntimeError):
    """A shm slot's generation stamp does not match its frame header: the
    slot was overwritten while a reader still held its descriptor, i.e. the
    strict request/reply slot lifecycle was violated.  Always a bug — the
    transport raises loudly instead of returning silently wrong bytes."""


class FrameTooLargeError(RuntimeError):
    """A payload exceeds the slot size (or the ring is exhausted); callers
    fall back to the inline-pickle path for that frame."""


class WireSpans:
    """Execute-reply wrapper piggybacking worker-side obs spans on the reply
    frame: ``out`` is the block's output pytree, ``spans`` the finished span
    tuples recorded while executing it (worker clock).  The pickle transport
    ships it as-is; the shm transport carries ``spans`` in the frame header
    and only ``out``'s leaves through the ring."""

    __slots__ = ("out", "spans")

    def __init__(self, out, spans):
        self.out = out
        self.spans = spans


def ascontiguous(a: np.ndarray) -> np.ndarray:
    """``a`` itself when already C-contiguous, else a C-contiguous copy.

    Dispatch normalises every column block through this at slicing time, so
    both transports see one layout: the pickle path stops serialising
    strided views (numpy pickles them via a gather) and the shm path writes
    with a single straight memcpy.  The identity fast path is load-bearing —
    tests assert no per-dispatch copy for already-contiguous blocks."""
    if isinstance(a, np.ndarray) and not a.flags.c_contiguous:
        return np.ascontiguousarray(a)
    return a


# -- pytree flatten (numpy-only; no jax treedefs cross the wire) ------------


def flatten_payload(obj: Any) -> Tuple[List[np.ndarray], Any]:
    """Flatten a payload pytree (dict/list/tuple nests of ndarrays plus
    arbitrary small non-array leaves) into ``(leaves, spec)``."""
    leaves: List[np.ndarray] = []

    def walk(o):
        if isinstance(o, np.ndarray):
            leaves.append(o)
            return ("a", len(leaves) - 1)
        if isinstance(o, dict):
            return ("d", [(k, walk(o[k])) for k in sorted(o)])
        if isinstance(o, tuple):
            return ("t", [walk(v) for v in o])
        if isinstance(o, list):
            return ("l", [walk(v) for v in o])
        return ("o", o)  # scalar / None / small object: rides in the spec

    return leaves, walk(obj)


def unflatten_payload(spec: Any, leaves: List[np.ndarray]) -> Any:
    tag, val = spec
    if tag == "a":
        return leaves[val]
    if tag == "d":
        return {k: unflatten_payload(s, leaves) for k, s in val}
    if tag == "t":
        return tuple(unflatten_payload(s, leaves) for s in val)
    if tag == "l":
        return [unflatten_payload(s, leaves) for s in val]
    return val


_ALIGN = 64  # leaf offsets are 64B-aligned: jax CPU zero-copy wants it


def _aligned(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


def measure(leaves: List[np.ndarray]) -> int:
    """Slot bytes needed to hold ``leaves`` at aligned offsets."""
    total = 0
    for a in leaves:
        total = _aligned(total) + a.nbytes
    return total


class ShmFrame:
    """Compact, picklable header of one shm-resident payload.

    ``region``/``slot``/``generation`` locate (and validate) the slot;
    ``entries`` is one ``(dtype_str, shape, offset)`` per array leaf in
    flatten order; ``spec`` rebuilds the pytree; ``spans`` optionally
    carries worker-side obs span tuples (control-plane sized); ``inline``
    holds the whole payload instead when the slot path was unusable
    (oversized frame / exhausted ring) — the per-frame pickle fallback."""

    __slots__ = ("region", "slot", "generation", "entries", "spec", "spans", "inline")

    def __init__(self, region, slot, generation, entries, spec, spans=None, inline=None):
        self.region = region
        self.slot = slot
        self.generation = generation
        self.entries = entries
        self.spec = spec
        self.spans = spans
        self.inline = inline

    def __getstate__(self):
        return (self.region, self.slot, self.generation, self.entries,
                self.spec, self.spans, self.inline)

    def __setstate__(self, st):
        (self.region, self.slot, self.generation, self.entries,
         self.spec, self.spans, self.inline) = st

    @property
    def nbytes(self) -> int:
        return sum(
            int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
            for dt, shape, _ in self.entries
        )


def write_leaves(buf: memoryview, leaves: List[np.ndarray]) -> List[Tuple[str, tuple, int]]:
    """Write ``leaves`` in place at aligned offsets into ``buf``; returns the
    frame entries.  One straight memcpy per leaf — callers pass C-contiguous
    arrays (see :func:`ascontiguous`)."""
    entries: List[Tuple[str, tuple, int]] = []
    off = 0
    for a in leaves:
        off = _aligned(off)
        if a.nbytes:
            dst = np.frombuffer(buf, dtype=np.uint8, count=a.nbytes, offset=off)
            dst[:] = np.frombuffer(
                np.ascontiguousarray(a).data, dtype=np.uint8, count=a.nbytes
            )
        entries.append((a.dtype.str, tuple(a.shape), off))
        off += a.nbytes
    return entries


def read_leaves(
    buf: memoryview,
    entries: List[Tuple[str, tuple, int]],
    copy: bool = True,
) -> List[np.ndarray]:
    """Rebuild leaves from a slot buffer.  ``copy=False`` returns views onto
    the shared slot — valid only while the slot's lifecycle guarantees no
    overwrite (the worker's request-decode path, where the strict
    request/reply protocol orders every overwrite after the reply)."""
    out: List[np.ndarray] = []
    for dt, shape, off in entries:
        dtype = np.dtype(dt)
        n = int(np.prod(shape, dtype=np.int64))
        a = np.frombuffer(buf, dtype=dtype, count=n, offset=off).reshape(shape)
        out.append(a.copy() if copy else a)
    return out
