"""The two wire formats behind the :class:`Transport` protocol.

Both transports speak the SAME frame positions on the control socket —
``("execute", name, <payload>, ctx?)`` out, ``("ok", <payload>)`` back —
and differ only in what ``<payload>`` is:

* :class:`PickleTransport` — the payload rides inline (the numpy column
  dict / output pytree itself, replies optionally wrapped in
  :class:`~repro.transport.frames.WireSpans`).  Byte-for-byte the
  pre-transport wire format; works across machines; the default.
* :class:`SharedMemoryTransport` — the payload is a
  :class:`~repro.transport.frames.ShmFrame` header and the bytes live in a
  per-worker-pair ``multiprocessing.shared_memory`` segment: a request
  ring written by the coordinator and read by the worker, and a reply ring
  written by the worker and read by the coordinator.

Slot lifecycle (the package docstring has the full argument):

* **request slots** are allocated/released by the coordinator — released
  when the request's reply is consumed (by then the worker has necessarily
  finished reading the request, because it replied);
* **reply slots** are allocated by the worker and released when the NEXT
  control frame arrives on its connection (:meth:`note_incoming`) — the
  coordinator only sends after draining every outstanding reply, so a new
  frame proves the previous reply was consumed (or deliberately dropped
  without ever mapping the slot, as the stale-hedge drain does).

A worker attaches via the ``shm_attach`` negotiation frame; in Python's
``SharedMemory`` the *attach* side is ALSO registered with the
``resource_tracker`` (3.10 registers unconditionally), which would
double-unlink the segment — and spam leak warnings — once the coordinator
unlinks it, so :meth:`SharedMemoryTransport.attach` immediately
unregisters the worker side: the coordinator is the one owner of the
segment's lifetime, and a SIGKILL'd worker leaks nothing.
"""
from __future__ import annotations

import os
import uuid
from multiprocessing import shared_memory
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.envknobs import env_float as _env_float
from repro.obs.envknobs import env_int as _env_int
from repro.obs.envknobs import env_str as _env_str

from .frames import (
    FrameTooLargeError,
    ShmFrame,
    WireSpans,
    ascontiguous,
    flatten_payload,
    measure,
    read_leaves,
    unflatten_payload,
    write_leaves,
)
from .ring import SlotRing

TRANSPORT_KINDS = ("pickle", "shm")


def transport_kind(override: Optional[str] = None) -> str:
    """The configured data-plane transport: ``override`` if given, else
    ``REPRO_MH_TRANSPORT`` (default ``pickle``)."""
    kind = (override or _env_str("REPRO_MH_TRANSPORT", "pickle")).strip().lower()
    if kind not in TRANSPORT_KINDS:
        raise ValueError(
            f"unknown transport {kind!r}: expected one of {TRANSPORT_KINDS}"
        )
    return kind


class Transport:
    """Data-plane codec for one coordinator↔worker pair.

    The coordinator calls :meth:`encode_request` / :meth:`decode_reply` /
    :meth:`release`; the worker calls :meth:`decode_request` /
    :meth:`encode_reply` / :meth:`note_incoming`.  The base class IS the
    pickle transport's behaviour; :class:`SharedMemoryTransport` overrides
    the payload representation only — the control protocol around it never
    changes, which is what keeps the two formats interchangeable under
    hedges, probes, drains and deaths.
    """

    kind = "pickle"

    # -- coordinator side --------------------------------------------------

    def encode_request(self, payload: Any) -> Tuple[Any, Optional[int]]:
        """``(wire_payload, slot_token)`` for one request.  The token (None
        on the inline paths) must be handed back to :meth:`release` once
        the request's reply has been consumed or abandoned."""
        return payload, None

    def decode_reply(self, payload: Any) -> Tuple[Any, Optional[list]]:
        """``(output_pytree, worker_span_tuples_or_None)``."""
        if isinstance(payload, WireSpans):
            return payload.out, payload.spans
        return payload, None

    def release(self, token: Optional[int]) -> None:
        """Return a request slot to the ring (no-op for ``None`` — inline
        frames hold no slot)."""

    # -- worker side -------------------------------------------------------

    def decode_request(self, payload: Any) -> Any:
        return payload

    def encode_reply(self, out: Any, spans: Optional[list] = None) -> Any:
        return WireSpans(out, spans) if spans is not None else out

    def note_incoming(self) -> None:
        """Worker hook on EVERY received control frame: the previous reply
        slot (if any) is now provably consumed — release it."""

    # -- lifecycle ---------------------------------------------------------

    def handshake(self) -> Optional[dict]:
        """Attach parameters to send the worker, or None when this
        transport needs no negotiation."""
        return None

    def reclaim(self) -> int:
        """Free every in-flight slot (worker-death path); returns the
        number of slots that were stuck."""
        return 0

    def close(self, unlink: bool = False) -> None:
        """Drop the transport's resources.  ``unlink=True`` (coordinator
        only) also removes the shared segment from the system."""

    def stats(self) -> Dict[str, Any]:
        return {"kind": self.kind}


class PickleTransport(Transport):
    """Inline-pickle data plane — the default-correct fallback."""


class SharedMemoryTransport(Transport):
    """Zero-copy data plane over one shared segment per worker pair.

    Layout: ``[request ring | reply ring]``, each ``nslots`` slots of
    ``slot_bytes`` payload (plus the 16-byte per-slot header).  Construct
    via :meth:`create` (coordinator — owns the segment and its unlink) or
    :meth:`attach` (worker — maps it and renounces tracker ownership).
    """

    kind = "shm"
    NAME_PREFIX = "repro_mh_"

    def __init__(self, shm, name: str, nslots: int, slot_bytes: int, side: str):
        self.name = name
        self.nslots = int(nslots)
        self.slot_bytes = int(slot_bytes)
        self.side = side  # "coordinator" | "worker"
        self._shm = shm
        region = SlotRing.region_bytes(self.nslots, self.slot_bytes)
        self._req = SlotRing(shm.buf, 0, self.nslots, self.slot_bytes)
        self._rep = SlotRing(shm.buf, region, self.nslots, self.slot_bytes)
        self._last_reply_slot: Optional[int] = None
        self._frames = 0
        self._inline = 0
        self._bytes = 0
        reg = obs_metrics.get_registry()
        self._c_written = reg.counter("transport.bytes_written")
        self._c_read = reg.counter("transport.bytes_read")
        self._c_inline = reg.counter("transport.frames_inline")
        self._c_frames = reg.counter("transport.frames_shm")

    # -- construction ------------------------------------------------------

    @classmethod
    def create(
        cls, nslots: Optional[int] = None, slot_bytes: Optional[int] = None
    ) -> "SharedMemoryTransport":
        """Coordinator side: create (and own) one segment for one worker
        pair.  Sizes come from ``REPRO_MH_SHM_SLOTS`` / ``REPRO_MH_SHM_SLOT_MB``
        unless given."""
        nslots = int(nslots if nslots is not None else _env_int("REPRO_MH_SHM_SLOTS", 4))
        if slot_bytes is None:
            slot_bytes = int(_env_float("REPRO_MH_SHM_SLOT_MB", 4.0) * 2**20)
        nslots = max(1, nslots)
        slot_bytes = max(4096, int(slot_bytes))
        name = f"{cls.NAME_PREFIX}{os.getpid()}_{uuid.uuid4().hex[:8]}"
        size = 2 * SlotRing.region_bytes(nslots, slot_bytes)
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        return cls(shm, name, nslots, slot_bytes, "coordinator")

    @classmethod
    def attach(cls, name: str, slots: int, slot_bytes: int) -> "SharedMemoryTransport":
        """Worker side: map an existing segment and immediately renounce
        resource-tracker ownership — Python 3.10 registers attaches too,
        and a tracker that thinks a worker owns the segment would unlink
        it (and warn about a leak) behind the coordinator's back."""
        shm = shared_memory.SharedMemory(name=name, create=False)
        # the creator embeds its pid in the name: a same-process attach (unit
        # tests) shares the creator's tracker cache entry and must NOT remove
        # it, or the creator's unlink-time unregister errors in the tracker
        creator = name[len(cls.NAME_PREFIX):].split("_", 1)[0]
        if creator != str(os.getpid()):
            try:
                from multiprocessing import resource_tracker

                resource_tracker.unregister(shm._name, "shared_memory")
            except Exception:
                pass  # tracker semantics vary by version; the coordinator owns the unlink regardless
        return cls(shm, name, slots, slot_bytes, "worker")

    def handshake(self) -> dict:
        return {"name": self.name, "slots": self.nslots, "slot_bytes": self.slot_bytes}

    # -- codec (shared by both directions) ---------------------------------

    def _encode(self, ring: SlotRing, region: str, payload, spans=None):
        leaves, spec = flatten_payload(payload)
        leaves = [ascontiguous(a) for a in leaves]
        nbytes = measure(leaves)
        rec = obs_trace.get_recorder()
        with rec.span(
            "transport.write", component="transport",
            attrs={"region": region, "bytes": nbytes},
        ):
            try:
                idx, gen, view = ring.acquire(nbytes)
            except FrameTooLargeError:
                # oversized frame / exhausted ring: this one frame rides the
                # socket as inline pickle — bounded, counted, never wrong
                self._inline += 1
                self._c_inline.inc()
                return ShmFrame(region, None, None, None, None,
                                spans=spans, inline=payload), None
            entries = write_leaves(view, leaves)
            ring.commit(idx, gen, nbytes)
        self._frames += 1
        self._bytes += nbytes
        self._c_frames.inc()
        self._c_written.inc(nbytes)
        return ShmFrame(region, idx, gen, entries, spec, spans=spans), idx

    def _decode(self, ring: SlotRing, frame: ShmFrame, copy: bool):
        if frame.inline is not None:
            return frame.inline
        rec = obs_trace.get_recorder()
        with rec.span(
            "transport.read", component="transport",
            attrs={"region": frame.region, "bytes": frame.nbytes},
        ):
            view = ring.read(frame.slot, frame.generation)
            leaves = read_leaves(view, frame.entries, copy=copy)
        self._c_read.inc(frame.nbytes)
        return unflatten_payload(frame.spec, leaves)

    # -- coordinator side --------------------------------------------------

    def encode_request(self, payload):
        return self._encode(self._req, "req", payload)

    def decode_reply(self, payload):
        if isinstance(payload, ShmFrame):
            # copy=True: the reply slot may be overwritten as soon as this
            # connection carries another frame — the output must own its
            # memory before the executor releases the worker's lock
            return self._decode(self._rep, payload, copy=True), payload.spans
        return Transport.decode_reply(self, payload)

    def release(self, token: Optional[int]) -> None:
        ring = self._req
        if token is not None and ring is not None:  # closed: reclaim already freed it
            ring.release(token)

    # -- worker side -------------------------------------------------------

    def decode_request(self, payload):
        if isinstance(payload, ShmFrame):
            # copy=False: views onto the slot are safe here — the
            # coordinator cannot release/rewrite a request slot before this
            # worker's reply is consumed, and the block is only read while
            # executing it (before the reply is sent)
            return self._decode(self._req, payload, copy=False)
        return payload

    def encode_reply(self, out, spans=None):
        frame, slot = self._encode(self._rep, "rep", out, spans=spans)
        if slot is not None:
            self._last_reply_slot = slot
        return frame

    def note_incoming(self) -> None:
        if self._last_reply_slot is not None:
            self._rep.release(self._last_reply_slot)
            self._last_reply_slot = None

    # -- lifecycle ---------------------------------------------------------

    def reclaim(self) -> int:
        req, rep = self._req, self._rep
        stuck = 0
        if req is not None:
            stuck += req.reclaim()
        if rep is not None:
            stuck += rep.reclaim()
        return stuck

    def close(self, unlink: bool = False) -> None:
        self._req = self._rep = None
        shm, self._shm = self._shm, None
        if shm is None:
            return
        try:
            shm.close()
        except BufferError:
            # live views (worker-side zero-copy decodes not yet collected)
            # keep the mapping pinned; the mapping dies with the process and
            # the NAME — the leakable resource — is what unlink removes.
            # Disarm the handle so __del__ doesn't retry the close and spam
            # "Exception ignored" at interpreter shutdown.
            shm._buf = None
            shm._mmap = None
        if unlink:
            try:
                shm.unlink()
            except FileNotFoundError:
                pass

    def stats(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "segment": self.name,
            "slots": self.nslots,
            "slot_bytes": self.slot_bytes,
            "frames": self._frames,
            "inline": self._inline,
            "bytes": self._bytes,
            "in_flight": (self._req.in_flight if self._req is not None else 0),
        }
