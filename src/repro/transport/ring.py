"""Fixed-slot shared-memory ring with generation-stamped slots.

One :class:`SlotRing` manages one *region* (request or reply direction) of
a worker pair's shared segment: ``nslots`` slots of ``slot_bytes`` each,
laid out back to back at a region offset.  Each slot begins with a 16-byte
header — ``(generation: u64, length: u64)`` — followed by the payload area.

The writer side owns allocation: ``acquire`` hands out a free slot and
bumps its generation; ``commit`` stamps the header after the payload is
written; ``release`` returns it to the free set once the peer can no
longer be reading it (see the lifecycle contract in the package
docstring).  The reader side never allocates — ``read`` maps a committed
slot and validates the generation stamp against the frame header, raising
:class:`~repro.transport.frames.TransportDesyncError` on mismatch instead
of returning overwritten bytes.

``reclaim`` frees every in-flight slot at once — the coordinator calls it
through the :class:`~repro.ft.DeathReclaimer` when the peer dies, so a
dead worker's unreleased slot can never wedge the ring for a rejoin.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from .frames import FrameTooLargeError, TransportDesyncError

_HEADER = 16  # u64 generation + u64 committed payload length


class SlotRing:
    """One direction's slot ring over a shared-memory buffer.

    Args:
      buf: the segment's full ``memoryview`` (shared by both regions).
      offset: byte offset of this region within the segment.
      nslots: slots in the ring.
      slot_bytes: payload capacity per slot (header not included).
    """

    def __init__(self, buf: memoryview, offset: int, nslots: int, slot_bytes: int):
        self.nslots = int(nslots)
        self.slot_bytes = int(slot_bytes)
        self._buf = buf
        self._offset = int(offset)
        self._lock = threading.Lock()
        self._free: List[int] = list(range(self.nslots))
        self._gen: List[int] = [0] * self.nslots
        self._inflight: Dict[int, int] = {}  # slot -> generation

    @staticmethod
    def region_bytes(nslots: int, slot_bytes: int) -> int:
        return nslots * (slot_bytes + _HEADER)

    def _slot_view(self, idx: int) -> memoryview:
        start = self._offset + idx * (self.slot_bytes + _HEADER)
        return self._buf[start : start + self.slot_bytes + _HEADER]

    def _header(self, idx: int) -> np.ndarray:
        return np.frombuffer(self._slot_view(idx), dtype=np.uint64, count=2)

    # -- writer side -------------------------------------------------------

    def acquire(self, nbytes: int) -> Tuple[int, int, memoryview]:
        """A free slot able to hold ``nbytes``: ``(slot, generation,
        payload_view)``.  Raises :class:`FrameTooLargeError` when the frame
        cannot fit a slot or every slot is in flight — the caller's cue to
        fall back to an inline-pickle frame."""
        if nbytes > self.slot_bytes:
            raise FrameTooLargeError(
                f"frame of {nbytes} bytes exceeds slot capacity {self.slot_bytes}"
            )
        with self._lock:
            if not self._free:
                raise FrameTooLargeError(
                    f"ring exhausted: all {self.nslots} slots in flight"
                )
            idx = self._free.pop(0)
            self._gen[idx] += 1
            gen = self._gen[idx]
            self._inflight[idx] = gen
        view = self._slot_view(idx)
        return idx, gen, view[_HEADER:]

    def commit(self, idx: int, gen: int, nbytes: int) -> None:
        """Stamp the slot header after its payload is fully written."""
        hdr = self._header(idx)
        hdr[0] = np.uint64(gen)
        hdr[1] = np.uint64(nbytes)

    def release(self, idx: int) -> None:
        """Return a slot to the free set (idempotent: a slot reclaimed on a
        death path may see a late release from a draining caller)."""
        with self._lock:
            if idx in self._inflight:
                del self._inflight[idx]
                self._free.append(idx)

    def reclaim(self) -> int:
        """Free every in-flight slot; returns how many were stuck.  The
        death path: the peer that would have consumed (and thereby
        released) them is gone."""
        with self._lock:
            stuck = len(self._inflight)
            for idx in list(self._inflight):
                del self._inflight[idx]
                self._free.append(idx)
        return stuck

    @property
    def in_flight(self) -> int:
        with self._lock:
            return len(self._inflight)

    # -- reader side -------------------------------------------------------

    def read(self, idx: int, gen: int) -> memoryview:
        """Map a committed slot's payload, validating its generation stamp
        against the frame header — a mismatch means the writer overwrote a
        slot whose reader had not finished (a lifecycle violation), and the
        bytes here would be another frame's."""
        if not 0 <= idx < self.nslots:
            raise TransportDesyncError(f"slot {idx} out of range 0..{self.nslots - 1}")
        hdr = self._header(idx)
        if int(hdr[0]) != int(gen):
            raise TransportDesyncError(
                f"slot {idx} generation {int(hdr[0])} != frame generation {gen}: "
                "slot overwritten while its frame was in flight"
            )
        return self._slot_view(idx)[_HEADER : _HEADER + int(hdr[1])]
