"""Concurrency lint: AST lock-discipline analysis over the threaded tiers.

Scans Python sources (by default ``serve/gateway/``, ``ft/`` and ``obs/``
— the threaded tiers of the serving stack) and builds, per file, the set
of known lock objects (any ``threading.Lock/RLock/Condition/Semaphore``
assignment discovers the attribute or variable name) plus a linear
intra-procedural model of which locks are held at every statement.  Three
rules:

* ``lock-order-inversion`` (error) — the global acquisition graph (lock A
  held while acquiring lock B) contains a cycle: two call paths take the
  same pair of locks in opposite orders, the classic ABBA deadlock.
* ``lock-blocking-call`` (error) — a blocking call executed while holding
  a lock: ``time.sleep``, ``Connection.recv/send``, unbounded or >100ms
  ``poll``, socket ``accept/connect``, ``select.select``, ``Thread.join``,
  ``Event.wait`` (waiting on the HELD condition itself is exempt — that
  atomically releases it), and ``close()`` of connection-like objects.
  Every request queued behind that lock stalls for the call's duration —
  the liveness-sweeper-vs-dispatch bug class.
* ``lock-unguarded-mutation`` (warning) — a field mutated under a lock
  somewhere in the file is also mutated with no lock held (constructors
  exempt): either the lock is unnecessary or the unguarded site is a race.

The model is deliberately intra-procedural and name-granular (locks are
identified by attribute/variable name): simple enough to stay exact about
what it claims, with ``# analyze: allow(<rule>) <reason>`` suppressions —
on the finding line or the enclosing ``def`` line — for the sites where
blocking under a lock IS the design (e.g. a per-connection lock that
exists to serialize a request/reply socket protocol)."""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Report

ORDER_INVERSION = "lock-order-inversion"
BLOCKING_CALL = "lock-blocking-call"
UNGUARDED_MUTATION = "lock-unguarded-mutation"

#: default scan roots, relative to the package source root
DEFAULT_SUBDIRS = ("serve/gateway", "ft", "obs", "transport")

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}

#: attribute calls that block the calling thread
_BLOCKING_ATTRS = {
    "recv": "Connection.recv blocks until a frame arrives",
    "recv_bytes": "Connection.recv_bytes blocks until a frame arrives",
    "send": "Connection.send blocks on a full socket buffer",
    "send_bytes": "Connection.send_bytes blocks on a full socket buffer",
    "accept": "accept blocks until a client dials in",
    "connect": "connect blocks for the TCP handshake",
    "sleep": "sleep stalls every thread queued on the held lock",
    "select": "select blocks up to its timeout",
    "join": "join blocks until the thread exits",
    "wait": "wait blocks until notified",
}
_MUTATORS = {
    "append", "appendleft", "extend", "insert", "pop", "popleft", "clear",
    "add", "discard", "remove", "update", "setdefault",
}
_CONN_HINTS = ("conn", "sock", "listener", "client")
_POLL_BOUND = 0.1  # poll(<=100ms) is a bounded micro-poll, not a block


def _base_name(expr) -> Optional[str]:
    """The identifying name of a lock-ish expression: ``w.lock`` -> "lock",
    ``self._mlock`` -> "_mlock", bare ``lk`` -> "lk"."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _expr_text(expr) -> str:
    try:
        return ast.unparse(expr)
    except Exception:  # pragma: no cover
        return "<expr>"


def _is_lock_ctor(call) -> bool:
    if not isinstance(call, ast.Call):
        return False
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else getattr(fn, "id", None)
    return name in _LOCK_CTORS


def discover_lock_names(trees: Sequence[ast.AST]) -> Set[str]:
    """Every attribute/variable name ever assigned a threading primitive,
    across all scanned files (locks cross module boundaries: the executor
    holds a ``_Worker.lock`` defined elsewhere)."""
    names: Set[str] = set()
    for tree in trees:
        for node in ast.walk(tree):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                value = node.value
                if value is None or not _is_lock_ctor(value):
                    continue
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in targets:
                    n = _base_name(t)
                    if n:
                        names.add(n)
    return names


class _Mutation:
    __slots__ = ("attr", "locked", "file", "line", "func", "def_line", "is_init")

    def __init__(self, attr, locked, file, line, func, def_line, is_init):
        self.attr = attr
        self.locked = locked
        self.file = file
        self.line = line
        self.func = func
        self.def_line = def_line
        self.is_init = is_init


class _FileScan:
    """One file's linear lock-state walk."""

    def __init__(self, path: str, tree: ast.AST, lock_names: Set[str]):
        self.path = path
        self.lock_names = lock_names
        self.held: Dict[str, int] = {}  # lock name -> hold count
        self.hold_order: List[str] = []
        self.edges: List[Tuple[str, str, str, int, Optional[int]]] = []
        self.blocking: List[Tuple[str, int, str, Optional[int]]] = []
        self.mutations: List[_Mutation] = []
        self.def_lines: Dict[int, int] = {}  # finding line -> enclosing def line
        self._func: Optional[str] = None
        self._def_line: Optional[int] = None
        for node in tree.body if isinstance(tree, ast.Module) else []:
            self._stmt(node)

    # -- lock state -----------------------------------------------------
    def _acquire(self, name: str, line: int) -> None:
        for h in self.hold_order:
            if h != name and self.held.get(h, 0) > 0:
                self.edges.append((h, name, self.path, line, self._def_line))
        self.held[name] = self.held.get(name, 0) + 1
        if name not in self.hold_order:
            self.hold_order.append(name)

    def _release(self, name: str) -> None:
        if self.held.get(name, 0) > 0:
            self.held[name] -= 1

    def _any_held(self) -> List[str]:
        return [h for h in self.hold_order if self.held.get(h, 0) > 0]

    # -- statements -----------------------------------------------------
    def _stmt(self, st) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            outer = (self._func, self._def_line, self.held, self.hold_order)
            self._func, self._def_line = st.name, st.lineno
            self.held, self.hold_order = {}, []  # a new frame runs later
            for sub in st.body:
                self._stmt(sub)
            self._func, self._def_line, self.held, self.hold_order = outer
        elif isinstance(st, ast.ClassDef):
            for sub in st.body:
                self._stmt(sub)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            acquired = []
            for item in st.items:
                self._expr(item.context_expr)
                name = _base_name(item.context_expr)
                if name in self.lock_names:
                    self._acquire(name, st.lineno)
                    acquired.append(name)
            for sub in st.body:
                self._stmt(sub)
            for name in reversed(acquired):
                self._release(name)
        elif isinstance(st, ast.If):
            self._expr(st.test)
            for sub in st.body:
                self._stmt(sub)
            for sub in st.orelse:
                self._stmt(sub)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self._expr(st.iter)
            for sub in st.body + st.orelse:
                self._stmt(sub)
        elif isinstance(st, ast.While):
            self._expr(st.test)
            for sub in st.body + st.orelse:
                self._stmt(sub)
        elif isinstance(st, ast.Try):
            for sub in st.body:
                self._stmt(sub)
            for h in st.handlers:
                for sub in h.body:
                    self._stmt(sub)
            for sub in st.orelse + st.finalbody:
                self._stmt(sub)
        else:
            if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    st.targets if isinstance(st, ast.Assign) else [st.target]
                )
                for t in targets:
                    self._mutation_target(t)
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._expr(child)

    def _mutation_target(self, t) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._mutation_target(el)
            return
        if isinstance(t, ast.Subscript):
            t = t.value
        if isinstance(t, ast.Attribute):
            self._record_mutation(t.attr, t.lineno)

    def _record_mutation(self, attr: str, line: int) -> None:
        self.mutations.append(
            _Mutation(
                attr,
                bool(self._any_held()),
                self.path,
                line,
                self._func,
                self._def_line,
                self._func in (None, "__init__", "__new__", "__post_init__"),
            )
        )

    # -- expressions ----------------------------------------------------
    def _expr(self, e) -> None:
        for node in ast.walk(e):
            if isinstance(node, ast.Call):
                self._call(node)

    def _call(self, call: ast.Call) -> None:
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return
        attr = fn.attr
        recv_name = _base_name(fn.value)
        if attr == "acquire" and recv_name in self.lock_names:
            self._acquire(recv_name, call.lineno)
            return
        if attr == "release" and recv_name in self.lock_names:
            self._release(recv_name)
            return
        if attr in _MUTATORS and isinstance(fn.value, ast.Attribute):
            self._record_mutation(fn.value.attr, call.lineno)
        held = self._any_held()
        if not held:
            return
        if attr in _BLOCKING_ATTRS:
            if isinstance(fn.value, (ast.Constant, ast.JoinedStr)):
                return  # "sep".join(...) and friends
            if attr == "wait" and recv_name in held:
                return  # Condition.wait on the held condition releases it
            self._blocking(call, attr, _BLOCKING_ATTRS[attr], held)
        elif attr == "poll" and self._poll_blocks(call):
            self._blocking(
                call, "poll", "unbounded or >100ms poll stalls the lock", held
            )
        elif attr == "close" and recv_name and any(
            h in recv_name.lower() for h in _CONN_HINTS
        ):
            self._blocking(
                call, "close", "socket close can block on linger/flush", held
            )

    @staticmethod
    def _poll_blocks(call: ast.Call) -> bool:
        args = list(call.args) + [
            kw.value for kw in call.keywords if kw.arg == "timeout"
        ]
        if not args:
            return True  # poll() blocks until data arrives
        a = args[0]
        if isinstance(a, ast.Constant) and isinstance(a.value, (int, float)):
            return a.value > _POLL_BOUND
        return True  # a computed timeout cannot be proven small

    def _blocking(self, call, what, why, held) -> None:
        self.blocking.append(
            (
                f"{_expr_text(call.func)}() [{what}] while holding "
                f"{'+'.join(held)}: {why}",
                call.lineno,
                what,
                self._def_line,
            )
        )


def check(
    paths: Sequence[str],
) -> Report:
    """Run the three lock rules over ``paths`` (files or directories) and
    return the report with inline suppressions already applied."""
    files: List[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    sources: Dict[str, str] = {}
    trees: Dict[str, ast.AST] = {}
    rep = Report()
    for f in files:
        text = f.read_text()
        try:
            trees[str(f)] = ast.parse(text)
        except SyntaxError as e:  # pragma: no cover - scanned code is valid
            rep.add(
                BLOCKING_CALL, "error", f"cannot parse: {e}", str(f), e.lineno
            )
            continue
        sources[str(f)] = text

    lock_names = discover_lock_names(list(trees.values()))
    scans = [_FileScan(path, tree, lock_names) for path, tree in trees.items()]

    def_lines: Dict[str, Dict[int, int]] = {}

    def note_def(path, line, dline):
        if dline is not None:
            def_lines.setdefault(path, {})[line] = dline

    # blocking calls
    for s in scans:
        for msg, line, _what, dline in s.blocking:
            rep.add(BLOCKING_CALL, "error", msg, s.path, line)
            note_def(s.path, line, dline)

    # lock-order inversions: cycle = both directions of a pair observed
    edges: Dict[Tuple[str, str], Tuple[str, int, Optional[int]]] = {}
    for s in scans:
        for a, b, path, line, dline in s.edges:
            edges.setdefault((a, b), (path, line, dline))
    for (a, b), (path, line, dline) in sorted(edges.items()):
        if a < b and (b, a) in edges:
            rpath, rline, _ = edges[(b, a)]
            rep.add(
                ORDER_INVERSION,
                "error",
                f"lock {b!r} is acquired while holding {a!r} here, but "
                f"{rpath}:{rline} acquires them in the opposite order — "
                f"ABBA deadlock",
                path,
                line,
            )
            note_def(path, line, dline)

    # unguarded mutations of elsewhere-guarded fields (per file)
    for s in scans:
        guarded = {
            m.attr for m in s.mutations if m.locked and not m.is_init
        }
        seen: Set[Tuple[str, int]] = set()
        for m in s.mutations:
            if m.locked or m.is_init or m.attr not in guarded:
                continue
            if (m.attr, m.line) in seen:
                continue
            seen.add((m.attr, m.line))
            rep.add(
                UNGUARDED_MUTATION,
                "warning",
                f"field {m.attr!r} is mutated here with no lock held but is "
                f"lock-guarded elsewhere in this file"
                + (f" (in {m.func})" if m.func else ""),
                m.file,
                m.line,
            )
            note_def(m.file, m.line, m.def_line)

    for path, text in sources.items():
        rep.apply_suppressions(path, text, def_lines.get(path))
    return rep


def default_paths(src_root) -> List[str]:
    root = pathlib.Path(src_root)
    return [str(root / "repro" / sub) for sub in DEFAULT_SUBDIRS]
