"""Env-knob rule: every ``REPRO_*`` variable referenced under ``src/``
must be registered in :mod:`repro.obs.envknobs` and documented in the
README.

This is the former ``tests/test_obs.py`` static scan promoted to an
analyzer rule so there is exactly one implementation and one findings
pipeline; the test now asserts through this API.
"""
from __future__ import annotations

import pathlib
import re
from typing import Dict, List, Optional, Tuple

from .findings import Report

KNOB_UNREGISTERED = "env-knob-unregistered"
KNOB_UNDOCUMENTED = "env-knob-undocumented"

_KNOB_RE = re.compile(r"REPRO_[A-Z0-9_]+")


def knob_refs(src_root) -> Dict[str, List[Tuple[str, int]]]:
    """Every ``REPRO_*`` name referenced under ``src_root`` mapped to its
    reference sites (file, line).  A reference immediately followed by
    ``*`` is a wildcard doc mention (``REPRO_OBS_*``), not a knob."""
    refs: Dict[str, List[Tuple[str, int]]] = {}
    for path in sorted(pathlib.Path(src_root).rglob("*.py")):
        for lineno, line in enumerate(path.read_text().splitlines(), start=1):
            for m in _KNOB_RE.finditer(line):
                if m.end() < len(line) and line[m.end()] == "*":
                    continue
                refs.setdefault(m.group(0).rstrip("_"), []).append(
                    (str(path), lineno)
                )
    return refs


def check(src_root, readme_path, knobs: Optional[dict] = None) -> Report:
    """Report unregistered/undocumented knobs.  ``knobs`` defaults to the
    live :data:`repro.obs.envknobs.KNOBS` registry."""
    if knobs is None:
        from repro.obs import envknobs

        knobs = envknobs.KNOBS
    rep = Report()
    refs = knob_refs(src_root)
    try:
        readme = pathlib.Path(readme_path).read_text()
    except OSError:
        readme = ""
        rep.add(
            KNOB_UNDOCUMENTED,
            "error",
            f"README not found at {readme_path}",
            str(readme_path),
        )
    for name in sorted(refs):
        file, line = refs[name][0]
        if name not in knobs:
            rep.add(
                KNOB_UNREGISTERED,
                "error",
                f"{name} is read from the environment but never registered "
                f"in repro.obs.envknobs — undiscoverable, undocumented "
                f"default",
                file,
                line,
            )
        if readme and name not in readme:
            rep.add(
                KNOB_UNDOCUMENTED,
                "error",
                f"{name} is referenced in src/ but not documented in "
                f"README.md",
                file,
                line,
            )
    # registered but silently absent from the README (registry drift)
    for name in sorted(knobs):
        if readme and name not in readme:
            rep.add(
                KNOB_UNDOCUMENTED,
                "error",
                f"{name} is registered in envknobs but missing from "
                f"README.md",
            )
    return rep
