"""``python -m repro.analyze`` — run every analyzer pass over this repo.

Passes, in order:

1. Concurrency lint over the threaded tiers (``serve/gateway``, ``ft``,
   ``obs``).
2. Env-knob registration/documentation check over all of ``src/``.
3. Plan verification: fit the quickstart and LTR pipelines on synthetic
   data, verify the staged and fused plans by abstract interpretation
   (fusion legality included), and round-trip an export bundle through
   the structural gate.  Skip with ``--skip-plans`` for a fast lint-only
   run.

Exit code is 1 when ``--strict`` and any active error-severity finding
remains, else 0.  ``--json PATH`` additionally writes the machine-
readable report.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from .findings import PlanSchemaError, Report
from . import knobcheck, lockcheck, plan_check


def _repo_root() -> pathlib.Path:
    # src/repro/analyze/__main__.py -> repo root is three levels up from src/
    return pathlib.Path(__file__).resolve().parents[3]


def _quickstart_pipeline():
    import numpy as np
    import jax.numpy as jnp

    from repro.core import (
        HashIndexTransformer,
        KamaeSparkPipeline,
        LogTransformer,
        StringIndexEstimator,
        StringToStringListTransformer,
    )
    from repro.core import types as T

    rng = np.random.default_rng(1)
    n = 64
    batch = {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(rng.choice(["Action|Comedy", "Drama"], n), 32)
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000,
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=4, defaultValue="PADDED",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
        ]
    )
    return pipe.fit(batch), batch, None


def _ltr_pipeline():
    from repro.apps.ltr_pipeline import build_ltr_pipeline
    from repro.data import ltr_rows

    train = ltr_rows(96, seed=0)
    fitted, cols = build_ltr_pipeline(train)
    batch = {k: v[:48] for k, v in ltr_rows(48, seed=5).items()}
    return fitted, batch, cols


def check_plans(report: Report) -> None:
    """Verify the repo's own shipped pipelines: staged + fused plans via
    abstract interpretation, plus an export-bundle structural round-trip."""
    from repro.core.export import PreprocessModel
    from repro.core.plan import TransformPlan

    for name, build in (("quickstart", _quickstart_pipeline), ("ltr", _ltr_pipeline)):
        fitted, batch, cols = build()
        for fuse in (False, True):
            plan = TransformPlan(fitted.stages, outputs=cols, fuse=fuse)
            mode = "fused" if fuse else "staged"
            # feed only the columns the pruned plan reads — extra provided
            # columns are a (correct) skew warning, not a repo defect
            req = set(plan_check.plan_required_inputs(plan))
            ex = {k: v for k, v in batch.items() if k in req}
            report.extend(
                plan_check.verify_plan(plan, example=ex, where=f"{name}/{mode}")
            )
        # export round-trip through the structural gate
        model = PreprocessModel.from_fitted(fitted, outputs=cols)
        try:
            PreprocessModel.load_bytes(model.save_bytes())
        except PlanSchemaError as e:
            report.extend(Report(e.findings))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze", description=__doc__
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 on any active error-severity finding",
    )
    ap.add_argument("--json", metavar="PATH", help="write JSON report here")
    ap.add_argument(
        "--skip-plans", action="store_true",
        help="lint only: skip fitting/verifying the repo pipelines",
    )
    ap.add_argument(
        "--root", metavar="DIR", default=None,
        help="repo root (default: inferred from this file's location)",
    )
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root) if args.root else _repo_root()
    src = root / "src"
    report = Report()

    report.extend(lockcheck.check(lockcheck.default_paths(src)))
    report.extend(knobcheck.check(src, root / "README.md"))
    if not args.skip_plans:
        check_plans(report)

    print(report.format_text())
    if args.json:
        report.dump_json(args.json)
    if args.strict and report.errors():
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
