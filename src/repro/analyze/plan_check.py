"""Static plan verifier: abstract interpretation over TransformPlan schedules.

The verifier replays a plan's scheduled nodes over an ABSTRACT environment
of ``jax.ShapeDtypeStruct`` columns — ``jax.eval_shape`` traces each
stage's ``coerce -> apply -> coerce_out`` exactly as ``TransformPlan.
_execute`` would, but nothing executes and no buffer is allocated — and
checks, per node:

* ``plan-missing-input``    a column read that no prior node produced and
                            the input schema does not provide (skew: the
                            artifact will KeyError, or worse, silently bind
                            a wrong same-named column at first execute);
* ``plan-use-after-free``   a column read after an earlier node's
                            ``dead_after`` dropped it from the environment —
                            the liveness analogue of referencing a donated
                            buffer after donation;
* ``plan-version-skew``     an ``in_spec`` whose recorded column version
                            disagrees with the abstract write counter (a
                            mutated / re-ordered / truncated schedule: the
                            plan's CSE keys would silently alias stale
                            values);
* ``plan-fusion-legality``  a ``_FusedNode`` whose lowered ChainProgram is
                            not dtype/shape-equivalent to replaying its
                            staged member stages (a ``ChainFallback`` trace
                            is legal — the runtime falls back to the staged
                            members, bit-identity preserved);
* ``plan-eval-error``       a stage whose abstract replay raises — the plan
                            cannot execute on inputs of this schema;
* ``plan-missing-output``   a requested output absent from the final
                            environment;
* ``plan-dead-column``      (warning) a produced column that nothing reads,
                            that is not an output and that liveness never
                            frees — the planner missed a dead column and
                            every batch pays its memory;
* ``plan-schema-skew``      declared/provided schema disagreement: missing
                            or dtype-kind-mismatched columns are errors
                            (string-vs-numeric skew silently corrupts),
                            extra provided columns and width-only dtype
                            differences are warnings.

A structural subset of these checks (no jax, no tracing) runs as the cheap
gate inside export-bundle save/load and ``registry.register`` — see
:func:`verify_schedule_structure` and :func:`check_schema`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Report

# rule ids (importable so tests/gates never typo a string)
MISSING_INPUT = "plan-missing-input"
USE_AFTER_FREE = "plan-use-after-free"
VERSION_SKEW = "plan-version-skew"
FUSION_LEGALITY = "plan-fusion-legality"
EVAL_ERROR = "plan-eval-error"
MISSING_OUTPUT = "plan-missing-output"
DEAD_COLUMN = "plan-dead-column"
SCHEMA_SKEW = "plan-schema-skew"


# ---------------------------------------------------------------------------
# schemas: {col: {"dtype": str, "shape": [trailing dims...]}}
# ---------------------------------------------------------------------------


def schema_of_batch(batch) -> Dict[str, dict]:
    """Column schema of a concrete batch; shape excludes the leading batch
    axis so the schema is batch-size-agnostic."""
    out = {}
    for k, v in batch.items():
        a = np.asarray(v)
        out[k] = {"dtype": str(a.dtype), "shape": [int(d) for d in a.shape[1:]]}
    return out


def _structs_from_schema(schema: Dict[str, dict], batch: int = 2):
    import jax

    return {
        c: jax.ShapeDtypeStruct((batch, *s["shape"]), np.dtype(s["dtype"]))
        for c, s in schema.items()
    }


def _structs_from_batch(batch):
    import jax

    return {
        c: jax.ShapeDtypeStruct(np.asarray(v).shape, np.asarray(v).dtype)
        for c, v in batch.items()
    }


def _dtype_kind(dtype: str) -> str:
    """Coarse dtype class for skew severity: uint8 is the string-bytes
    marker (see ``repro.core.types.is_string_col``), so string-vs-numeric
    is a kind mismatch while float32-vs-float64 is only a width note."""
    d = np.dtype(dtype)
    if d == np.uint8:
        return "string"
    if d.kind in ("i", "u", "b"):
        return "int"
    if d.kind == "f":
        return "float"
    return d.kind


def check_schema(
    required: Dict[str, Optional[dict]],
    provided: Dict[str, dict],
    where: str = "schema",
    allow_extra: bool = True,
) -> Report:
    """Skew between a plan's required inputs and a provided schema (an
    export bundle's recorded fit schema, or a registry example row).

    ``required`` maps column -> schema dict or None (name known, dtype
    unknown).  Missing columns and dtype-KIND mismatches (string vs
    numeric, float vs int) are errors; width-only differences and trailing
    shape differences are warnings; extra provided columns are warnings
    unless ``allow_extra``."""
    rep = Report()
    for col, spec in sorted(required.items()):
        got = provided.get(col)
        if got is None:
            rep.add(
                SCHEMA_SKEW,
                "error",
                f"{where}: required input column {col!r} missing",
            )
            continue
        if spec is None:
            continue
        want_dt, got_dt = str(spec["dtype"]), str(got["dtype"])
        if want_dt != got_dt:
            if _dtype_kind(want_dt) != _dtype_kind(got_dt):
                rep.add(
                    SCHEMA_SKEW,
                    "error",
                    f"{where}: column {col!r} dtype skew: pipeline was fit "
                    f"on {want_dt}, provided {got_dt}",
                )
            else:
                rep.add(
                    SCHEMA_SKEW,
                    "warning",
                    f"{where}: column {col!r} dtype width differs "
                    f"({want_dt} vs {got_dt})",
                )
        elif list(spec.get("shape", [])) != list(got.get("shape", [])):
            rep.add(
                SCHEMA_SKEW,
                "warning",
                f"{where}: column {col!r} trailing shape differs "
                f"({spec.get('shape')} vs {got.get('shape')})",
            )
    if not allow_extra:
        for col in sorted(set(provided) - set(required)):
            rep.add(
                SCHEMA_SKEW,
                "warning",
                f"{where}: column {col!r} provided but never read",
            )
    return rep


# ---------------------------------------------------------------------------
# node replay (abstract: everything below runs only under jax.eval_shape)
# ---------------------------------------------------------------------------


def _coerce_abstract(stage, spec, arr):
    from repro.core import types as T

    _col, _ver, token = spec
    if token is None:
        return arr
    if token[0] == "string" and T.is_string_col(arr):
        return arr  # "string" coercion is identity on byte columns
    return stage._coerce(arr)


def _replay_node(node):
    def run(*arrs):
        stage = node.stage
        ins = tuple(
            _coerce_abstract(stage, spec, a)
            for spec, a in zip(node.in_specs, arrs)
        )
        outs = stage.apply(stage.weights(), ins)
        return tuple(stage._coerce_out(o) for o in outs)

    return run


def _replay_members(node):
    """Replay a fused node's member stages one by one (the semantics the
    runtime falls back to) over a chain-local environment; returns the
    chain's external outputs in ``out_cols`` order."""

    def run(*arrs):
        sub = {spec[0]: a for spec, a in zip(node.in_specs, arrs)}
        for m in node.members:
            stage = m.stage
            ins = tuple(
                _coerce_abstract(stage, spec, sub[spec[0]])
                for spec in m.in_specs
            )
            outs = stage.apply(stage.weights(), ins)
            outs = tuple(stage._coerce_out(o) for o in outs)
            sub.update(zip(m.out_cols, outs))
        return tuple(sub[c] for c in node.out_cols)

    return run


def _replay_program(program):
    from repro.kernels.fused_transform import ops as fused_ops

    def run(*arrs):
        return tuple(fused_ops.execute_chain_xla(program, list(arrs)))

    return run


# ---------------------------------------------------------------------------
# the abstract-interpretation walk
# ---------------------------------------------------------------------------


def verify_plan(
    plan,
    example=None,
    schema: Optional[Dict[str, dict]] = None,
    check_fusion: bool = True,
    where: str = "plan",
) -> Report:
    """Verify a built :class:`~repro.core.plan.TransformPlan` against an
    input schema (or a concrete example batch) WITHOUT executing it.

    Walks the scheduled nodes over an abstract environment, tracing each
    node with ``jax.eval_shape`` and checking every rule in the module
    docstring.  Returns the findings report; empty = the plan is provably
    executable on inputs of this schema and every fused chain is dtype/
    shape-equivalent to its staged members."""
    import jax

    from repro.core.plan import _FusedNode
    from repro.core import fusion

    rep = Report()
    if example is not None:
        env = _structs_from_batch(example)
    elif schema is not None:
        env = _structs_from_schema(schema)
    else:
        raise ValueError("verify_plan needs an example batch or a schema")
    provided = set(env)

    version: Dict[str, int] = {}
    freed: Dict[str, int] = {}  # col -> index of the node whose dead_after dropped it
    produced_at: Dict[str, int] = {}
    read_cols: set = set()
    poisoned: set = set()  # cols whose structs are unknown after an earlier error

    def check_reads(specs, i) -> bool:
        """Validate one node's reads; False when any input is unusable."""
        usable = True
        for col, ver, _tok in specs:
            read_cols.add(col)
            if col not in env:
                if col in poisoned:
                    usable = False
                elif col in freed:
                    rep.add(
                        USE_AFTER_FREE,
                        "error",
                        f"{where}: node {i} reads column {col!r} after node "
                        f"{freed[col]} freed it (dead_after) — the donated/"
                        f"dropped buffer no longer exists",
                    )
                    usable = False
                else:
                    rep.add(
                        MISSING_INPUT,
                        "error",
                        f"{where}: node {i} reads column {col!r} which no "
                        f"prior node produces and the input schema does not "
                        f"provide",
                    )
                    usable = False
                continue
            if ver != version.get(col, 0):
                rep.add(
                    VERSION_SKEW,
                    "error",
                    f"{where}: node {i} expects version {ver} of column "
                    f"{col!r} but the schedule produces version "
                    f"{version.get(col, 0)} at this point (mutated or "
                    f"re-ordered schedule)",
                )
        return usable

    def bump(cols, i):
        for c in cols:
            version[c] = version.get(c, 0) + 1
            produced_at[c] = i
            freed.pop(c, None)

    for i, node in enumerate(plan._nodes):
        if isinstance(node, _FusedNode):
            usable = check_reads(node.in_specs, i)
            ins = [env[c] for c, _, _ in node.in_specs if c in env]
            member_structs = None
            if usable:
                try:
                    member_structs = jax.eval_shape(_replay_members(node), *ins)
                except Exception as e:  # pragma: no cover - defensive
                    rep.add(
                        EVAL_ERROR,
                        "error",
                        f"{where}: fused node {i} member replay failed: "
                        f"{type(e).__name__}: {e}",
                    )
            if usable and check_fusion:
                try:
                    prog_structs = jax.eval_shape(
                        _replay_program(node.program), *ins
                    )
                except fusion.ChainFallback:
                    prog_structs = None  # legal: runtime falls back to members
                except Exception as e:
                    prog_structs = None
                    rep.add(
                        FUSION_LEGALITY,
                        "error",
                        f"{where}: fused node {i} program "
                        f"{node.program.signature()} does not trace: "
                        f"{type(e).__name__}: {e}",
                    )
                if prog_structs is not None and member_structs is not None:
                    for col, ps, ms in zip(
                        node.out_cols, prog_structs, member_structs
                    ):
                        if ps.dtype != ms.dtype or ps.shape != ms.shape:
                            rep.add(
                                FUSION_LEGALITY,
                                "error",
                                f"{where}: fused node {i} column {col!r}: "
                                f"program yields {ps.dtype}{list(ps.shape)} "
                                f"but staged members yield "
                                f"{ms.dtype}{list(ms.shape)} — fusion is not "
                                f"semantics-preserving",
                            )
            # member-level version bookkeeping (internal cols included)
            for m in node.members:
                bump(m.out_cols, i)
            if member_structs is not None:
                env.update(zip(node.out_cols, member_structs))
                poisoned.difference_update(node.out_cols)
            else:
                poisoned.update(node.out_cols)
                for c in node.out_cols:
                    env.pop(c, None)
        else:
            usable = check_reads(node.in_specs, i)
            out_structs = None
            if usable:
                try:
                    out_structs = jax.eval_shape(
                        _replay_node(node), *[env[c] for c, _, _ in node.in_specs]
                    )
                except Exception as e:
                    stage_name = type(getattr(node.stage, "stage", node.stage)).__name__
                    rep.add(
                        EVAL_ERROR,
                        "error",
                        f"{where}: node {i} ({stage_name} -> "
                        f"{node.out_cols}) cannot execute on this input "
                        f"schema: {type(e).__name__}: {e}",
                    )
            bump(node.out_cols, i)
            if out_structs is not None:
                env.update(zip(node.out_cols, out_structs))
                poisoned.difference_update(node.out_cols)
            else:
                poisoned.update(node.out_cols)
                for c in node.out_cols:
                    env.pop(c, None)
        for c in node.dead_after:
            if env.pop(c, None) is not None:
                freed[c] = i

    outputs = plan._outputs
    if outputs is not None:
        for c in outputs:
            if c not in env and c not in poisoned:
                why = (
                    f"freed by node {freed[c]}'s dead_after"
                    if c in freed
                    else "never produced"
                )
                rep.add(
                    MISSING_OUTPUT,
                    "error",
                    f"{where}: requested output column {c!r} absent from the "
                    f"final environment ({why})",
                )
        keep = set(outputs)
        for c, at in sorted(produced_at.items()):
            if c in keep or c in read_cols or c not in env:
                continue
            rep.add(
                DEAD_COLUMN,
                "warning",
                f"{where}: column {c!r} (produced by node {at}) is never "
                f"read, is not a requested output and is never freed — the "
                f"planner missed a dead column",
            )
        # provided columns nothing reads and no output requests: skew note
        unused = sorted(
            provided - read_cols - keep
        )
        for c in unused:
            rep.add(
                SCHEMA_SKEW,
                "warning",
                f"{where}: provided input column {c!r} is never read by any "
                f"scheduled node",
            )
    return rep


# ---------------------------------------------------------------------------
# structural checks (no jax): the cheap export/registry gate
# ---------------------------------------------------------------------------


def plan_required_inputs(plan) -> List[str]:
    """External input columns the scheduled nodes read (works for full-env
    plans too, where ``plan.required_inputs()`` returns None)."""
    produced: set = set()
    required: List[str] = []
    for n in plan._nodes:
        for c, _, _ in n.in_specs:
            if c not in produced and c not in required:
                required.append(c)
        produced.update(n.out_cols)
        produced.update(getattr(n, "internal", ()))
    for c in plan._outputs or ():
        if c not in produced and c not in required:
            required.append(c)
    return required


def _sched_walk_member(d: dict, state: dict, rep: Report, where: str, i) -> None:
    """Version/liveness bookkeeping for one staged node dict of a schedule."""
    env, version, freed = state["env"], state["version"], state["freed"]
    for col, ver, _tok in d["in_specs"]:
        state["read"].add(col)
        if col not in env:
            if col in freed:
                rep.add(
                    USE_AFTER_FREE,
                    "error",
                    f"{where}: node {i} reads column {col!r} after node "
                    f"{freed[col]} freed it (dead_after)",
                )
            elif state["closed"]:
                rep.add(
                    MISSING_INPUT,
                    "error",
                    f"{where}: node {i} reads column {col!r} which is "
                    f"neither produced upstream nor in the recorded input "
                    f"schema",
                )
            else:
                env.add(col)  # open world: assume a raw input column
        if ver != version.get(col, 0):
            rep.add(
                VERSION_SKEW,
                "error",
                f"{where}: node {i} expects version {ver} of column {col!r} "
                f"but the schedule produces version {version.get(col, 0)} "
                f"at this point",
            )
    for c in d["out_cols"]:
        version[c] = version.get(c, 0) + 1
        env.add(c)
        freed.pop(c, None)


def verify_schedule_structure(
    sched: dict,
    n_stages: Optional[int] = None,
    input_schema: Optional[Dict[str, dict]] = None,
    where: str = "schedule",
) -> Report:
    """Jax-free structural verification of a serialized plan schedule (the
    dict :meth:`TransformPlan.schedule` emits, as stored in export
    bundles).  Checks stage indices, column versions, use-after-free and
    output presence; with ``input_schema`` the environment is CLOSED —
    a read of a column the schema does not provide is an error (the skew
    gate for bundle load)."""
    rep = Report()
    closed = input_schema is not None
    state = {
        "env": set(input_schema or ()),
        "version": {},
        "freed": {},
        "read": set(),
        "closed": closed,
    }

    def walk(d: dict, i) -> None:
        if "fused" in d:
            for m in d["members"]:
                walk(m, i)
            for c in d.get("internal", ()):
                if c in state["env"]:
                    state["env"].discard(c)
                    state["freed"][c] = i
        else:
            idx = d.get("stage", -1)
            if n_stages is not None and not 0 <= int(idx) < n_stages:
                rep.add(
                    MISSING_INPUT,
                    "error",
                    f"{where}: node {i} references stage index {idx} but the "
                    f"bundle has {n_stages} stages",
                )
                return
            _sched_walk_member(d, state, rep, where, i)
        for c in d.get("dead_after", ()):
            if c in state["env"]:
                state["env"].discard(c)
                state["freed"][c] = i

    for i, d in enumerate(sched.get("nodes", [])):
        walk(d, i)

    for c in sched.get("outputs") or ():
        if c not in state["env"]:
            why = (
                f"freed by node {state['freed'][c]}'s dead_after"
                if c in state["freed"]
                else "never produced"
            )
            rep.add(
                MISSING_OUTPUT,
                "error",
                f"{where}: requested output column {c!r} absent from the "
                f"final environment ({why})",
            )
    return rep


def gate_enabled() -> bool:
    """The verifier gates in export/registry honour ``REPRO_ANALYZE_GATE``
    (default on) so a knowingly-skewed artifact can still be loaded for
    forensics."""
    from repro.obs import envknobs

    return envknobs.env_flag("REPRO_ANALYZE_GATE", True)
