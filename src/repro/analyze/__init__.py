"""Static analysis over the preprocessing stack.

Two passes plus the env-knob rule, one findings pipeline:

* :mod:`repro.analyze.plan_check` — abstract interpretation over
  :class:`~repro.core.plan.TransformPlan` schedules and the fusion IR:
  dtype/shape inference per column without executing a row, fusion
  legality, schema skew, dead columns, use-after-free of liveness-freed
  buffers.  Also the cheap structural gate inside export bundle
  save/load and ``registry.register``.
* :mod:`repro.analyze.lockcheck` — AST lock-discipline lint for the
  threaded tiers: lock-order inversions, blocking calls under a lock,
  unguarded mutation of elsewhere-guarded fields.
* :mod:`repro.analyze.knobcheck` — every ``REPRO_*`` env knob referenced
  in ``src/`` must be registered and README-documented.

Run all of it with ``python -m repro.analyze [--strict] [--json out]``.
"""
from .findings import (  # noqa: F401
    BAD_SUPPRESSION,
    Finding,
    PlanSchemaError,
    Report,
    parse_suppressions,
)
from .plan_check import (  # noqa: F401
    DEAD_COLUMN,
    EVAL_ERROR,
    FUSION_LEGALITY,
    MISSING_INPUT,
    MISSING_OUTPUT,
    SCHEMA_SKEW,
    USE_AFTER_FREE,
    VERSION_SKEW,
    check_schema,
    gate_enabled,
    plan_required_inputs,
    schema_of_batch,
    verify_plan,
    verify_schedule_structure,
)
from .lockcheck import (  # noqa: F401
    BLOCKING_CALL,
    ORDER_INVERSION,
    UNGUARDED_MUTATION,
)
from .knobcheck import KNOB_UNDOCUMENTED, KNOB_UNREGISTERED  # noqa: F401
from . import knobcheck, lockcheck, plan_check  # noqa: F401

__all__ = [
    "Finding",
    "Report",
    "PlanSchemaError",
    "parse_suppressions",
    "verify_plan",
    "verify_schedule_structure",
    "check_schema",
    "schema_of_batch",
    "plan_required_inputs",
    "gate_enabled",
    "plan_check",
    "lockcheck",
    "knobcheck",
]
