"""Findings pipeline shared by every analyzer pass.

One :class:`Finding` per detected problem, one :class:`Report` per run —
the plan verifier, the concurrency lint and the env-knob check all emit
into the same structures, so the CLI, the export/registry gates and the
tests consume a single format (``file:line severity rule: message`` text
or machine-readable JSON).

Inline suppressions: a source line (or the ``def`` line of the enclosing
function, for function-scoped rules) may carry

    # analyze: allow(<rule>[,<rule>...]) <reason>

A suppression REQUIRES a reason; an allow without one does not suppress
and instead raises an ``analyze-bad-suppression`` finding — silent
waivers are exactly the bug class this subsystem exists to kill.
Suppressed findings stay in the report (marked, with the reason) so the
JSON record shows every waived site.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

#: severity levels, in gate order: --strict fails on any active "error".
SEVERITIES = ("error", "warning")

_SUPPRESS_RE = re.compile(
    r"#\s*analyze:\s*allow\(([a-zA-Z0-9_,\s-]+)\)\s*(.*?)\s*$"
)

BAD_SUPPRESSION = "analyze-bad-suppression"


class PlanSchemaError(ValueError):
    """A fitted artifact failed the static plan/schema verifier gate —
    raised by export-bundle save/load and ``registry.register`` instead of
    accepting a schema-mismatched artifact that would only fail (or worse,
    silently corrupt) at first execute.  Carries the findings that tripped
    the gate."""

    def __init__(self, message: str, findings: Optional[List["Finding"]] = None):
        super().__init__(message)
        self.findings = list(findings or [])


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str  # "error" | "warning"
    message: str
    file: Optional[str] = None
    line: Optional[int] = None
    suppressed: bool = False
    suppress_reason: Optional[str] = None

    def format(self) -> str:
        loc = f"{self.file}:{self.line}: " if self.file else ""
        tail = (
            f"  [suppressed: {self.suppress_reason}]" if self.suppressed else ""
        )
        return f"{loc}{self.severity} {self.rule}: {self.message}{tail}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def parse_suppressions(text: str):
    """``{line -> {rule -> reason}}`` for every valid allow comment, plus a
    list of (line, raw rules) for allows missing their reason."""
    allowed: Dict[int, Dict[str, str]] = {}
    bad: List[tuple] = []
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m is None:
            continue
        rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
        reason = m.group(2).strip()
        if not reason:
            bad.append((i, rules))
            continue
        allowed.setdefault(i, {}).update({r: reason for r in rules})
    return allowed, bad


class Report:
    """An ordered collection of findings with the gate/format helpers."""

    def __init__(self, findings: Optional[List[Finding]] = None):
        self.findings: List[Finding] = list(findings or [])

    def add(
        self,
        rule: str,
        severity: str,
        message: str,
        file: Optional[str] = None,
        line: Optional[int] = None,
    ) -> Finding:
        f = Finding(rule, severity, message, file, line)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> "Report":
        self.findings.extend(other.findings)
        return self

    # -- suppression ----------------------------------------------------
    def apply_suppressions(self, path: str, text: str, def_lines=None) -> None:
        """Mark findings in ``path`` suppressed when an allow comment for
        their rule sits on the finding line or on the enclosing ``def``
        line (``def_lines`` maps finding line -> def line).  Allows with a
        missing reason become findings themselves."""
        allowed, bad = parse_suppressions(text)
        for line, rules in bad:
            self.add(
                BAD_SUPPRESSION,
                "error",
                f"allow({','.join(rules)}) without a reason — suppressions "
                f"must justify themselves",
                file=path,
                line=line,
            )
        def_lines = def_lines or {}
        for f in self.findings:
            if f.file != path or f.line is None or f.suppressed:
                continue
            for at in (f.line, def_lines.get(f.line)):
                reason = allowed.get(at, {}).get(f.rule) if at else None
                if reason is not None:
                    f.suppressed = True
                    f.suppress_reason = reason
                    break

    # -- views ----------------------------------------------------------
    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def errors(self) -> List[Finding]:
        return [f for f in self.active if f.severity == "error"]

    def warnings(self) -> List[Finding]:
        return [f for f in self.active if f.severity == "warning"]

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def ok(self) -> bool:
        return not self.errors()

    # -- output ---------------------------------------------------------
    def format_text(self) -> str:
        lines = [f.format() for f in self.findings]
        lines.append(
            f"{len(self.errors())} error(s), {len(self.warnings())} "
            f"warning(s), {len(self.findings) - len(self.active)} suppressed"
        )
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "findings": [f.to_json() for f in self.findings],
            "errors": len(self.errors()),
            "warnings": len(self.warnings()),
            "suppressed": len(self.findings) - len(self.active),
        }

    def dump_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2)
            fh.write("\n")

    def raise_if_errors(self, where: str) -> None:
        """The export/registry gate: typed error instead of silent accept."""
        errs = self.errors()
        if errs:
            detail = "; ".join(f.format() for f in errs[:8])
            raise PlanSchemaError(
                f"{where}: {len(errs)} schema/plan error(s): {detail}", errs
            )

    def __len__(self) -> int:
        return len(self.findings)

    def __repr__(self) -> str:
        return (
            f"Report(errors={len(self.errors())}, "
            f"warnings={len(self.warnings())}, total={len(self.findings)})"
        )
