"""Data substrate: synthetic data-lake generators and the sharded,
prefetching batch pipeline that feeds the fit engine and the trainers."""
from .pipeline import BatchPipeline, prefetch
from .synthetic import lm_token_batches, ltr_rows, movielens_rows

__all__ = ["BatchPipeline", "prefetch", "movielens_rows", "ltr_rows", "lm_token_batches"]
