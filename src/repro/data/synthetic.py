"""Deterministic synthetic data-lake generators.

Stand-ins for the paper's centralised data lake: MovieLens-shaped interaction
rows, Expedia-LTR-shaped search/filter rows (dates, prices, amenity lists,
nested sequences), and LM token streams for the architecture pool.  All
generators are seeded and cheap, so tests/benchmarks are reproducible.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import types as T

_GENRES = [
    "Action", "Adventure", "Animation", "Comedy", "Crime", "Documentary",
    "Drama", "Fantasy", "Horror", "Musical", "Mystery", "Romance", "SciFi",
    "Thriller", "War", "Western",
]
_AMENITIES = [
    "pool", "spa", "gym", "wifi", "parking", "bar", "restaurant", "beach",
    "pets", "aircon", "kitchen", "laundry", "shuttle", "breakfast",
]
_COUNTRIES = ["US", "GB", "FR", "DE", "JP", "BR", "IN", "AU", "CA", "MX"]


def movielens_rows(n: int, seed: int = 0, n_movies: int = 2000, n_users: int = 50000) -> T.Batch:
    """MovieLens-shaped rows matching the paper's Listing 1 schema."""
    rng = np.random.default_rng(seed)
    # zipf-ish movie popularity so frequencyDesc ordering is meaningful
    pop = rng.zipf(1.3, size=n) % n_movies + 1
    genres = []
    for _ in range(n):
        k = rng.integers(1, 6)
        genres.append("|".join(rng.choice(_GENRES, size=k, replace=False)))
    return {
        "UserID": jnp.asarray(rng.integers(1, n_users, n), jnp.int32),
        "MovieID": jnp.asarray(pop, jnp.int32),
        "Occupation": jnp.asarray(rng.integers(0, 21, n), jnp.int32),
        "Genres": jnp.asarray(T.encode_strings(genres, 64)),
        "Rating": jnp.asarray(rng.integers(1, 6, n), jnp.float32),
    }


def ltr_rows(n: int, list_size: int = 16, seed: int = 0) -> T.Batch:
    """Expedia-LTR-shaped rows: one query with ``list_size`` ranked items.

    Nested shapes: scalar query features, per-item (batch, list) features and
    per-item amenity strings (batch, list, bytes) — the "nested-sequence-
    native" case from paper §2.
    """
    rng = np.random.default_rng(seed)

    def dates(lo, hi):
        d = rng.integers(lo, hi, n)
        out = []
        for days in d:
            y, rem = divmod(int(days), 365)
            m, day = divmod(rem, 28)
            out.append(f"{2020 + y:04d}-{m % 12 + 1:02d}-{day + 1:02d}")
        return out

    amen = []
    for _ in range(n * list_size):
        k = rng.integers(1, 7)
        amen.append(",".join(rng.choice(_AMENITIES, size=k, replace=False)))
    amen = np.asarray(amen).reshape(n, list_size)

    price = rng.lognormal(4.5, 1.0, (n, list_size)).astype(np.float32)
    price[rng.random((n, list_size)) < 0.03] = np.nan  # nulls to impute

    rel = (rng.random((n, list_size)) < 0.15).astype(np.float32)  # clicks
    return {
        "search_date": jnp.asarray(T.encode_strings(dates(0, 365 * 5), 12)),
        "checkin_date": jnp.asarray(T.encode_strings(dates(365 * 5, 365 * 6), 12)),
        "destination": jnp.asarray(
            T.encode_strings(np.random.default_rng(seed + 1).choice(_COUNTRIES, n), 8)
        ),
        "user_id": jnp.asarray(rng.integers(1, 10_000_000, n), jnp.int64),
        "num_rooms": jnp.asarray(rng.integers(1, 4, n), jnp.int32),
        "item_price": jnp.asarray(price),
        "item_star_rating": jnp.asarray(rng.integers(1, 6, (n, list_size)), jnp.float32),
        "item_review_score": jnp.asarray(rng.uniform(1, 10, (n, list_size)), jnp.float32),
        "item_review_count": jnp.asarray(rng.zipf(1.5, (n, list_size)) % 5000, jnp.float32),
        "item_amenities": jnp.asarray(T.encode_strings(amen, 96)),
        "item_id": jnp.asarray(rng.integers(1, 2_000_000, (n, list_size)), jnp.int64),
        "label_click": jnp.asarray(rel),
    }


def lm_token_batches(
    batch: int, seq: int, vocab: int, steps: int, seed: int = 0
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Synthetic token stream with enough structure (markov-ish bigrams) for a
    ~100M-param LM's loss to visibly fall within a few hundred steps."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition: each token has 8 likely successors
    succ = rng.integers(0, vocab, size=(vocab, 8))
    for _ in range(steps):
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(seq):
            explore = rng.random(batch) < 0.1
            choice = succ[toks[:, t], rng.integers(0, 8, batch)]
            toks[:, t + 1] = np.where(explore, rng.integers(0, vocab, batch), choice)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:].astype(np.int32)),
        }
