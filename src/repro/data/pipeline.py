"""Sharded, prefetching batch pipeline.

The "Spark executor" half of the paper's world: streams columnar batches,
shards them over the mesh's data axes (device_put with a NamedSharding) and
overlaps host-side generation with device compute via a background prefetch
thread — the standard input-pipeline overlap trick for keeping TPUs fed.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional

import jax

from repro.core import types as T


def prefetch(it: Iterable[T.Batch], depth: int = 2) -> Iterator[T.Batch]:
    """Run the producer in a daemon thread, ``depth`` batches ahead."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    _END = object()
    err: list = []

    def worker():
        try:
            for item in it:
                q.put(item)
        except BaseException as e:  # propagate into consumer
            err.append(e)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            if err:
                raise err[0]
            return
        yield item


class BatchPipeline:
    """Re-iterable batch source with optional mesh sharding + prefetch.

    ``factory()`` must return a fresh iterator each call (multi-pass fitting
    re-scans, exactly like Spark re-scanning a DataFrame).
    """

    def __init__(
        self,
        factory: Callable[[], Iterable[T.Batch]],
        engine=None,
        prefetch_depth: int = 2,
    ):
        self.factory = factory
        self.engine = engine
        self.prefetch_depth = prefetch_depth

    def __call__(self) -> Iterator[T.Batch]:
        it = iter(self.factory())
        if self.engine is not None and self.engine.mesh is not None:
            it = (self.engine.shard_batch(b) for b in it)
        if self.prefetch_depth > 0:
            it = prefetch(it, self.prefetch_depth)
        return it

    def __iter__(self) -> Iterator[T.Batch]:
        return self()
