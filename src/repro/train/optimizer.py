"""AdamW with warmup-cosine schedule, global-norm clipping and ZeRO-style
state sharding (optimizer moments inherit the parameter PartitionSpecs, which
already combine TP over "model" and FSDP over "data" — so m/v/params are
fully sharded across the mesh with no replication, the ZeRO-3 memory shape).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_pspecs(param_specs) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P

    return {
        "m": jax.tree.map(lambda s: s, param_specs),
        "v": jax.tree.map(lambda s: s, param_specs),
        "step": P(),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, params, grads, opt_state
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_vec = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_vec).astype(p.dtype), m, v

    flat_p = params if isinstance(params, dict) else params
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_p[k], new_m[k], new_v[k] = upd(
            params[k], grads[k], opt_state["m"][k], opt_state["v"][k]
        )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
