"""Train step assembly: value_and_grad + microbatch accumulation scan +
AdamW, all expressed so pjit can shard it (params/optimizer by their
PartitionSpecs, batch over the data axes).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_pspecs


def train_state_init(model, seed: int = 0) -> Dict[str, Any]:
    params = model.init(seed)
    return {"params": params, "opt": adamw_init(params)}


def train_state_abstract(model) -> Dict[str, Any]:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    from repro.models import common as C

    params = C.abstract_params(model.defs())
    zeros = {k: jax.ShapeDtypeStruct(v.shape, jnp.float32) for k, v in params.items()}
    return {
        "params": params,
        "opt": {"m": zeros, "v": dict(zeros), "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }


def train_state_pspecs(model, rules=None) -> Dict[str, Any]:
    ps = model.pspecs(rules)
    return {"params": ps, "opt": opt_pspecs(ps)}


def make_train_step(
    model,
    ocfg: AdamWConfig,
    accum: int = 1,
    cast_params_once: bool = True,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    ``accum > 1`` splits the global batch into ``accum`` microbatches and
    accumulates gradients with a lax.scan — peak activation memory drops by
    ~accum at the cost of accum sequential passes (the standard memory /
    throughput knob at pod scale).

    ``cast_params_once`` casts fp32 master weights (>=2D) to the model compute
    dtype BEFORE the layer scan, so the per-layer FSDP all-gathers move bf16
    instead of fp32 — halving the dominant training collective (§Perf change
    #2; set False for the paper-faithful baseline numbers).
    """
    cdt = model.cfg.compute_dtype

    def loss_fn(params, batch):
        if cast_params_once:
            params = {
                k: (v.astype(cdt) if (v.ndim >= 2 and v.dtype == jnp.float32) else v)
                for k, v in params.items()
            }
        return model.loss(params, batch)

    def step(state, batch):
        params = state["params"]
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:

            def micro(carry, mb):
                acc, loss_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            micros = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]), batch
            )
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, jnp.zeros((), jnp.float32)), micros)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        new_p, new_opt, metrics = adamw_update(ocfg, params, grads, state["opt"])
        metrics = dict(metrics)
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_opt}, metrics

    return step
