"""int8 error-feedback gradient compression for the data-parallel all-reduce.

At pod scale the DP gradient all-reduce is the largest single collective; 4x
compression (f32 -> int8) with error feedback [1-bit Adam / EF-SGD lineage]
cuts it 4x at negligible quality cost.  Expressed with shard_map so the
quantise -> psum -> dequantise happens exactly at the collective boundary:

    g_local + e  ->  q = round(g/scale) int8  ->  psum(int32)  ->  g_hat
    e' = (g_local + e) - g_hat_local_contribution

Applies to pure-DP axes; with TP>1 the model-parallel reductions stay f32
(they carry activations, not gradients).  Exercised by tests and the
quickstart-scale examples; the train CLI enables it with --compress-grads.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantise(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q.astype(jnp.int8)


def compressed_psum_grads(grads: Any, errors: Any, axis_name: str) -> Tuple[Any, Any]:
    """Inside shard_map: all-reduce-mean grads in int8 with error feedback.

    Returns (mean_grads_f32, new_errors).
    """
    n = jax.lax.psum(jnp.ones(()), axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = _quantise(g, scale)
        deq = q.astype(jnp.float32) * scale
        new_e = g - deq  # residual stays local (error feedback)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale / n, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(errors)[0]
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    gs = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    es = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return gs, es


def make_compressed_dp_step(loss_fn, update_fn, mesh, axis_name: str = "data"):
    """Build a shard_map train step with int8-EF gradient all-reduce.

    loss_fn(params, batch) -> scalar; update_fn(params, grads, opt) ->
    (params, opt, metrics).  Params/opt replicated across the DP axis; batch
    sharded on its leading dim.
    """
    from jax.experimental.shard_map import shard_map

    def local_step(state, batch):
        params, opt, errors = state["params"], state["opt"], state["errors"]
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, errors = compressed_psum_grads(grads, errors, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        params, opt, metrics = update_fn(params, grads, opt)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return {"params": params, "opt": opt, "errors": errors}, metrics

    def state_spec(state):
        return {
            "params": jax.tree.map(lambda _: P(), state["params"]),
            "opt": jax.tree.map(lambda _: P(), state["opt"]),
            "errors": jax.tree.map(lambda _: P(), state["errors"]),
        }

    def step(state, batch):
        sspec = state_spec(state)
        bspec = jax.tree.map(lambda _: P(axis_name), batch)
        mspec = {}  # inferred: all replicated scalars
        fn = shard_map(
            local_step,
            mesh=mesh,
            in_specs=(sspec, bspec),
            out_specs=(sspec, P()),
            check_rep=False,
        )
        return fn(state, batch)

    return jax.jit(step)


def init_errors(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
