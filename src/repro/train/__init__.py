"""Training substrate: optimizer, schedules, train step, grad compression."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_pspecs
from .step import make_train_step, train_state_abstract, train_state_init, train_state_pspecs

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_pspecs",
    "make_train_step",
    "train_state_abstract",
    "train_state_init",
    "train_state_pspecs",
]
