"""Typed metrics registry with one top-level snapshot.

Two kinds of state feed ``obs.snapshot()``:

* **Instruments** — :class:`Counter` / :class:`Gauge` / :class:`Histogram`
  created through the registry.  Histograms are backed by the same
  mergeable DDSketch layout as the fitting engine and the gateway's
  latency telemetry (``repro.core.sketches``), so their quantile error
  bound and merge algebra are the ones already asserted by
  tests/test_sketches.py.
* **Sources** — existing snapshot callables (``gateway.snapshot``,
  ``executor.ft_snapshot``, ``runner.stats``, the cost model inside the
  gateway's snapshot) *re-registered* here instead of being re-invented:
  each registration holds only a weak reference to its owner, so a closed
  gateway or collected runner silently drops out of the snapshot rather
  than keeping the object alive or raising at poll time.  Registering the
  same name again replaces the previous owner (sequential gateways in a
  test suite: last one wins).

Exposition: ``render_text`` flattens the snapshot into sorted
``dotted.path value`` lines (one metric per line, machine-parseable);
``render_json`` is the same tree as JSON.
"""
from __future__ import annotations

import json
import threading
import weakref
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from repro.core import sketches


class Counter:
    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, by: float = 1.0) -> None:
        with self._lock:
            self._v += by

    @property
    def value(self) -> float:
        with self._lock:
            return self._v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins value, or a live callable (``bind``)."""

    __slots__ = ("_lock", "_v", "_fn")

    def __init__(self):
        self._lock = threading.Lock()
        self._v: float = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        with self._lock:
            self._v = value

    def bind(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    def snapshot(self):
        with self._lock:
            fn = self._fn
            if fn is None:
                return self._v
        try:
            return fn()
        except Exception as e:  # a dead provider must not poison the poll
            return f"error: {type(e).__name__}"


class Histogram:
    """DDSketch-backed distribution; records floats, exposes quantiles."""

    __slots__ = ("_lock", "_hist", "_count")

    def __init__(self):
        self._lock = threading.Lock()
        self._hist = sketches.dd_init_np()
        self._count = 0

    def record(self, value: float) -> None:
        if not (value >= 0.0):  # NaN / negative: sketch domain is positive
            return
        with self._lock:
            sketches.dd_update_np(self._hist, value)
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def quantiles(self, qs: Iterable[float] = (0.5, 0.99)) -> Dict[float, float]:
        qs = list(qs)
        with self._lock:
            vals = sketches.dd_quantile_np(self._hist, qs)
        return {q: float(v) for q, v in zip(qs, vals)}

    def snapshot(self):
        quants = self.quantiles()
        return {
            "count": self.count,
            **{f"p{round(q * 100):g}": round(v, 9) for q, v in quants.items()},
        }


class MetricsRegistry:
    """Instruments plus weakly-held snapshot sources, one coherent poll."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}
        # name -> (weakref-to-owner or None, callable)
        self._sources: Dict[str, Tuple[Optional[weakref.ref], Callable[[], Any]]] = {}

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is {type(inst).__name__}, not {cls.__name__}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- sources ------------------------------------------------------------

    def register_source(self, name: str, fn: Callable[[], Any], obj=None) -> None:
        """Fold ``fn()``'s dict into every snapshot under ``sources[name]``.
        ``obj`` (default: ``fn.__self__`` for bound methods) is held weakly —
        when it is collected the source unregisters itself."""
        if obj is None:
            obj = getattr(fn, "__self__", None)
        ref = None
        if obj is not None:
            if getattr(fn, "__self__", None) is obj:
                fn = weakref.WeakMethod(fn)  # don't let the callable pin obj
                ref = fn
            else:
                ref = weakref.ref(obj)
        with self._lock:
            self._sources[name] = (ref, fn)

    def unregister_source(self, name: str, obj=None) -> None:
        """Remove a source; with ``obj``, only when it still owns the name
        (a later registration under the same name survives)."""
        with self._lock:
            cur = self._sources.get(name)
            if cur is None:
                return
            if obj is not None and cur[0] is not None:
                owner = cur[0]()
                if isinstance(cur[0], weakref.WeakMethod) and owner is not None:
                    owner = owner.__self__  # WeakMethod derefs to the method
                if owner is not obj:
                    return
            self._sources.pop(name, None)

    def snapshot(self) -> dict:
        with self._lock:
            instruments = dict(self._instruments)
            sources = dict(self._sources)
        out: Dict[str, Any] = {
            "metrics": {k: v.snapshot() for k, v in sorted(instruments.items())},
            "sources": {},
        }
        dead = []
        for name, (ref, fn) in sorted(sources.items()):
            call = fn
            if isinstance(fn, weakref.WeakMethod):
                call = fn()
                if call is None:
                    dead.append(name)
                    continue
            elif ref is not None and ref() is None:
                dead.append(name)
                continue
            try:
                out["sources"][name] = call()
            except Exception as e:  # a failing source must not fail the poll
                out["sources"][name] = {"error": f"{type(e).__name__}: {e}"}
        if dead:
            with self._lock:
                for name in dead:
                    if self._sources.get(name, (None, None))[1] is sources[name][1]:
                        self._sources.pop(name, None)
        return out


def flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """``{"a": {"b": 1}} -> {"a.b": 1}`` (lists index numerically)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree, key=str):
            out.update(flatten(tree[k], f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = tree
    return out


def render_text(snap: Optional[dict] = None) -> str:
    """One ``dotted.path value`` line per leaf, sorted."""
    if snap is None:
        snap = get_registry().snapshot()
    lines = [f"{k} {v}" for k, v in sorted(flatten(snap).items())]
    return "\n".join(lines)


def render_json(snap: Optional[dict] = None) -> str:
    if snap is None:
        snap = get_registry().snapshot()
    return json.dumps(snap, default=str, sort_keys=True)


_default: Optional[MetricsRegistry] = None
_dlock = threading.Lock()


def get_registry() -> MetricsRegistry:
    global _default
    if _default is None:
        with _dlock:
            if _default is None:
                _default = MetricsRegistry()
    return _default


def set_registry(reg: Optional[MetricsRegistry]) -> None:
    global _default
    with _dlock:
        _default = reg
