"""Lock-cheap ring-buffer span recorder for distributed request tracing.

A *span* is one timed operation: ``(trace_id, span_id, parent_id, name,
component, t_start, t_end, process, attrs)``.  Timestamps come from one
monotonic clock per process (injectable — the fake-clock tests drive it);
cross-process stitching re-bases worker timestamps onto the coordinator's
clock via the offset estimated at attach time (see
``serve.gateway.multihost``), so a request renders as ONE tree spanning N
processes.

Cost model: finished spans land in a fixed-capacity ring (one short lock
per append, ``REPRO_OBS_RING`` spans, oldest overwritten) — recording never
allocates unboundedly and never blocks on I/O.  ``REPRO_OBS_TRACE=0``
makes every span a shared no-op object; ``REPRO_OBS_SAMPLE`` head-samples:
the keep/drop decision is made ONCE per trace at root creation and
inherited by every descendant (children of an unsampled root cost a single
attribute check), so a trace is always complete or absent, never partial.

Parenting: a ``with recorder.span(...)`` block pushes the span on a
thread-local stack; spans started inside inherit it implicitly.  Crossing
threads or processes, pass ``parent=`` explicitly or ``ctx=(trace_id,
span_id)`` — the tuple that rides multi-host shard frames.
"""
from __future__ import annotations

import itertools
import os
import random
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import envknobs

_UNSET = object()


class Span:
    """A started (possibly finished) span.  Usable as a context manager:
    entering pushes it on the recorder's thread-local parent stack."""

    __slots__ = (
        "trace_id", "span_id", "parent_id", "name", "component",
        "t_start", "t_end", "process", "attrs", "_rec",
    )
    sampled = True

    def __init__(self, rec, trace_id, span_id, parent_id, name, component,
                 t_start, process, attrs):
        self._rec = rec
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.component = component
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.process = process
        self.attrs: Dict[str, Any] = attrs if attrs is not None else {}

    @property
    def duration(self) -> float:
        end = self.t_end if self.t_end is not None else self._rec.clock()
        return end - self.t_start

    def set(self, key: str, value) -> None:
        self.attrs[key] = value

    def end(self, t: Optional[float] = None, error: Optional[str] = None) -> None:
        if self.t_end is not None:
            return  # already finished (with-block plus manual end)
        if error is not None:
            self.attrs["error"] = error
        self.t_end = t if t is not None else self._rec.clock()
        if self.t_end < self.t_start:
            self.t_end = self.t_start
        self._rec._record(self)

    def as_tuple(self) -> tuple:
        return (
            self.trace_id, self.span_id, self.parent_id, self.name,
            self.component, self.t_start,
            self.t_end if self.t_end is not None else self.t_start,
            self.process, dict(self.attrs),
        )

    def __enter__(self) -> "Span":
        self._rec._push(self)
        return self

    def __exit__(self, etype, exc, tb) -> None:
        self._rec._pop(self)
        self.end(error=f"{etype.__name__}: {exc}" if etype is not None else None)

    def __repr__(self) -> str:
        return (
            f"Span({self.name!r}, trace={self.trace_id:x}, id={self.span_id}, "
            f"parent={self.parent_id}, proc={self.process})"
        )


class _NullSpan:
    """Shared no-op span: what every recording call returns when tracing is
    off or the trace was not sampled.  All mutators are no-ops."""

    __slots__ = ()
    sampled = False
    trace_id = 0
    span_id = 0
    parent_id = 0
    name = component = ""
    t_start = t_end = 0.0
    process = 0
    duration = 0.0

    @property
    def attrs(self) -> dict:
        return {}  # fresh dict: stray writes cannot leak between call sites

    def set(self, key, value) -> None:
        pass

    def end(self, t=None, error=None) -> None:
        pass

    def as_tuple(self) -> tuple:
        return (0, 0, 0, "", "", 0.0, 0.0, 0, {})

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL = _NullSpan()


class TraceRecorder:
    """Per-process span recorder (ring buffer + id allocation + sampling).

    Args (each falls back to its env knob):
      capacity: ring size in spans (``REPRO_OBS_RING``, 4096).
      clock: monotonic time source (injectable for fake-clock tests).
      enabled: master gate (``REPRO_OBS_TRACE``, on).
      sample: head-sampling probability (``REPRO_OBS_SAMPLE``, 1.0).
      process: process label stamped on every span (multi-host workers set
        their mesh process id; 0 = coordinator/single process).
    """

    def __init__(
        self,
        capacity: Optional[int] = None,
        clock=time.perf_counter,
        enabled: Optional[bool] = None,
        sample: Optional[float] = None,
        process: int = 0,
    ):
        self.capacity = int(
            capacity if capacity is not None else envknobs.env_int("REPRO_OBS_RING", 4096)
        )
        if self.capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.clock = clock
        self.enabled = (
            enabled if enabled is not None else envknobs.env_flag("REPRO_OBS_TRACE", True)
        )
        self.sample = (
            sample if sample is not None else envknobs.env_float("REPRO_OBS_SAMPLE", 1.0)
        )
        self.process = int(process)
        self._ring: List[Optional[Span]] = [None] * self.capacity
        self._n = 0  # total spans ever recorded
        self._rlock = threading.Lock()
        # span ids are salted by process so coordinator and worker spans
        # stitched into one trace can never collide on span_id (which would
        # corrupt parent links in the rendered tree)
        self._ids = itertools.count((int(process) << 40) + 1)
        self._rng = random.Random((os.getpid() << 16) ^ int(time.time() * 1e3))
        self._tls = threading.local()

    # -- id/sampling --------------------------------------------------------

    def new_trace_id(self) -> int:
        return self._rng.getrandbits(63) or 1

    def _sampled(self) -> bool:
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return self._rng.random() < self.sample

    # -- span creation ------------------------------------------------------

    def span(
        self,
        name: str,
        component: str = "app",
        parent=_UNSET,
        ctx: Optional[Tuple[int, int]] = None,
        attrs: Optional[dict] = None,
        t_start: Optional[float] = None,
    ):
        """Start a span.  Parent resolution order: explicit ``ctx`` (a
        ``(trace_id, span_id)`` tuple off the wire — always sampled, the
        sender only propagates sampled traces), explicit ``parent`` span,
        the thread-local current span, else a NEW trace (head-sampling
        decision applies).  Returns :data:`NULL` when recording is off or
        the trace is unsampled."""
        if not self.enabled:
            return NULL
        if ctx is not None:
            trace_id, parent_id = int(ctx[0]), int(ctx[1])
        else:
            if parent is _UNSET:
                # inlined current(): this is the hot path, one attribute
                # lookup instead of two method calls
                st = getattr(self._tls, "stack", None)
                parent = st[-1] if st else None
            if parent is None:
                if self.sample < 1.0 and not self._sampled():
                    return NULL
                trace_id, parent_id = self._rng.getrandbits(63) or 1, 0
            elif not parent.sampled:
                return NULL
            else:
                trace_id, parent_id = parent.trace_id, parent.span_id
        return Span(
            self, trace_id, next(self._ids), parent_id, name, component,
            t_start if t_start is not None else self.clock(),
            self.process, attrs,
        )

    def root_span(self, name: str, component: str = "app", attrs=None,
                  t_start: Optional[float] = None):
        """Start a new trace unconditionally of any ambient span."""
        return self.span(name, component, parent=None, attrs=attrs, t_start=t_start)

    def event(self, name: str, component: str = "app", attrs=None, parent=_UNSET):
        """Instant (zero-duration) event, recorded immediately."""
        sp = self.span(name, component, parent=parent, attrs=attrs)
        sp.end(t=sp.t_start)
        return sp

    # -- thread-local parent stack ------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        elif span in st:  # exited out of order: drop it wherever it sits
            st.remove(span)

    def current(self) -> Optional[Span]:
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    # -- recording ----------------------------------------------------------

    def _record(self, span: Span) -> None:
        lock = self._rlock
        lock.acquire()
        try:
            self._ring[self._n % self.capacity] = span
            self._n += 1
        finally:
            lock.release()
        cap = getattr(self._tls, "capture", None)
        if cap is not None:
            cap.append(span)

    def capture(self):
        """Context manager collecting every span FINISHED by this thread
        during the block (on top of normal ring recording) — how a shard
        worker gathers the spans of one batch to piggyback on its reply."""
        return _Capture(self)

    def ingest(self, tuples: Iterable[tuple], offset: float = 0.0) -> List[Span]:
        """Adopt foreign (worker-side) finished spans, shifting their
        timestamps by ``offset`` onto this process's clock.  Durations are
        offset-invariant, so they stay non-negative."""
        out = []
        for t in tuples:
            trace_id, span_id, parent_id, name, component, t0, t1, proc, attrs = t
            sp = Span(self, trace_id, span_id, parent_id, name, component,
                      t0 + offset, proc, dict(attrs))
            sp.t_end = t1 + offset
            with self._rlock:
                self._ring[self._n % self.capacity] = sp
                self._n += 1
            out.append(sp)
        return out

    # -- introspection ------------------------------------------------------

    @property
    def recorded(self) -> int:
        """Total spans ever recorded (including ones the ring dropped)."""
        return self._n

    def spans(self) -> List[Span]:
        """Finished spans still in the ring, oldest first."""
        with self._rlock:
            n, cap = self._n, self.capacity
            if n <= cap:
                return [s for s in self._ring[:n]]
            i = n % cap
            return [s for s in self._ring[i:] + self._ring[:i]]

    def trace(self, trace_id: int) -> List[Span]:
        return [s for s in self.spans() if s.trace_id == trace_id]

    def reset(self) -> None:
        with self._rlock:
            self._ring = [None] * self.capacity
            self._n = 0


class _Capture:
    __slots__ = ("_rec", "_prev", "spans")

    def __init__(self, rec: TraceRecorder):
        self._rec = rec
        self.spans: List[Span] = []

    def __enter__(self) -> "_Capture":
        self._prev = getattr(self._rec._tls, "capture", None)
        self._rec._tls.capture = self.spans
        return self

    def __exit__(self, *exc) -> None:
        self._rec._tls.capture = self._prev

    def __iter__(self):
        return iter(self.spans)


# -- module-level default recorder ------------------------------------------

_default: Optional[TraceRecorder] = None
_dlock = threading.Lock()


def get_recorder() -> TraceRecorder:
    global _default
    if _default is None:
        with _dlock:
            if _default is None:
                _default = TraceRecorder()
    return _default


def set_recorder(rec: Optional[TraceRecorder]) -> None:
    global _default
    with _dlock:
        _default = rec


def span(name: str, component: str = "app", **kw):
    return get_recorder().span(name, component, **kw)


def event(name: str, component: str = "app", **kw):
    return get_recorder().event(name, component, **kw)


def current() -> Optional[Span]:
    return get_recorder().current()
