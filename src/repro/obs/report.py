"""Terminal trace/metrics viewer: ``python -m repro.obs.report <file>``.

Accepts any JSON the obs layer writes — a Chrome trace-event export, a
flight-recorder dump, or a bare metrics snapshot — and renders span trees
(indented, with millisecond durations and per-process labels) plus a
flattened metrics listing.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List

from . import export as obs_export
from . import metrics as obs_metrics


def format_trace_tree(tuples: List[tuple]) -> str:
    """Indented per-trace span trees, ordered by start time."""
    by_trace: Dict[int, List[tuple]] = defaultdict(list)
    for t in tuples:
        by_trace[t[0]].append(t)
    lines: List[str] = []
    for trace_id in sorted(by_trace):
        spans = sorted(by_trace[trace_id], key=lambda t: (t[5], t[1]))
        lines.append(f"trace {trace_id:x} ({len(spans)} spans)")
        ids = {t[1] for t in spans}
        children: Dict[int, List[tuple]] = defaultdict(list)
        roots: List[tuple] = []
        for t in spans:
            if t[2] in ids:
                children[t[2]].append(t)
            else:
                roots.append(t)  # parent 0, or parent outside this dump

        def emit(t: tuple, depth: int) -> None:
            _, span_id, _, name, component, t0, t1, proc, attrs = t
            dur = (t1 - t0) * 1e3
            extra = "".join(
                f" {k}={v}" for k, v in sorted(attrs.items()) if k != "error"
            )
            err = f"  ERROR: {attrs['error']}" if "error" in attrs else ""
            lines.append(
                f"  {'  ' * depth}{name:<20} {dur:9.3f}ms  "
                f"[{component}/p{proc}]{extra}{err}"
            )
            for c in children.get(span_id, []):
                emit(c, depth + 1)

        for r in roots:
            emit(r, 0)
    return "\n".join(lines)


def format_metrics(snap: dict) -> str:
    return obs_metrics.render_text(snap)


def render_file(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    parts: List[str] = []
    if isinstance(doc, dict) and "traceEvents" in doc:
        parts.append(format_trace_tree(obs_export.from_chrome(doc)))
    elif isinstance(doc, dict) and doc.get("kind") == "flight":
        parts.append(
            f"flight dump: reason={doc.get('reason')} "
            f"component={doc.get('component')} process={doc.get('process')} "
            f"t={doc.get('t'):.6f}"
        )
        if doc.get("attrs"):
            parts.append(f"attrs: {json.dumps(doc['attrs'], default=str)}")
        spans = [tuple(s) if not isinstance(s, tuple) else s for s in doc.get("spans", [])]
        spans = [(s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7], dict(s[8])) for s in spans]
        parts.append(format_trace_tree(spans))
        if doc.get("metrics"):
            parts.append("-- metrics --")
            parts.append(format_metrics(doc["metrics"]))
    else:
        parts.append(format_metrics(doc))
    return "\n".join(p for p in parts if p)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render an obs JSON artifact (Chrome trace export, "
        "flight dump, or metrics snapshot) as text.",
    )
    ap.add_argument("path", help="JSON file to render")
    args = ap.parse_args(argv)
    print(render_file(args.path))
    return 0


if __name__ == "__main__":
    sys.exit(main())
