"""Central registry and parsers for every ``REPRO_*`` environment knob.

Every knob the codebase reads is declared here — name, type, default and a
one-line doc — and a static-check test (``tests/test_obs.py``) fails the
suite when a ``REPRO_*`` reference lands in ``src/`` without a registration
here and a mention in README.md.  The parsers are the single source of
truthiness: ``env_flag`` accepts the full falsy set (``0/false/no/off`` and
the empty string — the PR-7 fix that stopped ``REPRO_FT_HEDGE=off`` from
reading as *on*), and numeric parsers fall back to the caller's default on
garbage instead of raising mid-request.

Defaults recorded in :data:`KNOBS` are documentation; call sites keep
passing their own default so a knob whose default is *derived* (e.g.
``REPRO_FT_MAX_RESHARDS`` = workers - 1) stays honest.  ``default=None``
in a registration means "derived / see doc".

This module is import-light on purpose (stdlib only): it is imported by
``repro.core``/``repro.serve`` modules on both sides of the multi-host
socket, before jax is touched.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional


class Knob(NamedTuple):
    name: str
    kind: str  # "flag" | "tristate" | "float" | "int" | "str"
    default: object  # documentation only; call sites pass their own
    doc: str


KNOBS: Dict[str, Knob] = {}


def register(name: str, kind: str, default, doc: str) -> None:
    KNOBS[name] = Knob(name, kind, default, doc)


_FALSY = ("0", "false", "no", "off", "")


def env_flag(name: str, default: bool) -> bool:
    """Boolean knob: unset -> default; set -> anything outside the falsy
    set (``0/false/no/off`` and empty, case-insensitive) is true."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in _FALSY


def env_tristate(name: str) -> Optional[bool]:
    """Three-state knob: unset -> None (caller decides, e.g. "TPU only"),
    set -> truthiness as :func:`env_flag`."""
    raw = os.environ.get(name)
    if raw is None:
        return None
    return raw.strip().lower() not in _FALSY


def env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except (TypeError, ValueError):
        return default


def env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except (TypeError, ValueError):
        return default


def env_str(name: str, default: str = "") -> str:
    return os.environ.get(name) or default


def snapshot() -> Dict[str, dict]:
    """Registered knobs with their current environment values (None when
    unset) — surfaced by ``obs.snapshot()`` so a trace dump records the
    configuration it ran under."""
    return {
        k.name: {
            "kind": k.kind,
            "default": k.default,
            "value": os.environ.get(k.name),
            "doc": k.doc,
        }
        for k in sorted(KNOBS.values())
    }


# -- registry ---------------------------------------------------------------
# Execution / planning
register("REPRO_FUSE_CHAINS", "flag", True,
         "Collapse elementwise/row-local stage runs into FusedChain nodes.")
register("REPRO_FUSED_KERNEL", "tristate", None,
         "Force (1) / forbid (0) the fused-transform Pallas megakernel; unset = TPU only.")
register("REPRO_HASH_KERNEL", "tristate", None,
         "Force (1) / forbid (0) the bloom_hash Pallas kernel; unset = TPU only.")
register("REPRO_HASH_CHUNK", "int", None,
         "Override the byte-chunk width of the long-string bloom_hash grid.")
register("REPRO_TUNE_BUDGET", "int", 8,
         "Max candidate block configs timed per autotune sweep.")
register("REPRO_TUNE_CACHE", "str", "~/.cache/repro/tuned_configs.json",
         "Path of the persisted tuned-config store.")
register("REPRO_RUNNER_AUTOPACK", "flag", False,
         "Adaptive superbatch pack sizing in PlanRunner.")
register("REPRO_RUNNER_PACK_TARGET_MS", "float", 50.0,
         "Autopack's target superbatch execute time.")
# Serving
register("REPRO_SERVE_DONATE", "flag", True,
         "Donate staged input buffers to fused executables.")
register("REPRO_GW_COST_MODEL", "flag", True,
         "Build the gateway's finish-time ExecuteCostModel.")
register("REPRO_GW_COST_Q", "float", 0.9,
         "Quantile of observed execute time the cost model estimates with.")
register("REPRO_GW_COST_SAFETY", "float", 1.0,
         "Safety multiplier on the cost-model quantile.")
register("REPRO_GW_COST_PRIOR_MS", "float", 0.0,
         "Estimate used before any data exists (0 = never shed on ignorance).")
register("REPRO_GW_COST_MIN_SAMPLES", "int", 1,
         "Observations a bucket needs before its own histogram is trusted.")
register("REPRO_GW_COST_FIT", "flag", True,
         "Linear rows->time fallback for unseen buckets.")
# Multi-host transport
register("REPRO_MH_TRANSPORT", "str", "pickle",
         "Shard data-plane wire format: pickle (inline, default) or shm "
         "(zero-copy shared-memory rings, negotiated per worker).")
register("REPRO_MH_SHM_SLOTS", "int", 4,
         "Slots per direction in each worker pair's shared-memory ring.")
register("REPRO_MH_SHM_SLOT_MB", "float", 4.0,
         "Payload capacity (MiB) of one shared-memory slot; larger frames "
         "fall back to inline pickle per frame.")
# Fault tolerance
register("REPRO_FT_HEARTBEAT_S", "float", 5.0,
         "Liveness window: suspect after one silent window, dead after two.")
register("REPRO_FT_HEDGE", "flag", True,
         "Race flagged stragglers' blocks with a local re-execute.")
register("REPRO_FT_MAX_RESHARDS", "int", None,
         "Worker deaths absorbed before batches fail loudly (default: workers - 1).")
register("REPRO_FT_DEBUG", "flag", False,
         "Debug-level obs.log output for the ft component (fault-path tracing).")
# Observability
register("REPRO_OBS_TRACE", "flag", True,
         "Master gate for the span recorder; off = every span is a no-op.")
register("REPRO_OBS_SAMPLE", "float", 1.0,
         "Head-sampling probability, decided once per trace at root creation.")
register("REPRO_OBS_RING", "int", 4096,
         "Capacity (spans) of the in-memory trace ring buffer.")
register("REPRO_OBS_FLIGHT", "flag", True,
         "Flight recorder: freeze the last-N ring spans on fault triggers.")
register("REPRO_OBS_FLIGHT_N", "int", 256,
         "Spans captured per flight-recorder dump.")
register("REPRO_OBS_FLIGHT_DIR", "str", "",
         "Directory for flight-dump JSON files (empty = in-memory only).")
register("REPRO_OBS_SHED_SPIKE", "int", 32,
         "Gateway sheds within one second that trigger a flight dump.")
register("REPRO_OBS_LOG", "str", "info",
         "Minimum obs.log level (debug/info/warn/error).")
# Static analysis
register("REPRO_ANALYZE_GATE", "flag", True,
         "Plan/schema verifier gate inside export bundle load and "
         "registry.register; off = accept artifacts unchecked.")
