"""Unified observability layer: tracing, metrics, flight recorder, export.

Import-light by design (stdlib + numpy via ``repro.core.sketches``; no jax),
so both sides of the multi-host socket — and test harness subprocesses —
can load it before any backend initialises.

* :mod:`.trace` — ring-buffer span recorder (``REPRO_OBS_TRACE`` /
  ``REPRO_OBS_SAMPLE`` / ``REPRO_OBS_RING``).
* :mod:`.metrics` — typed counters/gauges/DDSketch histograms plus weakly
  registered snapshot sources; ``obs.snapshot()`` is the one poll.
* :mod:`.flight` — last-N span freeze on faults (``REPRO_OBS_FLIGHT*``).
* :mod:`.export` / :mod:`.report` — Chrome/Perfetto JSON and a terminal
  viewer (``python -m repro.obs.report``).
* :mod:`.log` — structured stderr lines (``REPRO_OBS_LOG``;
  ``REPRO_FT_DEBUG`` keeps gating the ft component's debug output).
* :mod:`.envknobs` — every ``REPRO_*`` knob: parsers + registry + docs.
"""
from . import envknobs, export, flight, log, metrics, trace
from .flight import FlightRecorder, get_flight, set_flight
from .metrics import MetricsRegistry, get_registry, render_json, render_text, set_registry
from .trace import NULL, Span, TraceRecorder, current, event, get_recorder, set_recorder, span


def snapshot() -> dict:
    """One top-level operational snapshot: registered instruments, every
    registered source's snapshot (gateway / ft / runner / cost model), the
    trace recorder's state and the env-knob configuration."""
    rec = get_recorder()
    out = get_registry().snapshot()
    out["trace"] = {
        "enabled": rec.enabled,
        "sample": rec.sample,
        "capacity": rec.capacity,
        "recorded": rec.recorded,
        "in_ring": len(rec.spans()),
        "process": rec.process,
    }
    out["flight"] = {"dumps": get_flight().dumps}
    out["env"] = {
        k: v["value"] for k, v in envknobs.snapshot().items() if v["value"] is not None
    }
    return out


__all__ = [
    "envknobs", "export", "flight", "log", "metrics", "trace",
    "FlightRecorder", "get_flight", "set_flight",
    "MetricsRegistry", "get_registry", "set_registry", "render_json", "render_text",
    "NULL", "Span", "TraceRecorder", "current", "event", "get_recorder",
    "set_recorder", "span", "snapshot",
]
