"""Structured stderr logging for the serving stack.

One line per record: monotonic timestamp, level, component, message, then
``key=value`` fields — written with a single ``print(..., flush=True)`` so
records from N subprocesses interleave per-line, never mid-line (the bare
``[repro.ft]`` prints this replaces could tear under concurrent workers).

Level policy: ``REPRO_OBS_LOG`` sets the global minimum (default ``info``).
A component may additionally be opted into debug via its own historical
flag — ``REPRO_FT_DEBUG`` keeps gating the ``ft`` component's debug output,
so existing workflows keep working — registered in
:data:`COMPONENT_DEBUG_FLAGS`.
"""
from __future__ import annotations

import sys
import time
from typing import Dict

from . import envknobs

LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}

# component -> env flag that force-enables its debug records
COMPONENT_DEBUG_FLAGS: Dict[str, str] = {"ft": "REPRO_FT_DEBUG"}

_clock = time.perf_counter


def enabled_for(level: str, component: str) -> bool:
    lvl = LEVELS.get(level, 20)
    floor = LEVELS.get(envknobs.env_str("REPRO_OBS_LOG", "info").lower(), 20)
    if lvl >= floor:
        return True
    flag = COMPONENT_DEBUG_FLAGS.get(component)
    return flag is not None and envknobs.env_flag(flag, False)


def log(level: str, component: str, msg: str, **fields) -> None:
    if not enabled_for(level, component):
        return
    extra = "".join(f" {k}={v}" for k, v in fields.items())
    print(
        f"[{_clock():.6f}] {level.upper():<5} {component}: {msg}{extra}",
        file=sys.stderr,
        flush=True,
    )


def debug(component: str, msg: str, **fields) -> None:
    log("debug", component, msg, **fields)


def info(component: str, msg: str, **fields) -> None:
    log("info", component, msg, **fields)


def warn(component: str, msg: str, **fields) -> None:
    log("warn", component, msg, **fields)


def error(component: str, msg: str, **fields) -> None:
    log("error", component, msg, **fields)
