"""Chrome trace-event / Perfetto export for recorded spans.

``to_chrome`` renders spans as complete-duration (``ph:"X"``) trace events —
the JSON object format both ``chrome://tracing`` and https://ui.perfetto.dev
load directly.  Track layout: ``pid`` is the span's process (coordinator 0,
shard workers by mesh process id), ``tid`` is derived from the trace id so
each request renders as its own row and spans nest by time within it.
Instant events (zero-duration) render as ``ph:"i"``.

The span identity (trace/span/parent ids) rides in ``args`` along with the
exact monotonic timestamps, so ``from_chrome`` round-trips a document back
into the recorder's tuple form — the stitched-trace acceptance test pushes
an N=2 trace through ``to_chrome`` → ``from_chrome`` and compares.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List


def _tuples(spans: Iterable) -> List[tuple]:
    return [s if isinstance(s, tuple) else s.as_tuple() for s in spans]


def to_chrome(spans: Iterable) -> Dict[str, Any]:
    events = []
    for t in _tuples(spans):
        trace_id, span_id, parent_id, name, component, t0, t1, proc, attrs = t
        ev: Dict[str, Any] = {
            "name": name,
            "cat": component or "app",
            "ts": t0 * 1e6,  # microseconds, Chrome's unit
            "pid": int(proc),
            "tid": int(trace_id % 1_000_000),
            "args": {
                "trace_id": f"{trace_id:x}",
                "span_id": int(span_id),
                "parent_id": int(parent_id),
                "t_start": float(t0),
                "t_end": float(t1),
                **{k: str(v) for k, v in dict(attrs).items()},
            },
        }
        if t1 > t0:
            ev["ph"] = "X"
            ev["dur"] = (t1 - t0) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def from_chrome(doc: Dict[str, Any]) -> List[tuple]:
    """Inverse of :func:`to_chrome` (for events it produced): recorder-form
    span tuples.  Extra attrs come back stringified — identity, structure
    and timing are exact."""
    out = []
    for ev in doc.get("traceEvents", []):
        args = dict(ev.get("args", {}))
        trace_id = int(args.pop("trace_id", "0"), 16)
        span_id = int(args.pop("span_id", 0))
        parent_id = int(args.pop("parent_id", 0))
        t0 = float(args.pop("t_start", ev.get("ts", 0.0) / 1e6))
        t1 = float(args.pop("t_end", t0 + ev.get("dur", 0.0) / 1e6))
        out.append(
            (trace_id, span_id, parent_id, ev.get("name", ""),
             ev.get("cat", "app"), t0, t1, int(ev.get("pid", 0)), args)
        )
    return out


def write_chrome_trace(path: str, spans: Iterable) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(spans), f, default=str)
    return path


def load_chrome_trace(path: str) -> List[tuple]:
    with open(path) as f:
        return from_chrome(json.load(f))
