"""Flight recorder: freeze the recent past when something goes wrong.

The trace ring always holds the last ``REPRO_OBS_RING`` spans; when a fault
fires — a worker death/reshard, a :class:`WorkerFailedError`, reshard-budget
exhaustion, a shed spike at the gateway door — :meth:`FlightRecorder.trigger`
freezes the last ``REPRO_OBS_FLIGHT_N`` of them plus a metrics snapshot into
one JSON document: the black box of the seconds *leading up to* the fault,
exactly what a post-mortem needs and what live polling can never reconstruct.

Dumps are kept in memory (``last`` / ``dumps``) and, when
``REPRO_OBS_FLIGHT_DIR`` is set, written to
``<dir>/flight-<process>-<seq>.json`` (loadable by ``python -m
repro.obs.report``).  A per-reason cooldown (default 1 s) stops a fault
storm from dumping in a loop; ``REPRO_OBS_FLIGHT=0`` disables triggering
entirely.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from . import envknobs
from . import log as obs_log
from . import metrics as obs_metrics
from . import trace as obs_trace


class FlightRecorder:
    def __init__(
        self,
        recorder: Optional[obs_trace.TraceRecorder] = None,
        registry: Optional[obs_metrics.MetricsRegistry] = None,
        last_n: Optional[int] = None,
        out_dir: Optional[str] = None,
        enabled: Optional[bool] = None,
        cooldown_s: float = 1.0,
        clock=time.perf_counter,
    ):
        self._recorder = recorder
        self._registry = registry
        self.last_n = int(
            last_n if last_n is not None else envknobs.env_int("REPRO_OBS_FLIGHT_N", 256)
        )
        self.out_dir = (
            out_dir if out_dir is not None else envknobs.env_str("REPRO_OBS_FLIGHT_DIR", "")
        )
        self.enabled = (
            enabled if enabled is not None else envknobs.env_flag("REPRO_OBS_FLIGHT", True)
        )
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._last_fire: dict = {}  # reason -> t of last dump
        self._seq = 0
        self.last: Optional[dict] = None
        self.dumps = 0
        self.history: list = []  # most recent dumps (bounded)

    def _rec(self) -> obs_trace.TraceRecorder:
        return self._recorder if self._recorder is not None else obs_trace.get_recorder()

    def trigger(self, reason: str, component: str = "obs", attrs: Optional[dict] = None,
                force: bool = False) -> Optional[dict]:
        """Freeze a dump.  Returns it (also stored on ``last``), or None
        when disabled or within the reason's cooldown window."""
        if not self.enabled:
            return None
        now = self._clock()
        with self._lock:
            if not force and now - self._last_fire.get(reason, float("-inf")) < self.cooldown_s:
                return None
            self._last_fire[reason] = now
            self._seq += 1
            seq = self._seq
        rec = self._rec()
        spans = rec.spans()[-self.last_n:]
        registry = self._registry if self._registry is not None else obs_metrics.get_registry()
        try:
            metrics = registry.snapshot()
        except Exception as e:  # the dump must land even if a source is sick
            metrics = {"error": f"{type(e).__name__}: {e}"}
        dump = {
            "kind": "flight",
            "reason": reason,
            "component": component,
            "t": now,
            "process": rec.process,
            "seq": seq,
            "attrs": attrs or {},
            "spans": [s.as_tuple() for s in spans],
            "metrics": metrics,
        }
        with self._lock:
            self.last = dump
            self.dumps += 1
            self.history.append(dump)
            if len(self.history) > 16:
                self.history.pop(0)
        path = self._write(dump, seq)
        obs_log.warn(
            component, f"flight dump triggered: {reason}",
            spans=len(spans), seq=seq, **({"path": path} if path else {}),
        )
        return dump

    def _write(self, dump: dict, seq: int) -> Optional[str]:
        if not self.out_dir:
            return None
        try:
            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.join(
                self.out_dir, f"flight-{dump['process']}-{seq:04d}.json"
            )
            with open(path, "w") as f:
                json.dump(dump, f, default=str)
            return path
        except OSError:
            return None  # a full/readonly disk must not take down serving


_default: Optional[FlightRecorder] = None
_dlock = threading.Lock()


def get_flight() -> FlightRecorder:
    global _default
    if _default is None:
        with _dlock:
            if _default is None:
                _default = FlightRecorder()
    return _default


def set_flight(fr: Optional[FlightRecorder]) -> None:
    global _default
    with _dlock:
        _default = fr
