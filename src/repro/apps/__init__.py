"""Application-level pipelines (the paper's use-cases as library code)."""
