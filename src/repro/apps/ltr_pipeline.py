"""The paper's §3 Learning-to-Rank search-filters pipeline, faithfully
reconstructed: ~60 chained transforms over query + per-item nested features.

    - dates disassembled into parts (month, weekday, dayofyear) for seasonality
    - date subtraction -> durations (days-until-checkin)
    - log transform of wide-range numericals (price, review count)
    - string features split into lists on delimiters (amenities)
    - selected numericals assembled -> standard scaled -> disassembled
    - categoricals indexed (vocab, hash, bloom and shared variants)
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core import (
    ArrayAggregateTransformer,
    BloomEncodeTransformer,
    BucketizeTransformer,
    ClipTransformer,
    ComparisonTransformer,
    DateDiffTransformer,
    DatePartTransformer,
    HashIndexTransformer,
    IfThenElseTransformer,
    ImputeEstimator,
    KamaeSparkPipeline,
    LogTransformer,
    LogicalTransformer,
    MathBinaryTransformer,
    MinMaxScaleEstimator,
    OneHotTransformer,
    QuantileBinEstimator,
    RoundTransformer,
    ScaleTransformer,
    StandardScaleEstimator,
    StringContainsTransformer,
    StringIndexEstimator,
    StringToDateTransformer,
    StringToStringListTransformer,
    VectorAssembleTransformer,
    VectorDisassembleTransformer,
)


def build_ltr_stages() -> Tuple[list, List[str]]:
    """Returns (stages, model feature columns)."""
    stages = [
        # --- dates -> parts and durations (8 stages) -----------------------
        StringToDateTransformer(inputCol="search_date", outputCol="search_days"),
        StringToDateTransformer(inputCol="checkin_date", outputCol="checkin_days"),
        DatePartTransformer(inputCol="search_days", outputCol="search_month", part="month"),
        DatePartTransformer(inputCol="search_days", outputCol="search_weekday", part="weekday"),
        DatePartTransformer(inputCol="checkin_days", outputCol="checkin_month", part="month"),
        DatePartTransformer(inputCol="checkin_days", outputCol="checkin_doy", part="dayofyear"),
        DateDiffTransformer(inputCols=["checkin_days", "search_days"], outputCol="lead_days"),
        LogTransformer(inputCol="lead_days", outputCol="lead_days_log", alpha=1.0, inputDtype="float32"),
        # --- numerical hygiene: impute, log (6) ------------------------------
        ImputeEstimator(inputCol="item_price", outputCol="price_filled", strategy="median"),
        LogTransformer(inputCol="price_filled", outputCol="price_log", alpha=1.0),
        LogTransformer(inputCol="item_review_count", outputCol="reviews_log", alpha=1.0),
        MathBinaryTransformer(inputCols=["item_review_score", "item_star_rating"], outputCol="score_x_star", op="mul"),
        MathBinaryTransformer(inputCol="price_filled", outputCol="price_per_room", op="div", constant=2.0),
        LogTransformer(inputCol="price_per_room", outputCol="price_per_room_log", alpha=1.0),
        # --- derived flags (4) ------------------------------------------------
        ComparisonTransformer(inputCol="item_star_rating", outputCol="is_luxury", op="ge", constant=4.0),
        ComparisonTransformer(inputCol="item_review_score", outputCol="is_loved", op="ge", constant=8.0),
        ComparisonTransformer(inputCol="price_filled", outputCol="is_budget", op="lt", constant=80.0),
        ComparisonTransformer(inputCol="lead_days", outputCol="is_last_minute", op="lt", constant=3.0),
        # --- amenity lists: split + shared indexing + aggregate (4) --------
        StringToStringListTransformer(
            inputCol="item_amenities", outputCol="amenities_split", separator=",",
            listLength=8, defaultValue="PADDED", outMaxLen=16,
        ),
        StringIndexEstimator(
            inputCol="amenities_split", outputCol="amenities_idx",
            maskToken="PADDED", numOOVIndices=1, stringOrderType="frequencyDesc",
        ),
        ArrayAggregateTransformer(
            inputCol="amenities_idx", outputCol="amenity_count", op="count", maskValue=0,
        ),
        LogTransformer(inputCol="amenity_count", outputCol="amenity_count_log", alpha=1.0, inputDtype="float32"),
        # --- categorical ids (5) ----------------------------------------------
        StringIndexEstimator(inputCol="destination", outputCol="dest_idx", numOOVIndices=1),
        # dual encoding: vocab index + collision-tolerant hash of the SAME
        # column (OOV-robust embeddings); the execution planner computes the
        # shared seed-0 hash once for both stages
        HashIndexTransformer(inputCol="destination", outputCol="dest_hash", numBins=4096),
        HashIndexTransformer(inputCol="user_id", outputCol="user_hash", inputDtype="string", numBins=65536),
        BloomEncodeTransformer(inputCol="item_id", outputCol="item_bloom", inputDtype="string", numBins=4096, numHashes=2),
        QuantileBinEstimator(inputCol="price_log", outputCol="price_bucket", numBuckets=8),
        # --- assemble -> standard scale -> disassemble (3, paper verbatim) --
        VectorAssembleTransformer(
            inputCols=["price_log", "reviews_log", "score_x_star", "price_per_room_log"],
            outputCol="num_vec",
        ),
        StandardScaleEstimator(inputCol="num_vec", outputCol="num_vec_s", featureSize=4),
        VectorDisassembleTransformer(
            inputCol="num_vec_s",
            outputCols=["price_log_s", "reviews_log_s", "score_x_star_s", "price_per_room_log_s"],
        ),
        # --- query-level scaling (2) -------------------------------------------
        MinMaxScaleEstimator(inputCol="lead_days_log", outputCol="lead_days_s"),
        StandardScaleEstimator(inputCol="amenity_count_log", outputCol="amenity_count_s"),
        # --- additional seasonality / interaction features (the production
        # pipeline the paper describes has ~60 transforms; same families) ----
        DatePartTransformer(inputCol="search_days", outputCol="search_year", part="year"),
        DatePartTransformer(inputCol="search_days", outputCol="search_day", part="day"),
        DatePartTransformer(inputCol="checkin_days", outputCol="checkin_weekday", part="weekday"),
        ComparisonTransformer(inputCol="checkin_weekday", outputCol="is_weekend_checkin", op="ge", constant=6),
        ComparisonTransformer(inputCol="search_weekday", outputCol="is_weekend_search", op="ge", constant=6),
        ScaleTransformer(inputCol="checkin_month", outputCol="checkin_month_n", multiplier=1 / 12.0, inputDtype="float32"),
        ScaleTransformer(inputCol="checkin_doy", outputCol="checkin_doy_n", multiplier=1 / 366.0, inputDtype="float32"),
        OneHotTransformer(inputCol="search_weekday", outputCol="search_weekday_1h", depth=8),
        BucketizeTransformer(inputCol="lead_days", outputCol="lead_bucket", splits=[1.0, 3.0, 7.0, 14.0, 30.0, 90.0], inputDtype="float64"),
        LogicalTransformer(inputCols=["is_luxury", "is_loved"], outputCol="lux_and_loved", op="and"),
        LogicalTransformer(inputCols=["is_budget", "is_loved"], outputCol="budget_gem", op="and"),
        IfThenElseTransformer(inputCols=["is_luxury", "item_review_score", "item_star_rating"], outputCol="quality_signal"),
        MathBinaryTransformer(inputCols=["item_review_count", "item_star_rating"], outputCol="reviews_per_star", op="div"),
        LogTransformer(inputCol="reviews_per_star", outputCol="reviews_per_star_log", alpha=1.0),
        ClipTransformer(inputCol="item_review_score", outputCol="review_clipped", minValue=2.0, maxValue=10.0),
        RoundTransformer(inputCol="price_filled", outputCol="price_rounded", mode="floor"),
        MathBinaryTransformer(inputCol="price_rounded", outputCol="price_mod100", op="mod", constant=100.0),
        ComparisonTransformer(inputCol="price_mod100", outputCol="charm_price", op="ge", constant=90.0),
        StringContainsTransformer(inputCol="item_amenities", outputCol="has_pool", pattern="pool"),
        StringContainsTransformer(inputCol="item_amenities", outputCol="has_wifi", pattern="wifi"),
        ArrayAggregateTransformer(inputCol="amenities_idx", outputCol="rare_amenity", op="max", maskValue=0),
        MinMaxScaleEstimator(inputCol="checkin_doy_n", outputCol="checkin_doy_s"),
        StandardScaleEstimator(inputCol="reviews_per_star_log", outputCol="reviews_per_star_s"),
        StandardScaleEstimator(inputCol="quality_signal", outputCol="quality_signal_s"),
    ]
    # model consumes per-item numeric features (query-level ones broadcast)
    features = [
        "price_log_s",
        "reviews_log_s",
        "score_x_star_s",
        "price_per_room_log_s",
        "item_star_rating",
        "amenity_count_s",
        "reviews_per_star_s",
        "quality_signal_s",
    ]
    return stages, features


def build_ltr_pipeline(train_batch):
    stages, features = build_ltr_stages()
    pipe = KamaeSparkPipeline(stages=stages)
    fitted = pipe.fit(train_batch)
    return fitted, features


def n_transforms() -> int:
    """Transform count incl. sub-operations — the paper quotes ~60 overall."""
    stages, _ = build_ltr_stages()
    n = 0
    for s in stages:
        n += max(len(s.output_names), 1)
    return n
