"""Request micro-batching for the fused serving path.

The paper's production deployment serves ~200 requests/s behind a Java
chassis; the throughput win of a fused XLA program only materialises if
requests are batched.  This batcher gathers requests up to ``max_batch`` or
``max_wait_ms`` (whichever first), pads the batch to a fixed set of bucket
sizes (so XLA reuses a handful of compiled programs instead of recompiling
per batch size), runs the fused model once, and scatters replies.

Host→device staging goes through the same :func:`repro.core.runner.
stage_batch` helper as the offline PlanRunner, so online and offline paths
place batches identically — including onto a mesh, when ``sharding`` is
given.  Each call stages a FRESH device batch, which is what makes the
FusedModel's default buffer donation safe on this path.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runner import stage_batch


class _Pending:
    __slots__ = ("features", "event", "result", "error")

    def __init__(self, features):
        self.features = features
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class MicroBatcher:
    """Batches single-row feature dicts into fused-model calls.

    Args:
      model_fn: batch features dict -> outputs (first axis = batch).
      max_batch: upper bound on batch size.
      max_wait_ms: latency budget for filling a batch.
      buckets: padded batch sizes to compile for (ascending).
      sharding: optional jax sharding for staged request batches (a serving
        tier running the fused model across a mesh); None = default device.
    """

    def __init__(
        self,
        model_fn: Callable[[Dict[str, jax.Array]], Any],
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
        sharding=None,
    ):
        self.model_fn = model_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.buckets = tuple(b for b in buckets if b <= max_batch) or (max_batch,)
        self.sharding = sharding
        self.q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = False
        self.batches_run = 0
        self.rows_served = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- client side ------------------------------------------------------
    def submit(self, features: Dict[str, Any], timeout: float = 30.0):
        p = _Pending(features)
        self.q.put(p)
        if not p.event.wait(timeout):
            raise TimeoutError("serving deadline exceeded")
        if p.error is not None:
            raise p.error
        return p.result

    def close(self):
        self._stop = True
        self._thread.join(timeout=5)

    # -- server side --------------------------------------------------------
    def _collect(self) -> List[_Pending]:
        try:
            first = self.q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self):
        while not self._stop:
            batch = self._collect()
            if not batch:
                continue
            try:
                n = len(batch)
                bs = _bucket(n, self.buckets)
                cols = {}
                for k in batch[0].features:
                    rows = [np.asarray(p.features[k]) for p in batch]
                    stacked = np.stack(rows)
                    if bs > n:  # pad with repeats of the last row
                        pad = np.repeat(stacked[-1:], bs - n, axis=0)
                        stacked = np.concatenate([stacked, pad], axis=0)
                    cols[k] = stacked
                out = self.model_fn(stage_batch(cols, self.sharding))
                out = jax.device_get(out)
                self.batches_run += 1
                self.rows_served += n
                for i, p in enumerate(batch):
                    p.result = jax.tree.map(lambda a: a[i], out)
                    p.event.set()
            except BaseException as e:  # deliver errors to all waiters
                for p in batch:
                    p.error = e
                    p.event.set()
