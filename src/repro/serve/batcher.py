"""Request micro-batching for the fused serving path.

The paper's production deployment serves ~200 requests/s behind a Java
chassis; the throughput win of a fused XLA program only materialises if
requests are batched.  This batcher gathers requests up to ``max_batch`` or
``max_wait_ms`` (whichever first), pads the batch to a fixed set of bucket
sizes (so XLA reuses a handful of compiled programs instead of recompiling
per batch size), runs the fused model once, and scatters replies.

Failure containment: a batch whose model call raises is re-run one request
at a time, so a single poisoned request receives its own error while the
rest of the batch still gets results.  ``close()`` drains — requests still
queued when the loop stops fail fast with :class:`BatcherClosedError`
instead of leaving their submitters blocked until timeout.

Host→device staging goes through the same :func:`repro.core.runner.
stage_batch` helper as the offline PlanRunner, so online and offline paths
place batches identically — including onto a mesh, when ``sharding`` is
given.  Each call stages a FRESH device batch, which is what makes the
FusedModel's default buffer donation safe on this path.

The multi-model, admission-controlled serving tier built on the same
batching ideas lives in :mod:`repro.serve.gateway`.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.runner import stage_batch


class BatcherClosedError(RuntimeError):
    """The batcher was closed before this request could run."""


class _Pending:
    __slots__ = ("features", "event", "result", "error")

    def __init__(self, features):
        self.features = features
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None


def _bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket holding ``n`` rows (``buckets`` ascending)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def normalize_buckets(buckets: Sequence[int], max_batch: int):
    """``(ascending buckets <= max_batch, clamped max_batch)``.

    The bucket list is the CLOSED set of batch shapes the serving tier
    executes (and, behind a warmed gateway, the only compiled ones); a batch
    larger than the top bucket would run unpadded at a never-bucketed shape,
    so ``max_batch`` clamps to it.  Shared by MicroBatcher and the gateway
    registry so the two tiers bucket identically."""
    bl = tuple(sorted(b for b in buckets if b <= max_batch)) or (int(max_batch),)
    return bl, min(int(max_batch), bl[-1])


def run_padded_batch(rows_features, bucket_size: int, model_fn, sharding=None, stage: bool = True):
    """Run a list of single-row feature dicts as ONE padded model call.

    Stacks rows column-wise, pads to ``bucket_size`` by repeating the last
    row (padding rows are discarded, never returned), stages the batch
    (:func:`repro.core.runner.stage_batch`, mesh-sharded when ``sharding``
    is given) and scatters the host-fetched outputs back per row.  Shared by
    :class:`MicroBatcher` and the gateway's batch executor so the two
    serving tiers cannot diverge in padding/staging/scatter semantics.

    ``stage=False`` hands the padded HOST columns straight to ``model_fn``
    — for self-staging servables (the multi-host gateway's
    :class:`~repro.serve.gateway.multihost.MultiHostServable`), where each
    process stages exactly its own row block and a coordinator-side
    device_put would be a wasted full-batch copy."""
    n = len(rows_features)
    cols = {}
    for k in rows_features[0]:
        stacked = np.stack([np.asarray(f[k]) for f in rows_features])
        if bucket_size > n:
            pad = np.repeat(stacked[-1:], bucket_size - n, axis=0)
            stacked = np.concatenate([stacked, pad], axis=0)
        cols[k] = stacked
    out = model_fn(stage_batch(cols, sharding) if stage else cols)
    out = jax.device_get(out)
    return [jax.tree.map(lambda a, i=i: a[i], out) for i in range(n)]


class MicroBatcher:
    """Batches single-row feature dicts into fused-model calls.

    Args:
      model_fn: batch features dict -> outputs (first axis = batch).
      max_batch: upper bound on batch size.
      max_wait_ms: latency budget for filling a batch.
      buckets: padded batch sizes to compile for (ascending).
      sharding: optional jax sharding for staged request batches (a serving
        tier running the fused model across a mesh); None = default device.
    """

    def __init__(
        self,
        model_fn: Callable[[Dict[str, jax.Array]], Any],
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
        sharding=None,
    ):
        self.model_fn = model_fn
        self.max_wait = max_wait_ms / 1e3
        self.buckets, self.max_batch = normalize_buckets(buckets, max_batch)
        self.sharding = sharding
        self.q: "queue.Queue[_Pending]" = queue.Queue()
        self._stop = False
        self._closed = False
        self._close_lock = threading.Lock()
        self.batches_run = 0
        self.rows_served = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- client side ------------------------------------------------------
    def submit(self, features: Dict[str, Any], timeout: float = 30.0):
        p = _Pending(features)
        # closed-check and enqueue are atomic vs close(): a request is either
        # rejected here or guaranteed to be in the queue before close() runs
        # its final drain — never silently stranded between the two
        with self._close_lock:
            if self._closed:
                raise BatcherClosedError("MicroBatcher is closed")
            self.q.put(p)
        if not p.event.wait(timeout):
            raise TimeoutError("serving deadline exceeded")
        if p.error is not None:
            raise p.error
        return p.result

    def close(self):
        """Stop the loop and DRAIN: any request still queued is failed with
        :class:`BatcherClosedError` immediately, so its submitter unblocks
        now rather than at its timeout."""
        with self._close_lock:
            self._closed = True
            self._stop = True
        self._thread.join(timeout=5)
        self._drain()

    def _drain(self):
        while True:
            try:
                p = self.q.get_nowait()
            except queue.Empty:
                return
            p.error = BatcherClosedError(
                "MicroBatcher closed before the request ran"
            )
            p.event.set()

    # -- server side --------------------------------------------------------
    def _collect(self) -> List[_Pending]:
        try:
            first = self.q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _run(self, batch: List[_Pending]) -> None:
        try:
            n = len(batch)
            bs = _bucket(n, self.buckets)
            results = run_padded_batch(
                [p.features for p in batch], bs, self.model_fn, self.sharding
            )
            self.batches_run += 1
            self.rows_served += n
            for p, r in zip(batch, results):
                p.result = r
                p.event.set()
        except BaseException as e:
            if len(batch) == 1:
                # errors reach exactly the request that caused them
                batch[0].error = e
                batch[0].event.set()
            else:
                # failure isolation: re-run one request at a time so a single
                # poisoned request cannot fail the whole batch
                for p in batch:
                    self._run([p])

    def _loop(self):
        while not self._stop:
            batch = self._collect()
            if batch:
                self._run(batch)
        self._drain()  # requests that raced the close still unblock
