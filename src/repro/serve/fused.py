"""FusedModel — the paper's deployment artifact.

Fuses the exported :class:`~repro.core.export.PreprocessModel` with a trained
backbone into ONE jitted function: raw request features go in, model outputs
come out, and XLA compiles preprocessing + model as a single program.  This
is precisely the mechanism behind the paper's production result (61% serving
latency / 58% cost reduction vs interpreting a preprocessing pipeline — here
the unfused baseline is measured by ``benchmarks/preprocessing.py``).

Request buffers are DONATED to the fused executable by default: the serving
tier (MicroBatcher) stages a fresh batch per call, so XLA may reuse the
request buffers for intermediates/outputs instead of allocating.  Callers
that re-read a batch after calling the model (donated jax buffers are
invalidated) opt out per-instance with ``donate=False`` or globally with
``REPRO_SERVE_DONATE=0``.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.export import PreprocessModel


def _donate_default() -> bool:
    return os.environ.get("REPRO_SERVE_DONATE", "1") not in ("0", "false", "")


class FusedModel:
    def __init__(
        self,
        preprocess: PreprocessModel,
        model_fn: Callable[[Any, T.Batch], Any],
        params: Any,
        feature_map: Optional[Dict[str, str]] = None,
        donate: Optional[bool] = None,
    ):
        """
        Args:
          preprocess: exported preprocessing graph.
          model_fn: (params, features) -> outputs, consuming preprocessed cols.
          params: backbone weights.
          feature_map: renames preprocessed columns to model input names.
          donate: donate the raw request buffers to the fused executable.
            None = the ``REPRO_SERVE_DONATE`` env default (on).  Donated
            input arrays are invalidated after the call.
        """
        self.preprocess = preprocess
        self.model_fn = model_fn
        self.params = params
        self.feature_map = feature_map or {}
        self.donate = _donate_default() if donate is None else donate
        # the fused path traces the preprocessing through its TransformPlan:
        # coercions/hashes are CSE'd before XLA ever sees them, which keeps
        # trace time and HLO size down for wide pipelines.  All jit wrappers
        # are created once here — never per call.
        self._plan = preprocess.plan()
        self._fused = jax.jit(
            self._call, donate_argnums=(1,) if self.donate else ()
        )
        self._unfused_pre = jax.jit(preprocess.__call__)
        self._unfused_model = jax.jit(model_fn)

    def _call(self, params, raw: T.Batch):
        feats = self._plan.fn(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self.model_fn(params, feats)

    def __call__(self, raw: T.Batch):
        """Single-XLA-program serving path (preprocessing fused in).  With
        donation on (default), ``raw``'s buffers are consumed by the call."""
        return self._fused(self.params, raw)

    def call_unfused(self, raw: T.Batch):
        """Two-program baseline (MLeap-style pipeline-then-model) — used by
        the latency benchmark to quantify the fusion win."""
        feats = self._unfused_pre(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self._unfused_model(self.params, feats)
