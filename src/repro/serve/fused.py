"""FusedModel — the paper's deployment artifact.

Fuses the exported :class:`~repro.core.export.PreprocessModel` with a trained
backbone into ONE jitted function: raw request features go in, model outputs
come out, and XLA compiles preprocessing + model as a single program.  This
is precisely the mechanism behind the paper's production result (61% serving
latency / 58% cost reduction vs interpreting a preprocessing pipeline — here
the unfused baseline is measured by ``benchmarks/preprocessing.py``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import types as T
from repro.core.export import PreprocessModel


class FusedModel:
    def __init__(
        self,
        preprocess: PreprocessModel,
        model_fn: Callable[[Any, T.Batch], Any],
        params: Any,
        feature_map: Optional[Dict[str, str]] = None,
    ):
        """
        Args:
          preprocess: exported preprocessing graph.
          model_fn: (params, features) -> outputs, consuming preprocessed cols.
          params: backbone weights.
          feature_map: renames preprocessed columns to model input names.
        """
        self.preprocess = preprocess
        self.model_fn = model_fn
        self.params = params
        self.feature_map = feature_map or {}
        # the fused path traces the preprocessing through its TransformPlan:
        # coercions/hashes are CSE'd before XLA ever sees them, which keeps
        # trace time and HLO size down for wide pipelines.  All jit wrappers
        # are created once here — never per call.
        self._plan = preprocess.plan()
        self._fused = jax.jit(self._call)
        self._unfused_pre = jax.jit(preprocess.__call__)
        self._unfused_model = jax.jit(model_fn)

    def _call(self, params, raw: T.Batch):
        feats = self._plan.fn(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self.model_fn(params, feats)

    def __call__(self, raw: T.Batch):
        """Single-XLA-program serving path (preprocessing fused in)."""
        return self._fused(self.params, raw)

    def call_unfused(self, raw: T.Batch):
        """Two-program baseline (MLeap-style pipeline-then-model) — used by
        the latency benchmark to quantify the fusion win."""
        feats = self._unfused_pre(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self._unfused_model(self.params, feats)
