"""FusedModel — the paper's deployment artifact.

Fuses the exported :class:`~repro.core.export.PreprocessModel` with a trained
backbone into ONE jitted function: raw request features go in, model outputs
come out, and XLA compiles preprocessing + model as a single program.  This
is precisely the mechanism behind the paper's production result (61% serving
latency / 58% cost reduction vs interpreting a preprocessing pipeline — here
the unfused baseline is measured by ``benchmarks/preprocessing.py``).

The fused executable cache is **mesh-keyed**, mirroring
:meth:`repro.core.plan.TransformPlan.jit_for`: wrappers are cached per
``(sharding fingerprint, donate)`` and within each wrapper XLA keys on the
input signature, so ONE FusedModel instance serves an unsharded laptop and
any number of multi-device meshes from the same code path.  Pass a batch
sharding (``Engine.batch_sharding()`` / ``launch.mesh.batch_sharding``) to
``__call__``/``jit_for``; params are placed replicated on the same mesh.

Request buffers are DONATED to the fused executable by default: the serving
tier (MicroBatcher / ServingGateway) stages a fresh batch per call, so XLA
may reuse the request buffers for intermediates/outputs instead of
allocating.  Callers that re-read a batch after calling the model (donated
jax buffers are invalidated) opt out per-instance with ``donate=False`` or
globally with ``REPRO_SERVE_DONATE=0``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax

from repro.core import types as T
from repro.core.export import PreprocessModel
from repro.launch.mesh import sharding_fingerprint
from repro.obs import envknobs
from repro.obs import trace as obs_trace


def _donate_default() -> bool:
    return envknobs.env_flag("REPRO_SERVE_DONATE", True)


class FusedModel:
    def __init__(
        self,
        preprocess: PreprocessModel,
        model_fn: Callable[[Any, T.Batch], Any],
        params: Any,
        feature_map: Optional[Dict[str, str]] = None,
        donate: Optional[bool] = None,
    ):
        """
        Args:
          preprocess: exported preprocessing graph.
          model_fn: (params, features) -> outputs, consuming preprocessed cols.
          params: backbone weights.
          feature_map: renames preprocessed columns to model input names.
          donate: donate the raw request buffers to the fused executable.
            None = the ``REPRO_SERVE_DONATE`` env default (on).  Donated
            input arrays are invalidated after the call.
        """
        self.preprocess = preprocess
        self.model_fn = model_fn
        self.params = params
        self.feature_map = feature_map or {}
        self.donate = _donate_default() if donate is None else donate
        # the fused path traces the preprocessing through its TransformPlan:
        # coercions/hashes are CSE'd before XLA ever sees them, which keeps
        # trace time and HLO size down for wide pipelines.  All jit wrappers
        # are created once per (sharding, donate) — never per call.
        self._plan = preprocess.plan()
        self._trace_count = 0
        self._jit_cache: Dict[tuple, object] = {}
        self._unfused_pre = jax.jit(preprocess.__call__)
        self._unfused_model = jax.jit(model_fn)

    def _call(self, params, raw: T.Batch):
        self._trace_count += 1  # python side effect: runs at trace time only
        obs_trace.get_recorder().event(
            "fused.trace", component="plan",
            attrs={"trace_count": self._trace_count},
        )
        feats = self._plan.fn(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self.model_fn(params, feats)

    def jit_for(self, sharding=None, donate: Optional[bool] = None):
        """The cached fused wrapper for one execution context (mirrors
        ``TransformPlan.jit_for``).

        ``sharding`` is the batch placement for the raw request columns — a
        NamedSharding from ``Engine.batch_sharding()`` for a mesh-sharded
        serving tier, or None for the default device.  Params are lowered
        replicated on the sharding's mesh.  Wrappers are cached on
        ``(sharding_fingerprint, donate)``: equal-fingerprint meshes hit the
        same compiled program, a differing mesh is a guaranteed miss — one
        FusedModel serves unsharded and any number of meshes, compiled at
        most once per input signature."""
        if donate is None:
            donate = self.donate
        key = (sharding_fingerprint(sharding), bool(donate))
        fn = self._jit_cache.get(key)
        if fn is None:
            kwargs = {}
            if sharding is not None:
                mesh = getattr(sharding, "mesh", None)
                if mesh is not None:
                    from jax.sharding import NamedSharding, PartitionSpec

                    repl = NamedSharding(mesh, PartitionSpec())
                else:
                    repl = sharding
                # pytree prefixes: whole params tree replicated, every raw
                # column placed with the batch sharding
                kwargs["in_shardings"] = (repl, sharding)
            fn = jax.jit(
                self._call, donate_argnums=(1,) if donate else (), **kwargs
            )
            self._jit_cache[key] = fn
        return fn

    def warm_fused(self, raw: T.Batch) -> dict:
        """Autotune the plan's fused transform chains on a representative raw
        batch (see :meth:`repro.core.plan.TransformPlan.warm_fused`).  Called
        by ``registry.warmup`` BEFORE the AOT precompile sweep so tuned block
        configs are on disk by the time the fused executable lowers; a tuned-
        config cache hit performs zero sweeps.  Returns the tuner stats."""
        return self._plan.warm_fused(raw)

    @property
    def trace_count(self) -> int:
        """How many times the fused function has been traced — the serving
        tier's compile-count probe (zero new traces after warmup)."""
        return self._trace_count

    @property
    def stats(self) -> dict:
        return {
            "trace_count": self._trace_count,
            "jit_cache_entries": len(self._jit_cache),
        }

    def __call__(self, raw: T.Batch, sharding=None):
        """Single-XLA-program serving path (preprocessing fused in).  With
        donation on (default), ``raw``'s buffers are consumed by the call.
        ``sharding`` selects the mesh-keyed executable (see ``jit_for``)."""
        return self.jit_for(sharding)(self.params, raw)

    def call_unfused(self, raw: T.Batch):
        """Two-program baseline (MLeap-style pipeline-then-model) — used by
        the latency benchmark to quantify the fusion win."""
        feats = self._unfused_pre(raw)
        feats = {self.feature_map.get(k, k): v for k, v in feats.items()}
        return self._unfused_model(self.params, feats)
