"""Serving layer: fused preprocessing+model bundles, batched decode, and the
multi-model online gateway (admission control, continuous batching)."""
from .batcher import BatcherClosedError, MicroBatcher
from .decode import greedy_decode
from .fused import FusedModel
from .gateway import (
    DeadlineExceededError,
    GatewayClosedError,
    GatewayError,
    QueueFullError,
    ServingGateway,
    UnknownModelError,
)

__all__ = [
    "FusedModel",
    "MicroBatcher",
    "BatcherClosedError",
    "ServingGateway",
    "GatewayError",
    "QueueFullError",
    "DeadlineExceededError",
    "GatewayClosedError",
    "UnknownModelError",
    "greedy_decode",
]
