"""Serving layer: fused preprocessing+model bundles, batched decode, and the
multi-model online gateway (admission control, continuous batching)."""
from .batcher import BatcherClosedError, MicroBatcher
from .decode import greedy_decode
from .fused import FusedModel
from .gateway import (
    DeadlineExceededError,
    ExecuteCostModel,
    GatewayClosedError,
    GatewayError,
    InfeasibleDeadlineError,
    MultiHostExecutor,
    MultiHostServable,
    QueueFullError,
    ServingGateway,
    ShardServer,
    UnknownModelError,
    WorkerFailedError,
    accept_workers,
)

__all__ = [
    "FusedModel",
    "MicroBatcher",
    "BatcherClosedError",
    "ServingGateway",
    "ExecuteCostModel",
    "MultiHostExecutor",
    "MultiHostServable",
    "ShardServer",
    "WorkerFailedError",
    "accept_workers",
    "GatewayError",
    "QueueFullError",
    "DeadlineExceededError",
    "InfeasibleDeadlineError",
    "GatewayClosedError",
    "UnknownModelError",
    "greedy_decode",
]
