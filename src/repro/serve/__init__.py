"""Serving layer: fused preprocessing+model bundles, batched decode."""
from .fused import FusedModel
from .decode import greedy_decode

__all__ = ["FusedModel", "greedy_decode"]
