"""Serving layer: fused preprocessing+model bundles, batched decode, and the
multi-model online gateway (admission control, continuous batching)."""
from .batcher import BatcherClosedError, MicroBatcher
from .decode import greedy_decode
from .fused import FusedModel
from .gateway import (
    DeadlineExceededError,
    ExecuteCostModel,
    GatewayClosedError,
    GatewayError,
    InfeasibleDeadlineError,
    QueueFullError,
    ServingGateway,
    UnknownModelError,
)

__all__ = [
    "FusedModel",
    "MicroBatcher",
    "BatcherClosedError",
    "ServingGateway",
    "ExecuteCostModel",
    "GatewayError",
    "QueueFullError",
    "DeadlineExceededError",
    "InfeasibleDeadlineError",
    "GatewayClosedError",
    "UnknownModelError",
    "greedy_decode",
]
