"""Batched greedy decode loop over a model's serve step."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def greedy_decode(model, params, prompt_tokens: jax.Array, steps: int, max_len: int):
    """Prefill via single-token steps, then generate ``steps`` new tokens.

    prompt_tokens: (B, P) int32.  Returns (B, steps) generated ids.
    """
    B, P = prompt_tokens.shape
    cache = model.init_cache(B, max_len)

    decode = jax.jit(model.decode_step)

    tok = prompt_tokens[:, :1]
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompt_tokens[:, t : t + 1])
    outs = []
    nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        outs.append(nxt)
        logits, cache = decode(params, cache, nxt)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)
