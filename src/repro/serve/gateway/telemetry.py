"""Per-request latency telemetry on the gateway hot path.

Observations land in the SAME log-binned layout as the mergeable DDSketch in
:mod:`repro.core.sketches` (``dd_init`` / ``dd_merge`` / ``dd_quantile``),
via the numpy fast path (``dd_bin_np``) — a jit dispatch per request would
cost more than the thing being measured.  Each recording thread owns its own
histogram (no lock on the hot path); because the sketch is a commutative
monoid under addition, merging the per-thread histograms at snapshot time is
order-independent — the same property that lets the fitting engine merge
shard statistics in any order (asserted by tests/test_sketches.py, along
with the documented ~4% relative quantile error bound).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Tuple

import numpy as np

from repro.core import sketches


class LatencySketch:
    """Thread-sharded DDSketch recorder: seconds in, quantiles out."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hists: Dict[int, np.ndarray] = {}  # thread ident -> histogram

    def record(self, seconds: float) -> None:
        tid = threading.get_ident()
        h = self._hists.get(tid)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(tid, sketches.dd_init_np())
        h[int(sketches.dd_bin_np(seconds))] += 1

    def merged(self) -> np.ndarray:
        """One histogram folding every recording thread's observations."""
        with self._lock:
            hists = list(self._hists.values())
        out = sketches.dd_init_np()
        for h in hists:
            out = sketches.dd_merge(out, h)
        return out

    @property
    def count(self) -> int:
        return int(self.merged().sum())

    def quantiles(self, qs: Iterable[float] = (0.5, 0.99)) -> Dict[float, float]:
        qs = tuple(qs)
        # dd_quantile_np handles the empty histogram (NaN per q) and avoids a
        # jnp dispatch on the snapshot path
        vals = sketches.dd_quantile_np(self.merged(), list(qs))
        return {q: float(v) for q, v in zip(qs, vals)}

    def snapshot_us(self, qs: Tuple[float, ...] = (0.5, 0.99)) -> Dict[str, float]:
        """Quantiles in microseconds plus the observation count — the shape
        the gateway surfaces per (model, stage)."""
        quants = self.quantiles(qs)
        out = {quantile_label(q): round(v * 1e6, 1) for q, v in quants.items()}
        out["count"] = self.count
        return out


class CounterSet:
    """Thread-safe named event counters with a consistent snapshot.

    The fault-tolerant executor mutates these from gateway worker threads,
    the ping sweeper and the accept loop concurrently; ``snapshot()`` returns
    one coherent dict (taken under the lock) so a poller never observes, say,
    a death without its reshard.  ``set()`` records gauges (last-write-wins
    values like recovery latency) alongside the monotone counters."""

    def __init__(self, **initial):
        self._lock = threading.Lock()
        self._counts: Dict[str, float] = dict(initial)

    def inc(self, name: str, by: float = 1) -> None:
        with self._lock:
            self._counts[name] = self._counts.get(name, 0) + by

    def set(self, name: str, value: float) -> None:
        with self._lock:
            self._counts[name] = value

    def get(self, name: str, default: float = 0):
        with self._lock:
            return self._counts.get(name, default)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counts)


def quantile_label(q: float) -> str:
    """``0.5 -> 'p50_us'``, ``0.99 -> 'p99_us'``, ``0.999 -> 'p99_9_us'``.

    Truncating with ``int(q * 100)`` collapsed 0.99 and 0.999 onto the same
    ``p99_us`` key, silently dropping one of them from a snapshot dict."""
    pct = round(q * 100, 6)
    text = f"{pct:g}".replace(".", "_")
    return f"p{text}_us"
