"""Per-request latency telemetry on the gateway hot path.

Observations land in the SAME log-binned layout as the mergeable DDSketch in
:mod:`repro.core.sketches` (``dd_init`` / ``dd_merge`` / ``dd_quantile``),
via the numpy fast path (``dd_bin_np``) — a jit dispatch per request would
cost more than the thing being measured.  Each recording thread owns its own
histogram (no lock on the hot path); because the sketch is a commutative
monoid under addition, merging the per-thread histograms at snapshot time is
order-independent — the same property that lets the fitting engine merge
shard statistics in any order (asserted by tests/test_sketches.py, along
with the documented ~4% relative quantile error bound).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Tuple

import numpy as np

from repro.core import sketches


class LatencySketch:
    """Thread-sharded DDSketch recorder: seconds in, quantiles out.

    Snapshots are memoized by update count: each recording thread bumps its
    own counter (single-writer, no lock on the hot path), their sum is the
    sketch's *version*, and the merged histogram + quantile dict are cached
    per ``(version, qs)`` — a concurrent poller hammering ``snapshot_us``
    pays one version sum per poll instead of a full merge + quantile scan
    when nothing was recorded in between (``recomputes`` counts the cache
    misses; the memoization test pins it)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hists: Dict[int, np.ndarray] = {}  # thread ident -> histogram
        self._counts: Dict[int, int] = {}  # thread ident -> records so far
        self._cache: Tuple = (-1, None, None, None)  # version, qs, merged, snap
        self.recomputes = 0

    def record(self, seconds: float) -> None:
        tid = threading.get_ident()
        h = self._hists.get(tid)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(tid, sketches.dd_init_np())
                self._counts.setdefault(tid, 0)
        h[int(sketches.dd_bin_np(seconds))] += 1
        # single-writer per tid: a plain increment is safe; pollers reading a
        # torn-by-one version merely recompute (or serve) one poll early
        self._counts[tid] += 1  # analyze: allow(lock-unguarded-mutation) single writer per tid; torn reads only cost one early recompute

    def _version(self) -> int:
        with self._lock:
            return sum(self._counts.values())

    def merged(self) -> np.ndarray:
        """One histogram folding every recording thread's observations."""
        with self._lock:
            hists = list(self._hists.values())
        out = sketches.dd_init_np()
        for h in hists:
            out = sketches.dd_merge(out, h)
        return out

    @property
    def count(self) -> int:
        return int(self.merged().sum())

    def quantiles(self, qs: Iterable[float] = (0.5, 0.99)) -> Dict[float, float]:
        qs = tuple(qs)
        # dd_quantile_np handles the empty histogram (NaN per q) and avoids a
        # jnp dispatch on the snapshot path
        vals = sketches.dd_quantile_np(self.merged(), list(qs))
        return {q: float(v) for q, v in zip(qs, vals)}

    def snapshot_us(self, qs: Tuple[float, ...] = (0.5, 0.99)) -> Dict[str, float]:
        """Quantiles in microseconds plus the observation count — the shape
        the gateway surfaces per (model, stage).  Memoized by update count
        (returns a copy; callers may mutate their snapshot dicts)."""
        qs = tuple(qs)
        version = self._version()
        with self._lock:
            c_version, c_qs, _, c_snap = self._cache
            if c_version == version and c_qs == qs:
                return dict(c_snap)
        merged = self.merged()
        vals = sketches.dd_quantile_np(merged, list(qs))
        out = {
            quantile_label(q): round(float(v) * 1e6, 1) for q, v in zip(qs, vals)
        }
        out["count"] = int(merged.sum())
        with self._lock:
            self.recomputes += 1
            self._cache = (version, qs, merged, dict(out))
        return out


class CounterSet:
    """Thread-safe named event counters with a consistent snapshot.

    The fault-tolerant executor mutates these from gateway worker threads,
    the ping sweeper and the accept loop concurrently; ``snapshot()`` returns
    one coherent dict (taken under the lock) so a poller never observes, say,
    a death without its reshard.  ``set()`` records gauges (last-write-wins
    values like recovery latency) alongside the monotone counters."""

    def __init__(self, **initial):
        self._lock = threading.Lock()
        self._counts: Dict[str, float] = dict(initial)

    def inc(self, name: str, by: float = 1) -> None:
        with self._lock:
            self._counts[name] = self._counts.get(name, 0) + by

    def set(self, name: str, value: float) -> None:
        with self._lock:
            self._counts[name] = value

    def get(self, name: str, default: float = 0):
        with self._lock:
            return self._counts.get(name, default)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counts)


def quantile_label(q: float) -> str:
    """``0.5 -> 'p50_us'``, ``0.99 -> 'p99_us'``, ``0.999 -> 'p99_9_us'``.

    Truncating with ``int(q * 100)`` collapsed 0.99 and 0.999 onto the same
    ``p99_us`` key, silently dropping one of them from a snapshot dict."""
    pct = round(q * 100, 6)
    text = f"{pct:g}".replace(".", "_")
    return f"p{text}_us"
