"""ServingGateway — the online serving front door.

The paper's production result (61% latency / 58% cost) materialises behind a
request-serving chassis, not a benchmark loop.  This is that chassis for the
JAX reproduction: many named fused models behind ONE gateway, with

  client ──► admission (bounded queue, backpressure, door shedding)
                 │
                 ▼
         scheduler groups per (model, row shape); continuous,
         priority/deadline-aware formation, padded to buckets
                 │
                 ▼  (any idle worker)
         stage_batch ► fused executable (mesh-keyed cache) ► scatter replies

Every stage is measured into mergeable DDSketch histograms (queue wait,
execute, end-to-end, per model) and surfaced as quantile snapshots; warmup
AOT-precompiles every (model, bucket) shape so first requests never trace.

Single-model, no-admission serving remains available as
:class:`~repro.serve.batcher.MicroBatcher`; the gateway is the multi-model,
overload-safe tier on top of the same staging + bucketing machinery.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import envknobs
from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.batcher import run_padded_batch

from .admission import (
    AdmissionController,
    DeadlineExceededError,
    GatewayClosedError,
    InfeasibleDeadlineError,
    UnknownModelError,
)
from .costmodel import ExecuteCostModel
from .registry import ModelEntry, ModelRegistry
from .scheduler import BatchScheduler, Request
from .telemetry import LatencySketch

# execute_retry: durations of per-request reruns after a batch failure.
# execute_hedge / execute_reshard: durations of batches whose multi-host
# routing hit a straggler hedge or a degraded-mesh re-execution (tagged by
# the servable via take_batch_events).  All three are kept OUT of "execute"
# (and out of the cost model) so failure-path timings cannot distort the
# latency record or the scheduling estimates healthy batches live by.
_STAGES = ("queue", "execute", "execute_retry", "execute_hedge", "execute_reshard", "e2e")


class ServingGateway:
    """Admission-controlled, continuously-batching, multi-model gateway.

    Args:
      max_pending: bounded-queue admission cap (backpressure beyond it).
      max_wait_ms: batch-formation window (a tighter request deadline cuts
        it short).
      workers: executor threads pulling formed batches.  Batches for
        different models execute concurrently when >1.
      clock: monotonic time source (injectable for tests).
      cost_model: finish-time feasibility (see :mod:`.costmodel`).  ``None``
        (default) builds an :class:`ExecuteCostModel` unless
        ``REPRO_GW_COST_MODEL=0``; ``False`` disables it (launch-time-only
        deadlines, the pre-cost-model behaviour); an instance is used as-is.
    """

    def __init__(
        self,
        max_pending: int = 256,
        max_wait_ms: float = 2.0,
        workers: int = 2,
        clock=time.perf_counter,
        cost_model=None,
    ):
        if cost_model is None:
            enabled = envknobs.env_flag("REPRO_GW_COST_MODEL", True)
            cost_model = ExecuteCostModel() if enabled else None
        elif cost_model is False:
            cost_model = None
        elif cost_model is True:
            cost_model = ExecuteCostModel()
        self.cost = cost_model
        self.registry = ModelRegistry()
        self.admission = AdmissionController(
            max_pending,
            clock=clock,
            drain_estimator=self._drain_estimate if self.cost is not None else None,
        )
        self.scheduler = BatchScheduler(
            clock=clock, max_wait_ms=max_wait_ms, cost_model=self.cost
        )
        self._clock = clock
        self._seq_lock = threading.Lock()
        self._seq = 0
        self.sketches: Dict[Tuple[str, str], LatencySketch] = {}
        self._stats_lock = threading.Lock()
        self.stats = {
            "completed": 0,
            "shed_queued": 0,
            "shed_infeasible": 0,
            "failed": 0,
            "batches": 0,
            "rows": 0,
            "padded_rows": 0,
        }
        # shed-spike flight trigger: sheds within the current 1 s window
        # (guarded by _stats_lock); past the threshold the flight recorder
        # freezes the ring — overload post-mortems need the lead-up, not
        # the steady state a later poll would show
        self._shed_spike = int(envknobs.env_int("REPRO_OBS_SHED_SPIKE", 32))
        self._shed_win = [0.0, 0]  # window start, sheds in window
        # the gateway's operational snapshot re-registers into the one
        # top-level obs.snapshot() (weakly: a dropped gateway disappears;
        # a second gateway under the same name replaces this one)
        obs_metrics.get_registry().register_source("gateway", self.snapshot)
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(max(int(workers), 1))
        ]
        for t in self._threads:
            t.start()

    # -- registration ------------------------------------------------------

    def register(self, name: str, model, example: Dict[str, Any], **kw) -> ModelEntry:
        """Register a servable (FusedModel / PreprocessModel / callable)
        under ``name``; see :meth:`ModelRegistry.register`."""
        # sketches first: the model becomes submittable the moment the
        # registry holds it, and a worker may execute (and record) a batch
        # before this method returns
        for stage in _STAGES:
            self.sketches.setdefault((name, stage), LatencySketch())
        entry = self.registry.register(name, model, example=example, **kw)
        self.scheduler.set_limit(name, entry.max_batch, buckets=entry.buckets)
        return entry

    def warmup(self) -> Dict[str, int]:
        """AOT-precompile every (model, bucket) shape (see registry); with a
        cost model attached, a second timed probe per bucket seeds its
        execute-time estimates before any traffic arrives."""
        observe = None
        if self.cost is not None:
            observe = lambda name, bucket, dt: self.cost.observe(  # noqa: E731
                name, bucket, dt, source="warmup"
            )
        return self.registry.warmup(observe=observe, clock=self._clock)

    def _drain_estimate(self, model: Optional[str], priority: int, deadline) -> float:
        """Seconds of already-queued work ahead of a new request for
        ``model``: full batches of MORE-URGENT queued requests x estimated
        execute per batch, divided over the workers.  Deliberately an
        UNDER-estimate (partial batches count zero, in-flight batches and
        less-urgent queued work are ignored) — over-estimating drain would
        shed servable requests at the door, and formation is urgency-
        ordered, so a high-priority or tight-deadline request jumps ahead
        of queue depth it will never wait behind."""
        if self.cost is None or model is None:
            return 0.0
        try:
            entry = self.registry.get(model)
        except UnknownModelError:
            return 0.0
        ahead = self.scheduler.depth_ahead(model, priority, deadline)
        batches_ahead = ahead // max(entry.max_batch, 1)
        if batches_ahead == 0:
            return 0.0
        est = self.cost.estimate(model, entry.buckets[-1])
        if est is None:
            return 0.0
        return batches_ahead * est / len(self._threads)

    # -- client side -------------------------------------------------------

    def submit_async(
        self,
        model: str,
        features: Dict[str, Any],
        priority: int = 0,
        deadline_ms: Optional[float] = None,
    ) -> Request:
        """Admit and enqueue one request; returns the pending Request (wait
        on ``.event``, then read ``.result`` / ``.error``).  Raises
        UnknownModelError / QueueFullError / DeadlineExceededError /
        GatewayClosedError synchronously at the door."""
        self.registry.get(model)  # unknown model: reject before admission
        now = self._clock()
        deadline = now + deadline_ms / 1e3 if deadline_ms is not None else None
        # one trace per request, rooted here; the head-sampling decision is
        # made once at this root and inherited by every child span
        root = obs_trace.get_recorder().root_span(
            "request", component="gw", t_start=now,
            attrs={"model": model, "priority": int(priority)},
        )
        try:
            with obs_trace.get_recorder().span("admission", component="gw", parent=root):
                self.admission.admit(deadline, model=model, priority=int(priority))
        except BaseException as e:
            root.end(error=f"{type(e).__name__}: {e}")
            raise
        try:
            feats = {k: np.asarray(v) for k, v in features.items()}
            with self._seq_lock:
                self._seq += 1
                seq = self._seq
            req = Request(
                model, feats, int(priority), deadline, now, seq,
                obs_span=root if root.sampled else None,
            )
            self.scheduler.put(req)
        except BaseException as e:
            self.admission.release()
            root.end(error=f"{type(e).__name__}: {e}")
            raise
        return req

    def submit(
        self,
        model: str,
        features: Dict[str, Any],
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        timeout: float = 30.0,
    ):
        """Blocking request/reply through the gateway."""
        req = self.submit_async(model, features, priority, deadline_ms)
        if not req.event.wait(timeout):
            raise TimeoutError(f"no reply from model {model!r} in {timeout}s")
        if req.error is not None:
            raise req.error
        return req.result

    # -- server side -------------------------------------------------------

    def _worker(self) -> None:
        while not self._stop:
            item = self.scheduler.next_batch(timeout=0.05)
            if item is None:
                continue
            key, batch, shed = item
            try:
                for r, err in shed:
                    self._finish_error(
                        r,
                        err,
                        counter=(
                            "shed_infeasible"
                            if isinstance(err, InfeasibleDeadlineError)
                            else "shed_queued"
                        ),
                    )
                if batch:
                    entry = self.registry.get(key[0])
                    now = self._clock()
                    qsk = self.sketches[(entry.name, "queue")]
                    rec = obs_trace.get_recorder()
                    for r in batch:
                        qsk.record(now - r.t_submit)
                        if r.obs_span is not None:
                            # queue wait as a span: submit -> formation
                            rec.span(
                                "queue", component="gw", parent=r.obs_span,
                                t_start=r.t_submit,
                            ).end(t=now)
                    self._run_batch(entry, batch)
            except BaseException as e:  # the worker must outlive any batch:
                # a popped request that never reaches event.set() would leave
                # its client blocked until timeout and leak its admission slot
                for r in batch:
                    if not r.event.is_set():
                        self._finish_error(r, e, counter="failed")

    def _finish_error(self, req: Request, err: BaseException, counter: str) -> None:
        req.error = err
        if req.obs_span is not None:
            req.obs_span.end(error=f"{type(err).__name__}: {err}")
        req.event.set()
        self.admission.release()
        spike = False
        with self._stats_lock:
            self.stats[counter] += 1
            if counter.startswith("shed") and self._shed_spike > 0:
                now = self._clock()
                if now - self._shed_win[0] > 1.0:
                    self._shed_win[0] = now
                    self._shed_win[1] = 0
                self._shed_win[1] += 1
                if self._shed_win[1] >= self._shed_spike:
                    self._shed_win[1] = 0  # re-arm; flight cooldown also guards
                    spike = True
        if spike:
            # outside _stats_lock: the flight dump snapshots the metrics
            # registry, which calls back into this gateway's snapshot()
            obs_flight.get_flight().trigger(
                "shed_spike",
                component="gw",
                attrs={"model": req.model, "threshold": self._shed_spike},
            )

    def _run_batch(self, entry: ModelEntry, reqs: List[Request], retry: bool = False) -> None:
        try:
            n = len(reqs)
            bs = entry.bucket(n)
            # "execute" covers stack+stage+run+readback: the device-facing
            # cost of the batch, as a request experiences it.  The span is
            # parented to the most urgent member's trace and made the
            # thread's current span, so multi-host shard/hedge/reshard spans
            # nest under it
            xsp = obs_trace.get_recorder().span(
                "execute_retry" if retry else "execute",
                component="gw",
                parent=reqs[0].obs_span,
                attrs={"model": entry.name, "rows": n, "bucket": bs},
            )
            with xsp:
                t0 = self._clock()
                results = run_padded_batch(
                    [r.features for r in reqs],
                    bs,
                    entry.fn,
                    entry.sharding,
                    stage=entry.stage_inputs,
                )
                t1 = self._clock()
                # end at t1 so the request root (also ended at t1) strictly
                # contains it; the with-block exit is then a no-op on
                # success but still error-stamps the span on a raise
                xsp.end(t=t1)
            # retried / hedged / resharded executes are tagged apart and kept
            # out of the cost model: failure-path durations must not distort
            # the healthy execute record the gateway schedules by
            take = getattr(entry.fn, "take_batch_events", None)
            events = take() if take is not None else None
            stage = "execute"
            if retry:
                stage = "execute_retry"
            elif events:
                if events.get("resharded"):
                    stage = "execute_reshard"
                elif events.get("hedged"):
                    stage = "execute_hedge"
            self.sketches[(entry.name, stage)].record(t1 - t0)
            if stage == "execute" and self.cost is not None:
                self.cost.observe(entry.name, bs, t1 - t0)
            e2e = self.sketches[(entry.name, "e2e")]
            for r, result in zip(reqs, results):
                r.result = result
                e2e.record(t1 - r.t_submit)
                if r.obs_span is not None:
                    # the request's trace ends when its answer exists; t1 so
                    # the root's duration matches the e2e sketch, not the
                    # scatter loop's position within the batch
                    r.obs_span.end(t=t1)
                r.event.set()
                self.admission.release()
            with self._stats_lock:
                self.stats["completed"] += n
                if not retry:
                    self.stats["batches"] += 1
                self.stats["rows"] += n
                self.stats["padded_rows"] += bs - n
        except BaseException as e:
            if len(reqs) == 1:
                # a directly-formed single-request batch still executed once
                # (a solo RERUN is part of its sweep's single batch count)
                if not retry:
                    with self._stats_lock:
                        self.stats["batches"] += 1
                self._finish_error(reqs[0], e, counter="failed")
            else:
                # failure isolation (as in MicroBatcher): one poisoned
                # request must not fail the rest of its batch.  The whole
                # rerun sweep counts as ONE batch, and a request whose
                # deadline expired — or whose remaining budget cannot cover
                # a solo rerun — during the failed attempt is re-shed, not
                # re-executed into a late answer.
                with self._stats_lock:
                    self.stats["batches"] += 1
                solo_bucket = entry.bucket(1)
                for r in reqs:
                    now = self._clock()
                    ok, est_solo = (
                        self.cost.feasible(entry.name, solo_bucket, now, r.deadline)
                        if self.cost is not None
                        else (True, None)
                    )
                    if r.deadline is not None and r.deadline < now:
                        self._finish_error(
                            r,
                            DeadlineExceededError(
                                "deadline expired before retry (shed)"
                            ),
                            counter="shed_queued",
                        )
                    elif not ok:
                        self._finish_error(
                            r,
                            InfeasibleDeadlineError(
                                f"estimated rerun {est_solo * 1e3:.1f}ms exceeds "
                                f"the request's {(r.deadline - now) * 1e3:.1f}ms "
                                "remaining budget (shed before retry)"
                            ),
                            counter="shed_infeasible",
                        )
                    else:
                        self._run_batch(entry, [r], retry=True)

    # -- introspection / lifecycle ----------------------------------------

    def snapshot(self) -> dict:
        """Operational snapshot: counters + per-(model, stage) latency
        quantiles from the merged DDSketches."""
        with self._stats_lock:
            stats = dict(self.stats)
        stats.update(self.admission.stats)
        stats.update(self.scheduler.stats_snapshot())
        stats["pending"] = self.admission.pending
        stats["queue_depth"] = self.scheduler.depth
        models: Dict[str, dict] = {}
        cost_snap = self.cost.snapshot() if self.cost is not None else {}
        for name in self.registry.names():
            entry = self.registry.get(name)
            models[name] = {
                stage: self.sketches[(name, stage)].snapshot_us()
                for stage in _STAGES
            }
            models[name]["trace_count"] = entry.trace_count()
            models[name]["cost"] = cost_snap.get(name, {})
            models[name]["shards"] = entry.shards
            shard_snap = getattr(entry.fn, "shard_snapshot", None)
            if shard_snap is not None:
                # multi-host routing: coordinator-measured per-process
                # round-trip quantiles
                models[name]["shard_us"] = shard_snap()
            ft_snap = getattr(entry.fn, "ft_snapshot", None)
            if ft_snap is not None:
                # fault tolerance: per-worker health states plus
                # hedge/reshard/rejoin counters
                models[name]["ft"] = ft_snap()
        return {"stats": stats, "models": models}

    def close(self, timeout: float = 5.0) -> None:
        """Drain and stop: refuse new work, error out queued requests, join
        the workers.  In-flight batches finish normally."""
        drained = self.scheduler.close()
        self._stop = True
        for t in self._threads:
            t.join(timeout)
        obs_metrics.get_registry().unregister_source("gateway", obj=self)
        for r in drained:
            self._finish_error(
                r, GatewayClosedError("gateway closed before the request ran"),
                counter="failed",
            )

    def __enter__(self) -> "ServingGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
