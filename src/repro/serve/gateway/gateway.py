"""ServingGateway — the online serving front door.

The paper's production result (61% latency / 58% cost) materialises behind a
request-serving chassis, not a benchmark loop.  This is that chassis for the
JAX reproduction: many named fused models behind ONE gateway, with

  client ──► admission (bounded queue, backpressure, door shedding)
                 │
                 ▼
         scheduler groups per (model, row shape); continuous,
         priority/deadline-aware formation, padded to buckets
                 │
                 ▼  (any idle worker)
         stage_batch ► fused executable (mesh-keyed cache) ► scatter replies

Every stage is measured into mergeable DDSketch histograms (queue wait,
execute, end-to-end, per model) and surfaced as quantile snapshots; warmup
AOT-precompiles every (model, bucket) shape so first requests never trace.

Single-model, no-admission serving remains available as
:class:`~repro.serve.batcher.MicroBatcher`; the gateway is the multi-model,
overload-safe tier on top of the same staging + bucketing machinery.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.batcher import run_padded_batch

from .admission import (
    AdmissionController,
    DeadlineExceededError,
    GatewayClosedError,
)
from .registry import ModelEntry, ModelRegistry
from .scheduler import BatchScheduler, Request
from .telemetry import LatencySketch

_STAGES = ("queue", "execute", "e2e")


class ServingGateway:
    """Admission-controlled, continuously-batching, multi-model gateway.

    Args:
      max_pending: bounded-queue admission cap (backpressure beyond it).
      max_wait_ms: batch-formation window (a tighter request deadline cuts
        it short).
      workers: executor threads pulling formed batches.  Batches for
        different models execute concurrently when >1.
      clock: monotonic time source (injectable for tests).
    """

    def __init__(
        self,
        max_pending: int = 256,
        max_wait_ms: float = 2.0,
        workers: int = 2,
        clock=time.perf_counter,
    ):
        self.registry = ModelRegistry()
        self.admission = AdmissionController(max_pending, clock=clock)
        self.scheduler = BatchScheduler(clock=clock, max_wait_ms=max_wait_ms)
        self._clock = clock
        self._seq_lock = threading.Lock()
        self._seq = 0
        self.sketches: Dict[Tuple[str, str], LatencySketch] = {}
        self._stats_lock = threading.Lock()
        self.stats = {
            "completed": 0,
            "shed_queued": 0,
            "failed": 0,
            "batches": 0,
            "rows": 0,
            "padded_rows": 0,
        }
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(max(int(workers), 1))
        ]
        for t in self._threads:
            t.start()

    # -- registration ------------------------------------------------------

    def register(self, name: str, model, example: Dict[str, Any], **kw) -> ModelEntry:
        """Register a servable (FusedModel / PreprocessModel / callable)
        under ``name``; see :meth:`ModelRegistry.register`."""
        # sketches first: the model becomes submittable the moment the
        # registry holds it, and a worker may execute (and record) a batch
        # before this method returns
        for stage in _STAGES:
            self.sketches.setdefault((name, stage), LatencySketch())
        entry = self.registry.register(name, model, example=example, **kw)
        self.scheduler.set_limit(name, entry.max_batch)
        return entry

    def warmup(self) -> Dict[str, int]:
        """AOT-precompile every (model, bucket) shape (see registry)."""
        return self.registry.warmup()

    # -- client side -------------------------------------------------------

    def submit_async(
        self,
        model: str,
        features: Dict[str, Any],
        priority: int = 0,
        deadline_ms: Optional[float] = None,
    ) -> Request:
        """Admit and enqueue one request; returns the pending Request (wait
        on ``.event``, then read ``.result`` / ``.error``).  Raises
        UnknownModelError / QueueFullError / DeadlineExceededError /
        GatewayClosedError synchronously at the door."""
        self.registry.get(model)  # unknown model: reject before admission
        now = self._clock()
        deadline = now + deadline_ms / 1e3 if deadline_ms is not None else None
        self.admission.admit(deadline)
        try:
            feats = {k: np.asarray(v) for k, v in features.items()}
            with self._seq_lock:
                self._seq += 1
                seq = self._seq
            req = Request(model, feats, int(priority), deadline, now, seq)
            self.scheduler.put(req)
        except BaseException:
            self.admission.release()
            raise
        return req

    def submit(
        self,
        model: str,
        features: Dict[str, Any],
        priority: int = 0,
        deadline_ms: Optional[float] = None,
        timeout: float = 30.0,
    ):
        """Blocking request/reply through the gateway."""
        req = self.submit_async(model, features, priority, deadline_ms)
        if not req.event.wait(timeout):
            raise TimeoutError(f"no reply from model {model!r} in {timeout}s")
        if req.error is not None:
            raise req.error
        return req.result

    # -- server side -------------------------------------------------------

    def _worker(self) -> None:
        while not self._stop:
            item = self.scheduler.next_batch(timeout=0.05)
            if item is None:
                continue
            key, batch, shed = item
            try:
                for r in shed:
                    self._finish_error(
                        r,
                        DeadlineExceededError(
                            "deadline expired while queued (shed)"
                        ),
                        counter="shed_queued",
                    )
                if batch:
                    entry = self.registry.get(key[0])
                    now = self._clock()
                    qsk = self.sketches[(entry.name, "queue")]
                    for r in batch:
                        qsk.record(now - r.t_submit)
                    self._run_batch(entry, batch)
            except BaseException as e:  # the worker must outlive any batch:
                # a popped request that never reaches event.set() would leave
                # its client blocked until timeout and leak its admission slot
                for r in batch:
                    if not r.event.is_set():
                        self._finish_error(r, e, counter="failed")

    def _finish_error(self, req: Request, err: BaseException, counter: str) -> None:
        req.error = err
        req.event.set()
        self.admission.release()
        with self._stats_lock:
            self.stats[counter] += 1

    def _run_batch(self, entry: ModelEntry, reqs: List[Request]) -> None:
        try:
            n = len(reqs)
            bs = entry.bucket(n)
            # "execute" covers stack+stage+run+readback: the device-facing
            # cost of the batch, as a request experiences it
            t0 = self._clock()
            results = run_padded_batch(
                [r.features for r in reqs], bs, entry.fn, entry.sharding
            )
            t1 = self._clock()
            self.sketches[(entry.name, "execute")].record(t1 - t0)
            e2e = self.sketches[(entry.name, "e2e")]
            for r, result in zip(reqs, results):
                r.result = result
                e2e.record(t1 - r.t_submit)
                r.event.set()
                self.admission.release()
            with self._stats_lock:
                self.stats["completed"] += n
                self.stats["batches"] += 1
                self.stats["rows"] += n
                self.stats["padded_rows"] += bs - n
        except BaseException as e:
            if len(reqs) == 1:
                self._finish_error(reqs[0], e, counter="failed")
            else:
                # failure isolation (as in MicroBatcher): one poisoned
                # request must not fail the rest of its batch
                for r in reqs:
                    self._run_batch(entry, [r])

    # -- introspection / lifecycle ----------------------------------------

    def snapshot(self) -> dict:
        """Operational snapshot: counters + per-(model, stage) latency
        quantiles from the merged DDSketches."""
        with self._stats_lock:
            stats = dict(self.stats)
        stats.update(self.admission.stats)
        stats["pending"] = self.admission.pending
        stats["queue_depth"] = self.scheduler.depth
        models: Dict[str, dict] = {}
        for name in self.registry.names():
            entry = self.registry.get(name)
            models[name] = {
                stage: self.sketches[(name, stage)].snapshot_us()
                for stage in _STAGES
            }
            models[name]["trace_count"] = entry.trace_count()
        return {"stats": stats, "models": models}

    def close(self, timeout: float = 5.0) -> None:
        """Drain and stop: refuse new work, error out queued requests, join
        the workers.  In-flight batches finish normally."""
        drained = self.scheduler.close()
        self._stop = True
        for t in self._threads:
            t.join(timeout)
        for r in drained:
            self._finish_error(
                r, GatewayClosedError("gateway closed before the request ran"),
                counter="failed",
            )

    def __enter__(self) -> "ServingGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
