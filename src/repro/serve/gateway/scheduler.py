"""Continuous, shape-bucketed, priority/deadline-aware batch formation.

The single-queue MicroBatcher blocks its loop on ONE fifo: while a batch
executes, nothing is formed, and a request for model B waits behind model
A's batch window.  This scheduler decouples formation from execution
(continuous batching): requests accumulate into per-``(model, row-shape)``
groups while executables run, and any idle gateway worker can pull the next
ready batch the moment one exists.

**Readiness.**  A group is ready when it holds a full batch
(``max_batch`` requests), when its oldest request has waited ``max_wait``,
or when its tightest deadline is due — a deadline tighter than the batch
window cuts the window short rather than being shed by it.  Between events
the scheduler sleeps on a condition variable until the soonest of these
times; arrivals re-wake it.

**Ordering.**  Among ready groups, the group holding the most urgent request
wins; within a group, requests launch in urgency order ``(priority desc,
deadline asc, arrival asc)``.  Requests whose deadline has already passed at
formation time are separated out for shedding — they never occupy a slot in
the padded batch.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .admission import GatewayClosedError


class Request:
    """One admitted request riding through the gateway."""

    __slots__ = (
        "model",
        "features",
        "priority",
        "deadline",
        "t_submit",
        "seq",
        "event",
        "result",
        "error",
        "shape_sig",
    )

    def __init__(self, model, features, priority, deadline, t_submit, seq):
        self.model = model
        self.features = features
        self.priority = priority
        self.deadline = deadline  # absolute clock time, or None
        self.t_submit = t_submit
        self.seq = seq
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.shape_sig = shape_signature(features)

    def urgency(self) -> tuple:
        """Sort key: smaller is more urgent."""
        dl = self.deadline if self.deadline is not None else float("inf")
        return (-self.priority, dl, self.seq)


def shape_signature(features) -> tuple:
    """Row shape/dtype identity — requests batch only with matching rows."""
    return tuple(
        (k, tuple(np.shape(v)), str(getattr(v, "dtype", np.asarray(v).dtype)))
        for k, v in sorted(features.items())
    )


class BatchScheduler:
    """Forms batches per (model, row shape) group under one lock.

    ``next_batch`` is safe to call from many worker threads: a group is
    popped while the lock is held, so no batch is handed out twice.
    """

    def __init__(self, clock=time.perf_counter, max_wait_ms: float = 2.0):
        self._cv = threading.Condition()
        self._groups: Dict[Tuple[str, tuple], List[Request]] = {}
        self._limits: Dict[str, int] = {}
        self._clock = clock
        self.max_wait = max_wait_ms / 1e3
        self._closed = False

    def set_limit(self, model: str, max_batch: int) -> None:
        self._limits[model] = int(max_batch)

    def put(self, req: Request) -> None:
        with self._cv:
            if self._closed:
                raise GatewayClosedError("gateway is closed")
            self._groups.setdefault((req.model, req.shape_sig), []).append(req)
            self._cv.notify_all()

    @property
    def depth(self) -> int:
        with self._cv:
            return sum(len(g) for g in self._groups.values())

    # -- formation ---------------------------------------------------------

    def _ready_at(self, key, group, now: float) -> float:
        """Earliest time this group should launch."""
        if len(group) >= self._limits.get(key[0], 32):
            return now  # full batch: ready immediately
        oldest = min(r.t_submit for r in group)
        due = oldest + self.max_wait
        tightest = min(
            (r.deadline for r in group if r.deadline is not None),
            default=None,
        )
        if tightest is not None:
            due = min(due, tightest)  # launch AT the deadline, not past it
        return due

    def _pick_ready(self, now: float):
        best_key, best_urgency = None, None
        for key, group in self._groups.items():
            if self._ready_at(key, group, now) > now:
                continue
            u = min(r.urgency() for r in group)
            if best_urgency is None or u < best_urgency:
                best_key, best_urgency = key, u
        return best_key

    def _next_event(self, now: float) -> Optional[float]:
        times = [self._ready_at(k, g, now) for k, g in self._groups.items()]
        return min(times) if times else None

    def _form(self, key, now: float):
        group = self._groups.pop(key)
        group.sort(key=Request.urgency)
        shed, live = [], []
        for r in group:
            (shed if r.deadline is not None and r.deadline < now else live).append(r)
        limit = self._limits.get(key[0], 32)
        batch, rest = live[:limit], live[limit:]
        if rest:
            self._groups[key] = rest
            self._cv.notify_all()  # another worker may take the remainder
        return key, batch, shed

    def next_batch(self, timeout: float = 0.1):
        """Block up to ``timeout`` for a ready group.

        Returns ``(key, batch, shed)`` — ``batch`` ordered by urgency and
        capped at the model's ``max_batch``, ``shed`` the requests whose
        deadline expired while queued — or None on timeout/close."""
        end = self._clock() + timeout
        with self._cv:
            while True:
                now = self._clock()
                key = self._pick_ready(now)
                if key is not None:
                    return self._form(key, now)
                if self._closed or now >= end:
                    return None
                wake = self._next_event(now)
                until = end if wake is None else min(end, wake)
                self._cv.wait(max(until - now, 1e-4))

    def close(self) -> List[Request]:
        """Refuse new work and hand back everything still queued (the
        gateway errors the drained requests out)."""
        with self._cv:
            self._closed = True
            drained = [r for g in self._groups.values() for r in g]
            self._groups.clear()
            self._cv.notify_all()
        return drained
