"""Continuous, shape-bucketed, priority/deadline-aware batch formation.

The single-queue MicroBatcher blocks its loop on ONE fifo: while a batch
executes, nothing is formed, and a request for model B waits behind model
A's batch window.  This scheduler decouples formation from execution
(continuous batching): requests accumulate into per-``(model, row-shape)``
groups while executables run, and any idle gateway worker can pull the next
ready batch the moment one exists.

**Readiness.**  A group is ready when it holds a full batch
(``max_batch`` requests), when its oldest request has waited ``max_wait``,
or when its tightest deadline is due — a deadline tighter than the batch
window cuts the window short rather than being shed by it.  Between events
the scheduler sleeps on a condition variable until the soonest of these
times; arrivals re-wake it.

**Ordering.**  Among ready groups, the group holding the most urgent request
wins; within a group, requests launch in urgency order ``(priority desc,
deadline asc, arrival asc)``.  Requests whose deadline has already passed at
formation time are separated out for shedding — they never occupy a slot in
the padded batch.

**Finish-time feasibility (cost model).**  With an
:class:`~repro.serve.gateway.costmodel.ExecuteCostModel` attached, the
deadline is a *finish*-time bound, not a launch-time bound:

* a group becomes ready at ``tightest_deadline - est_execute`` rather than
  at the deadline itself, so the batch can still finish in time;
* at formation, a request that could not finish even in the cheapest
  possible launch (``now + est_execute(model, smallest bucket) > deadline``)
  is shed with :class:`InfeasibleDeadlineError` *before* occupying a padded
  slot;
* under overload, if padding the whole live group up to the next bucket
  would blow a member's deadline but a smaller bucket finishes in time, the
  batch is trimmed to the most-urgent prefix that fits the cheaper bucket
  (smaller bucket = earlier finish) and the remainder re-queued for the next
  formation instead of being dragged past its budget.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import trace as obs_trace
from repro.serve.batcher import _bucket

from .admission import (
    DeadlineExceededError,
    GatewayClosedError,
    InfeasibleDeadlineError,
)


class Request:
    """One admitted request riding through the gateway."""

    __slots__ = (
        "model",
        "features",
        "priority",
        "deadline",
        "t_submit",
        "seq",
        "event",
        "result",
        "error",
        "shape_sig",
        "obs_span",
    )

    def __init__(self, model, features, priority, deadline, t_submit, seq,
                 obs_span=None):
        self.model = model
        self.features = features
        self.priority = priority
        self.deadline = deadline  # absolute clock time, or None
        self.t_submit = t_submit
        self.seq = seq
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.shape_sig = shape_signature(features)
        # the request's root trace span (None when the gateway is untraced);
        # children — queue wait, formation, execute, shard dispatch — hang
        # off it, and completion/shedding ends it
        self.obs_span = obs_span

    def urgency(self) -> tuple:
        """Sort key: smaller is more urgent."""
        dl = self.deadline if self.deadline is not None else float("inf")
        return (-self.priority, dl, self.seq)


def shape_signature(features) -> tuple:
    """Row shape/dtype identity — requests batch only with matching rows."""
    return tuple(
        (k, tuple(np.shape(v)), str(getattr(v, "dtype", np.asarray(v).dtype)))
        for k, v in sorted(features.items())
    )


class BatchScheduler:
    """Forms batches per (model, row shape) group under one lock.

    ``next_batch`` is safe to call from many worker threads: a group is
    popped while the lock is held, so no batch is handed out twice.
    """

    def __init__(self, clock=time.perf_counter, max_wait_ms: float = 2.0, cost_model=None):
        self._cv = threading.Condition()
        self._groups: Dict[Tuple[str, tuple], List[Request]] = {}
        self._limits: Dict[str, int] = {}
        self._buckets: Dict[str, Tuple[int, ...]] = {}
        self._clock = clock
        self.max_wait = max_wait_ms / 1e3
        self.cost_model = cost_model
        self._closed = False
        # formation counters, mutated only under _cv: an independent record
        # of what _form decided, cross-checkable against the gateway's
        # per-error counters (snapshot-consistency tests rely on this)
        self._stats = {
            "sched_formed_batches": 0,
            "sched_formed_rows": 0,
            "sched_shed_expired": 0,
            "sched_shed_infeasible": 0,
            "sched_requeued": 0,
        }

    def stats_snapshot(self) -> Dict[str, int]:
        with self._cv:
            return dict(self._stats)

    def set_limit(self, model: str, max_batch: int, buckets=None) -> None:
        self._limits[model] = int(max_batch)
        if buckets:
            self._buckets[model] = tuple(sorted(int(b) for b in buckets))

    def put(self, req: Request) -> None:
        with self._cv:
            if self._closed:
                raise GatewayClosedError("gateway is closed")
            self._groups.setdefault((req.model, req.shape_sig), []).append(req)
            self._cv.notify_all()

    @property
    def depth(self) -> int:
        with self._cv:
            return sum(len(g) for g in self._groups.values())

    def depth_for(self, model: str) -> int:
        """Queued (not yet formed) requests for one model."""
        with self._cv:
            return sum(len(g) for k, g in self._groups.items() if k[0] == model)

    def depth_ahead(self, model: str, priority: int, deadline) -> int:
        """Queued requests for ``model`` that would launch BEFORE a new
        request with this (priority, deadline) — the admission controller's
        drain estimate reads this, not total depth: formation is urgency-
        ordered, so a high-priority or tight-deadline request jumps the
        queue and must not be door-shed as if it waited behind all of it."""
        p_key = -int(priority)
        d_key = deadline if deadline is not None else float("inf")
        with self._cv:
            n = 0
            for k, g in self._groups.items():
                if k[0] != model:
                    continue
                for r in g:
                    rp = -r.priority
                    rd = r.deadline if r.deadline is not None else float("inf")
                    if rp < p_key or (rp == p_key and rd <= d_key):
                        n += 1
            return n

    # -- formation ---------------------------------------------------------

    def _est(self, model: str, n: int) -> Optional[float]:
        """Estimated execute seconds for an ``n``-request batch of ``model``
        (padded to its bucket), or None when no cost model / no data."""
        if self.cost_model is None:
            return None
        bl = self._buckets.get(model)
        return self.cost_model.estimate(model, _bucket(n, bl) if bl else n)

    def _ready_at(self, key, group, now: float) -> float:
        """Earliest time this group should launch."""
        if len(group) >= self._limits.get(key[0], 32):
            return now  # full batch: ready immediately
        oldest = min(r.t_submit for r in group)
        due = oldest + self.max_wait
        tightest = min(
            (r.deadline for r in group if r.deadline is not None),
            default=None,
        )
        if tightest is not None:
            # launch early enough to FINISH by the deadline, not merely to
            # start at it; without an estimate this degrades to launch-at-
            # deadline (the pre-cost-model behaviour)
            est = self._est(key[0], min(len(group), self._limits.get(key[0], 32)))
            due = min(due, tightest - (est or 0.0))
        return due

    def _pick_ready(self, now: float):
        best_key, best_urgency = None, None
        for key, group in self._groups.items():
            if self._ready_at(key, group, now) > now:
                continue
            u = min(r.urgency() for r in group)
            if best_urgency is None or u < best_urgency:
                best_key, best_urgency = key, u
        return best_key

    def _next_event(self, now: float) -> Optional[float]:
        times = [self._ready_at(k, g, now) for k, g in self._groups.items()]
        return min(times) if times else None

    def _form(self, key, now: float):  # analyze: allow(lock-unguarded-mutation) caller holds _cv (the notify_all below would raise otherwise)
        model = key[0]
        group = self._groups.pop(key)
        group.sort(key=Request.urgency)
        shed: List[Tuple[Request, Exception]] = []
        live: List[Request] = []
        for r in group:
            if r.deadline is not None and r.deadline < now:
                shed.append(
                    (r, DeadlineExceededError("deadline expired while queued (shed)"))
                )
            else:
                live.append(r)
        # finish-time feasibility: a request that cannot finish even in the
        # cheapest possible launch (smallest bucket, starting now) is shed
        # BEFORE it occupies a padded slot
        est_min = self._est(model, 1)
        if est_min is not None and est_min > 0 and live:
            still = []
            for r in live:
                if r.deadline is not None and now + est_min > r.deadline:
                    shed.append(
                        (
                            r,
                            InfeasibleDeadlineError(
                                f"estimated execute {est_min * 1e3:.1f}ms exceeds the "
                                f"request's {(r.deadline - now) * 1e3:.1f}ms remaining "
                                "budget (shed at formation)"
                            ),
                        )
                    )
                else:
                    still.append(r)
            live = still
        limit = self._limits.get(model, 32)
        batch, rest = live[:limit], live[limit:]
        bl = self._buckets.get(model)
        if batch and self.cost_model is not None and bl:
            batch, extra = self._feasible_prefix(model, batch, bl, now)
            rest = extra + rest
        if rest:
            self._groups[key] = rest
            self._cv.notify_all()  # another worker may take the remainder
        if batch:
            self._stats["sched_formed_batches"] += 1
            self._stats["sched_formed_rows"] += len(batch)
            root = batch[0].obs_span
            if root is not None:
                # formation span on the most urgent member's trace: when the
                # batch launched relative to its members' waits, and what the
                # formation decided (shed/trim counts)
                obs_trace.get_recorder().span(
                    "sched.form", component="sched", parent=root, t_start=now,
                    attrs={
                        "model": model,
                        "formed": len(batch),
                        "shed": len(shed),
                        "requeued": len(rest),
                    },
                ).end()
        self._stats["sched_requeued"] += len(rest)
        for _, err in shed:
            if isinstance(err, InfeasibleDeadlineError):
                self._stats["sched_shed_infeasible"] += 1
            else:
                self._stats["sched_shed_expired"] += 1
        return key, batch, shed

    def _feasible_prefix(self, model, batch, bl, now):
        """Largest most-urgent prefix of ``batch`` whose covering bucket
        lets every member finish by its deadline.

        Padding always-up is wrong under overload: a group of 5 padded to
        bucket 8 pays est(8) for everyone, while serving the 4 most urgent
        at bucket 4 finishes earlier — so when est(bucket_up) would blow a
        member's deadline, descend to the cheapest covering bucket that does
        not, re-queueing the overflow for the next formation (it is NOT
        shed; its own feasibility is re-judged when its batch forms)."""
        b_up = _bucket(len(batch), bl)
        sizes = [len(batch)] + [b for b in reversed(bl) if b < b_up]
        for s in sizes:
            take = batch[:s]
            est = self.cost_model.estimate(model, _bucket(len(take), bl))
            if est is None or all(
                r.deadline is None or now + est <= r.deadline for r in take
            ):
                return take, batch[len(take):]
        # estimates moved concurrently; serve the most urgent request alone
        # rather than spin (its infeasibility was already re-checked above)
        return batch[:1], batch[1:]

    def next_batch(self, timeout: float = 0.1):
        """Block up to ``timeout`` for a ready group.

        Returns ``(key, batch, shed)`` — ``batch`` ordered by urgency and
        capped at the model's ``max_batch``; ``shed`` is a list of
        ``(request, error)`` pairs: requests whose deadline expired while
        queued (DeadlineExceededError) or that cannot finish in time under
        the cost model (InfeasibleDeadlineError) — or None on timeout/close."""
        end = self._clock() + timeout
        with self._cv:
            while True:
                now = self._clock()
                key = self._pick_ready(now)
                if key is not None:
                    return self._form(key, now)
                if self._closed or now >= end:
                    return None
                wake = self._next_event(now)
                until = end if wake is None else min(end, wake)
                self._cv.wait(max(until - now, 1e-4))

    def close(self) -> List[Request]:
        """Refuse new work and hand back everything still queued (the
        gateway errors the drained requests out)."""
        with self._cv:
            self._closed = True
            drained = [r for g in self._groups.values() for r in g]
            self._groups.clear()
            self._cv.notify_all()
        return drained
