"""Model registry for the serving gateway: many named models, one front door.

Each entry normalises a servable — a :class:`~repro.serve.fused.FusedModel`,
a bare :class:`~repro.core.export.PreprocessModel`, or any ``staged batch ->
outputs`` callable — into the same internal shape: a batch function, a set
of padded batch-size buckets, an optional mesh sharding for staged request
batches, and a compile-count probe.

**Warmup = AOT precompilation.**  The bucket list IS the closed set of batch
shapes the gateway will ever execute (requests are padded up to a bucket),
so ``warmup()`` drives every ``(model, bucket)`` shape through the model
once before traffic arrives — first requests never pay trace/compile cost,
and the probe lets tests assert ZERO new traces after warmup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.export import PreprocessModel
from repro.core.runner import stage_batch
from repro.serve.batcher import _bucket, normalize_buckets
from repro.serve.fused import FusedModel

from .admission import UnknownModelError


@dataclasses.dataclass
class ModelEntry:
    name: str
    fn: Callable  # staged device batch -> outputs (leading axis = batch)
    example: Dict[str, np.ndarray]  # one request row: shapes/dtypes template
    buckets: Tuple[int, ...]
    max_batch: int
    sharding: Any = None
    traces: Optional[Callable[[], int]] = None  # compile-count probe
    warmed: bool = False
    # self-staging servables (multi-host routing) receive padded HOST
    # columns: each process stages its own row block, so the gateway must
    # not device_put the full batch first
    stage_inputs: bool = True
    shards: int = 1  # processes a routed batch spans (1 = this process only)
    servable: Any = None  # the original model object (fused-chain tuning hook)
    tuned: Optional[dict] = None  # tuner stats from the last warmup, if any

    def bucket(self, n: int) -> int:
        return _bucket(n, self.buckets)

    def trace_count(self) -> int:
        return self.traces() if self.traces is not None else -1


def _normalize(name, model, sharding, donate) -> Tuple[Callable, Optional[Callable]]:
    """(batch fn, compile-count probe) for any supported servable.

    ``donate=None`` keeps the model's own default (FusedModel's env-driven
    donation; no donation for a bare PreprocessModel plan)."""
    if getattr(model, "self_staging", False):
        # cross-process servable (gateway.multihost): routes host columns
        # itself and aggregates its own job-wide compile probe
        traces = getattr(model, "trace_count", None)
        return model, traces
    if isinstance(model, FusedModel):
        jfn = model.jit_for(sharding, donate)
        fn = lambda batch: jfn(model.params, batch)  # noqa: E731
        return fn, lambda: model.trace_count
    if isinstance(model, PreprocessModel):
        plan = model.plan()
        fn = plan.jit_for(in_shardings=sharding, donate=bool(donate))
        return fn, lambda: plan.stats["trace_count"]
    if callable(model):
        return model, None
    raise TypeError(f"cannot serve {type(model).__name__} as model {name!r}")


def _schema_gate(name: str, model, example: Dict[str, Any]) -> None:
    """Static skew check at registration: the example row (the gateway's
    shape/dtype template for every warmup and padded batch) must satisfy
    the servable's plan — required columns present, dtype kinds matching
    the fit-time schema.  Raises :class:`repro.analyze.PlanSchemaError`
    instead of letting a mismatched entry fail (or silently corrupt) on
    its first request.  ``REPRO_ANALYZE_GATE=0`` disables."""
    from repro.analyze import plan_check

    if not plan_check.gate_enabled():
        return
    servable = model
    if isinstance(servable, FusedModel):
        plan = getattr(servable, "_plan", None)
    elif isinstance(servable, PreprocessModel):
        plan = servable.plan()
    else:
        plan = getattr(servable, "_plan", None)  # duck-typed servables
    if plan is None or not getattr(plan, "_nodes", None):
        return
    fit_schema = (
        getattr(servable, "input_schema", None)
        or getattr(getattr(servable, "preprocess", None), "input_schema", None)
        or {}
    )
    required = {
        c: fit_schema.get(c) for c in plan_check.plan_required_inputs(plan)
    }
    provided = {
        k: {
            "dtype": str(np.asarray(v).dtype),
            "shape": [int(d) for d in np.asarray(v).shape],  # one row
        }
        for k, v in example.items()
    }
    plan_check.check_schema(
        required, provided, where=f"registry.register({name!r})"
    ).raise_if_errors(f"registry.register({name!r})")


class ModelRegistry:
    def __init__(self):
        self._entries: Dict[str, ModelEntry] = {}

    def register(
        self,
        name: str,
        model,
        example: Dict[str, Any],
        buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
        max_batch: int = 32,
        sharding=None,
        donate: Optional[bool] = None,
    ) -> ModelEntry:
        """Register ``model`` under ``name``.

        ``example`` is ONE request row (features dict) used as the
        shape/dtype template for warmup batches.  With a mesh ``sharding``,
        buckets must be divisible by the number of batch shards (device_put
        splits the leading axis across them).  ``donate=None`` keeps the
        model's own donation default."""
        if name in self._entries:
            raise ValueError(f"model {name!r} already registered")
        bl, max_batch = normalize_buckets(buckets, max_batch)
        shards = int(getattr(model, "num_processes", 1))
        if shards > 1:
            # a bucket with fewer rows than DATA SHARDS leaves trailing
            # shards empty, i.e. zero-row blocks routed over the network;
            # padding a small batch up to >= one row per shard is strictly
            # cheaper than a zero-row round trip (blocks are carved per
            # data shard, so the floor is num_data_shards, not processes)
            floor = max(shards, int(getattr(model, "num_data_shards", shards)))
            bl = tuple(b for b in bl if b >= floor)
            if not bl:
                raise ValueError(
                    f"model {name!r}: no bucket holds >= {floor} rows "
                    f"(one per data shard)"
                )
        _schema_gate(name, model, example)
        fn, traces = _normalize(name, model, sharding, donate)
        hook = getattr(model, "register_example", None)
        if hook is not None:
            # fault-tolerant multi-host servables keep the row template and
            # bucket set: a rejoining worker is warmed with ITS row block of
            # the largest bucket before re-entering rotation
            hook({k: np.asarray(v) for k, v in example.items()}, bl)
        entry = ModelEntry(
            name=name,
            fn=fn,
            example={k: np.asarray(v) for k, v in example.items()},
            buckets=bl,
            max_batch=max_batch,
            sharding=sharding,
            traces=traces,
            stage_inputs=not getattr(model, "self_staging", False),
            shards=shards,
            servable=model,
        )
        self._entries[name] = entry
        return entry

    def get(self, name: str) -> ModelEntry:
        entry = self._entries.get(name)
        if entry is None:
            raise UnknownModelError(
                f"unknown model {name!r} (registered: {sorted(self._entries)})"
            )
        return entry

    def names(self):
        return sorted(self._entries)

    def __iter__(self):
        return iter(self._entries.values())

    def __len__(self):
        return len(self._entries)

    def warmup(self, observe=None, clock=None) -> Dict[str, int]:
        """Precompile every (model, bucket) shape; returns the per-model
        trace counts afterwards — the baseline for the zero-trace probe.

        With ``observe`` (an ``(name, bucket, seconds)`` callback, wired to
        the gateway's cost model), each bucket is driven a SECOND time after
        the compiling call and that steady-state duration — stage, execute,
        readback, exactly what gateway "execute" measures — is reported, so
        execute-time estimates exist before the first real request."""
        import time as _time

        clock = clock or _time.perf_counter
        counts: Dict[str, int] = {}
        for entry in self:
            self._tune_fused(entry)
            for b in entry.buckets:
                batch = {
                    k: np.repeat(v[None], b, axis=0)
                    for k, v in entry.example.items()
                }

                def call():  # self-staging servables stage per process
                    staged = (
                        stage_batch(batch, entry.sharding)
                        if entry.stage_inputs
                        else batch
                    )
                    return entry.fn(staged)

                jax.block_until_ready(call())
                if observe is not None:
                    # second call: compile cost is paid, so this times the
                    # steady-state execute the cost model must predict
                    t0 = clock()
                    jax.device_get(call())
                    observe(entry.name, b, clock() - t0)
            entry.warmed = True
            counts[entry.name] = entry.trace_count()
        return counts

    def _tune_fused(self, entry: ModelEntry) -> None:
        """Autotune fused transform chains BEFORE the AOT precompile sweep:
        the tuned-config store is populated (or hit — zero sweeps when the
        persisted cache already has winners) while the plan runs eagerly, so
        every executable compiled below lowers with its tuned block configs
        already resolved.  No-op for servables without fused chains or when
        the kernel route is off for this backend."""
        plan = None
        if isinstance(entry.servable, FusedModel):
            plan = entry.servable._plan
        elif isinstance(entry.servable, PreprocessModel):
            plan = entry.servable.plan()
        if plan is None or not getattr(plan, "fused_chain_count", 0):
            return
        from repro.kernels.fused_transform import tune

        if not tune.kernel_route():
            return
        b = max(entry.buckets)
        batch = {
            k: np.repeat(v[None], b, axis=0) for k, v in entry.example.items()
        }
        entry.tuned = plan.warm_fused(batch)
