"""Telemetry-driven execute-time cost model for the serving gateway.

The gateway's deadline was a *launch*-time bound: a request launched at
deadline−ε whose batch takes 10 ms still returns far past its deadline,
silently violating the contract the client asked for.  Turning the deadline
into a *finish*-time bound needs an estimate of how long a batch will take
before it runs — per ``(model, bucket)``, because the padded bucket size IS
the executable shape and each shape has its own cost.

:class:`ExecuteCostModel` keeps one DDSketch histogram per (model, bucket),
fed online from the same measured execute durations the gateway already
records (stack+stage+run+readback, exactly what a request experiences) and
seeded by a timed warmup probe so estimates exist before the first real
request.  An estimate is a high quantile of the observed distribution times
a safety factor — quantile, not mean, because shedding decisions care about
the tail a request would actually hit.

Fallback chain when a bucket has too few samples:

1. a LINEAR rows→time fit across this model's observed buckets (least
   squares over the per-bucket quantile estimates, at least two distinct
   buckets required): execute time is dominated by per-row work plus a
   fixed launch cost, so an unseen bucket size starts from an informed
   interpolation/extrapolation instead of a neighbour's number.  Clamped at
   zero; disable with ``REPRO_GW_COST_FIT=0`` / ``fit=False``;
2. the nearest *smaller* bucket with data, else the nearest larger one —
   an under-estimate serves a doomed request (the status-quo failure mode)
   while an over-estimate sheds a servable one (a new, worse failure mode);
3. the configured prior (``REPRO_GW_COST_PRIOR_MS``).  The default prior is
   0 ms — i.e. *never shed on ignorance*: before any measurement the gateway
   behaves exactly like the launch-time-only baseline (the fit never
   invents an estimate for a model with no data at all).  Deployments that
   would rather reject than risk a late answer can raise it.

Estimates (and the fit coefficients) are cached per model and invalidated
by observation count, so the formation/admission hot paths pay a dict
lookup, not a quantile scan or a regression.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core import sketches
from repro.obs.envknobs import env_flag as _env_flag
from repro.obs.envknobs import env_float as _env_float


class _BucketStats:
    __slots__ = ("hist", "count", "cached_at", "est")

    def __init__(self):
        self.hist = sketches.dd_init_np()
        self.count = 0
        self.cached_at = -1  # observation count the cached estimate reflects
        self.est = float("nan")


class ExecuteCostModel:
    """Per-(model, bucket) execute-time estimator.

    Args (each falls back to its env knob, then the documented default):
      quantile: which quantile of observed execute time to estimate with
        (``REPRO_GW_COST_Q``, default 0.9).
      safety: multiplier on the quantile (``REPRO_GW_COST_SAFETY``, 1.0).
      prior_ms: estimate used before any data exists for a model
        (``REPRO_GW_COST_PRIOR_MS``, default 0.0 = assume feasible).
      min_samples: observations a bucket needs before its own histogram is
        trusted over the fallback chain (``REPRO_GW_COST_MIN_SAMPLES``, 1).
      fit: linear rows→time fallback for unseen buckets
        (``REPRO_GW_COST_FIT``, on).
    """

    def __init__(
        self,
        quantile: Optional[float] = None,
        safety: Optional[float] = None,
        prior_ms: Optional[float] = None,
        min_samples: Optional[int] = None,
        fit: Optional[bool] = None,
    ):
        self.quantile = quantile if quantile is not None else _env_float("REPRO_GW_COST_Q", 0.9)
        self.safety = safety if safety is not None else _env_float("REPRO_GW_COST_SAFETY", 1.0)
        pm = prior_ms if prior_ms is not None else _env_float("REPRO_GW_COST_PRIOR_MS", 0.0)
        self.prior_s = pm / 1e3
        self.min_samples = int(
            min_samples if min_samples is not None else _env_float("REPRO_GW_COST_MIN_SAMPLES", 1)
        )
        if fit is None:
            fit = _env_flag("REPRO_GW_COST_FIT", True)
        self.fit = bool(fit)
        self._lock = threading.Lock()
        self._stats: Dict[Tuple[str, int], _BucketStats] = {}
        # model -> (total observation count the fit reflects, slope s/row,
        # intercept s, points fitted); None coefficients = not fittable yet
        self._fits: Dict[str, Tuple[int, Optional[float], Optional[float], int]] = {}
        self.observed = {"live": 0, "warmup": 0}

    # -- feeding -----------------------------------------------------------

    def observe(self, model: str, bucket: int, seconds: float, source: str = "live") -> None:
        """Fold one measured batch execute duration into the model.

        ``source`` is bookkeeping only ("live" | "warmup"); retried executes
        are deliberately NOT fed here (see gateway._run_batch) — a poisoned
        batch's rerun sweep says nothing about healthy execute cost.
        """
        if not (seconds >= 0.0):  # drops NaN and negatives
            return
        with self._lock:
            rec = self._stats.setdefault((model, int(bucket)), _BucketStats())
            sketches.dd_update_np(rec.hist, seconds)
            rec.count += 1
            self.observed[source] = self.observed.get(source, 0) + 1

    # -- querying ----------------------------------------------------------

    def _estimate_locked(self, rec: _BucketStats) -> float:
        if rec.cached_at != rec.count:
            q = sketches.dd_quantile_np(rec.hist, self.quantile)[0]
            rec.est = float(q) * self.safety
            rec.cached_at = rec.count
        return rec.est

    def _nearest_locked(self, model: str, bucket: int) -> Optional[_BucketStats]:
        known = [
            (b, rec)
            for (m, b), rec in self._stats.items()
            if m == model and rec.count >= self.min_samples
        ]
        if not known:
            return None
        smaller = [(b, r) for b, r in known if b <= bucket]
        if smaller:
            return max(smaller)[1]  # nearest smaller: err toward serving
        return min(known)[1]

    def _fit_locked(self, model: str) -> Tuple[Optional[float], Optional[float], int]:
        """(slope s/row, intercept s, points) of the least-squares line
        through this model's per-bucket estimates; (None, None, n) while
        fewer than two distinct buckets have trustworthy data.  Cached and
        invalidated by the model's total observation count."""
        known = [
            (b, rec)
            for (m, b), rec in self._stats.items()
            if m == model and rec.count >= self.min_samples
        ]
        total = sum(rec.count for _, rec in known)
        cached = self._fits.get(model)
        if cached is not None and cached[0] == total:
            return cached[1], cached[2], cached[3]
        slope = intercept = None
        if len(known) >= 2:
            xs = [float(b) for b, _ in known]
            ys = [self._estimate_locked(rec) for _, rec in known]
            n = len(xs)
            mx, my = sum(xs) / n, sum(ys) / n
            den = sum((x - mx) ** 2 for x in xs)
            if den > 0:
                slope = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / den
                intercept = my - slope * mx
        self._fits[model] = (total, slope, intercept, len(known))
        return slope, intercept, len(known)

    def estimate(self, model: str, bucket: int) -> Optional[float]:
        """Estimated execute seconds for one (model, bucket) batch, or None
        when nothing is known and no prior is configured (callers treat None
        as "assume feasible")."""
        with self._lock:
            rec = self._stats.get((model, int(bucket)))
            if rec is not None and rec.count >= self.min_samples:
                return self._estimate_locked(rec)
            if self.fit:
                slope, intercept, _ = self._fit_locked(model)
                if slope is not None:
                    return max(intercept + slope * int(bucket), 0.0)
            rec = self._nearest_locked(model, int(bucket))
            if rec is not None:
                return self._estimate_locked(rec)
        return self.prior_s if self.prior_s > 0 else None

    def feasible(
        self, model: str, bucket: int, now: float, deadline: Optional[float]
    ) -> Tuple[bool, Optional[float]]:
        """Can an execution started ``now`` finish by ``deadline``?  Returns
        ``(verdict, estimate_seconds)``.  No deadline, or no estimate and no
        prior, is feasible — never shed on ignorance.  The gateway's
        failure-path re-admissions (batch-retry sweeps, resharded
        re-executions) route through this so the judgement is the same one
        applied at the door and at formation."""
        est = self.estimate(model, int(bucket))
        if deadline is None or est is None:
            return True, est
        return now + est <= deadline, est

    # -- introspection -----------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, dict]]:
        """``{model: {bucket: {count, est_ms}, "fit": {...}}}`` for
        gateway.snapshot()."""
        with self._lock:
            keys = sorted(self._stats)
        out: Dict[str, Dict[str, dict]] = {}
        for model, bucket in keys:
            est = self.estimate(model, bucket)
            with self._lock:
                rec = self._stats.get((model, bucket))
                count = rec.count if rec is not None else 0
            out.setdefault(model, {})[str(bucket)] = {
                "count": count,
                "est_ms": None if est is None else round(est * 1e3, 3),
            }
        if self.fit:
            for model in out:
                with self._lock:
                    slope, intercept, points = self._fit_locked(model)
                out[model]["fit"] = {
                    "slope_ms_per_row": None if slope is None else round(slope * 1e3, 4),
                    "intercept_ms": None if intercept is None else round(intercept * 1e3, 4),
                    "buckets_fit": points,
                }
        return out
