"""Cross-process batch routing for the serving gateway.

One COORDINATOR process runs the full gateway — admission control, the
continuous batch scheduler, telemetry, the cost model — and routes each
formed batch across the processes of a :class:`~repro.launch.mesh.
ProcessMesh`: every process executes its contiguous row block of the padded
batch on its own devices, and the coordinator reassembles the outputs and
scatters replies.  The cost model keeps its per-(model, bucket) estimates,
fed from the wall time the COORDINATOR measures around the whole
scatter→execute→gather round trip — that is the cost a request actually
experiences, so it is the right number for finish-time feasibility.

Transport is ``multiprocessing.connection`` (length-prefixed pickle over a
socket, authkey-authenticated): the coordinator listens, each worker process
dials in and announces its process id, and the executor then speaks a strict
request/reply protocol per connection.  A connection carries one in-flight
batch at a time (guarded by a per-connection lock); batches for different
models serialise on the wire but their device execution still overlaps with
the coordinator's own shard.

Fidelity note: each worker executes through the SAME servable normalisation
as a single-process gateway (``registry._normalize``), i.e. a FusedModel
worker runs ``FusedModel.jit_for`` — on a real multi-host TPU runtime the
identical code path lowers against the global mesh; on the fake-device CPU
harness it lowers on the worker's local devices, which is exact for the
row-wise programs this repo serves (asserted bit-identical by
``tests/test_multihost.py``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.runner import stage_batch

from .telemetry import LatencySketch


def _concat_outputs(parts: List[Any]):
    """Concatenate per-process output pytrees along the batch axis."""
    parts = [p for p in parts if p is not None]
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *parts)


class WorkerFailedError(RuntimeError):
    """A shard worker reported an exception while executing its block."""


class MultiHostServable:
    """A gateway servable that fans each batch out across processes.

    Registered like any callable model; the registry recognises
    ``self_staging`` and hands it HOST columns (no coordinator-side
    device staging) — each process stages exactly its own rows, which is the
    per-host shard feeding contract of the serve path.
    """

    self_staging = True

    def __init__(self, executor: "MultiHostExecutor", name: str):
        self._ex = executor
        self.name = name

    @property
    def num_processes(self) -> int:
        return self._ex.num_processes

    @property
    def num_data_shards(self) -> int:
        """Row blocks are carved per data shard — the registry floors
        bucket sizes here so no shard's block ever routes empty."""
        return self._ex.pm.num_data_shards

    def __call__(self, host_cols: Dict[str, np.ndarray]):
        return self._ex.execute(self.name, host_cols)

    def trace_count(self) -> int:
        """Job-wide compile probe: coordinator + every worker (the gateway's
        zero-trace-after-warmup assertion covers all processes)."""
        return self._ex.trace_count(self.name)

    def shard_snapshot(self) -> Dict[str, dict]:
        """Per-process round-trip latency quantiles (coordinator-measured)."""
        return self._ex.shard_snapshot(self.name)


class MultiHostExecutor:
    """Coordinator-side router: splits a batch into per-process row blocks,
    executes the local block in-process, the rest over worker connections.

    Args:
      process_mesh: topology (this process must be process 0).
      sharding: optional sharding for the coordinator's local staging.
    """

    def __init__(self, process_mesh, sharding=None):
        if process_mesh.process_id != 0:
            raise ValueError("the gateway coordinator must be process 0")
        self.pm = process_mesh
        self.num_processes = process_mesh.num_processes
        self._local: Dict[str, Tuple[Any, Any]] = {}
        self._sharding = sharding
        self._conns: Dict[int, Any] = {}  # process id -> connection
        self._conn_locks: Dict[int, threading.Lock] = {}
        self._shard_lat: Dict[Tuple[str, int], LatencySketch] = {}
        self._lock = threading.Lock()

    # -- wiring ------------------------------------------------------------

    def add_model(self, name: str, model, donate=None) -> MultiHostServable:
        """Normalise ``model`` (FusedModel / PreprocessModel / callable —
        the registry's own normaliser) as the coordinator-side shard
        executor for ``name``; workers must serve the same name.  Returns
        the servable to ``gateway.register``."""
        from .registry import _normalize

        fn, traces = _normalize(name, model, self._sharding, donate)
        self._local[name] = (fn, traces)
        return MultiHostServable(self, name)

    def servable(self, name: str) -> MultiHostServable:
        if name not in self._local:
            raise KeyError(f"no local shard executor for {name!r}")
        return MultiHostServable(self, name)

    def attach(self, process_id: int, conn) -> None:
        """Adopt an accepted worker connection (see :func:`accept_workers`)."""
        if not 0 < process_id < self.num_processes:
            raise ValueError(f"worker process id {process_id} out of range")
        if process_id in self._conns:
            # a silent overwrite would strand the displaced worker forever
            # and keep `connected` false until timeout — fail with the real
            # misconfiguration instead
            raise ValueError(f"worker process {process_id} already attached")
        self._conns[process_id] = conn
        self._conn_locks[process_id] = threading.Lock()

    @property
    def connected(self) -> bool:
        return len(self._conns) == self.num_processes - 1

    # -- execution ---------------------------------------------------------

    def _process_blocks(self, n: int) -> List[Tuple[int, int]]:
        """Contiguous (start, stop) row block per process for an n-row
        padded batch (shard blocks merged by owning process)."""
        shard_blocks = self.pm.shard_row_blocks(n)
        out: List[Tuple[int, int]] = []
        for p in range(self.num_processes):
            mine = [
                shard_blocks[i]
                for i, owner in enumerate(self.pm.shard_process)
                if owner == p
            ]
            out.append((mine[0][0], mine[-1][1]))
        return out

    def execute(self, name: str, host_cols: Dict[str, np.ndarray]):
        """One routed batch: scatter row blocks, run the local shard while
        workers run theirs, gather and reassemble in process order."""
        if not self.connected:
            raise RuntimeError(
                f"executor has {len(self._conns)}/{self.num_processes - 1} workers"
            )
        n = int(next(iter(host_cols.values())).shape[0])
        blocks = self._process_blocks(n)
        t_send = {}
        # every acquired per-connection lock is released in the one finally
        # below: a failure anywhere (send to a dead worker, the local shard
        # raising, a broken recv) must not leave a lock held — that would
        # deadlock every later batch on that connection forever.  A request
        # that was SENT but whose reply was not consumed is drained first:
        # a stale reply left in the pipe would answer the NEXT batch.
        acquired: List[int] = []
        sent: set = set()
        replied: set = set()
        try:
            for p, (s, e) in enumerate(blocks):
                if p == 0:
                    continue
                block = {k: v[s:e] for k, v in host_cols.items()}
                self._conn_locks[p].acquire()
                acquired.append(p)
                t_send[p] = time.perf_counter()
                self._conns[p].send(("execute", name, block))
                sent.add(p)
            # the coordinator's own shard overlaps with the workers'
            s0, e0 = blocks[0]
            fn, _ = self._local[name]
            local_out = jax.device_get(
                fn(stage_batch({k: v[s0:e0] for k, v in host_cols.items()}, self._sharding))
            )
            parts = [local_out]
            err: Optional[BaseException] = None
            for p in range(1, self.num_processes):
                status, payload = self._conns[p].recv()
                replied.add(p)
                self._shard_sketch(name, p).record(time.perf_counter() - t_send[p])
                if status != "ok":
                    err = err or WorkerFailedError(
                        f"worker process {p} failed on model {name!r}: {payload}"
                    )
                    parts.append(None)
                else:
                    parts.append(payload)
        finally:
            for p in acquired:
                if p in sent and p not in replied:
                    try:
                        self._conns[p].recv()
                    except (EOFError, OSError):
                        pass  # worker gone: the connection is dead anyway
                self._conn_locks[p].release()
        if err is not None:
            raise err
        return _concat_outputs(parts)

    # -- introspection -----------------------------------------------------

    def _shard_sketch(self, name: str, p: int) -> LatencySketch:
        key = (name, p)
        sk = self._shard_lat.get(key)
        if sk is None:
            with self._lock:
                sk = self._shard_lat.setdefault(key, LatencySketch())
        return sk

    def shard_snapshot(self, name: str) -> Dict[str, dict]:
        return {
            f"process{p}": sk.snapshot_us()
            for (n, p), sk in sorted(self._shard_lat.items())
            if n == name
        }

    def trace_count(self, name: str) -> int:
        _, traces = self._local[name]
        total = traces() if traces is not None else 0
        for p in sorted(self._conns):
            with self._conn_locks[p]:
                self._conns[p].send(("traces", name))
                status, payload = self._conns[p].recv()
            if status == "ok" and payload >= 0:
                total += payload
        return total

    def close(self) -> None:
        """Tell every worker to exit its serve loop and drop connections."""
        for p, conn in sorted(self._conns.items()):
            try:
                with self._conn_locks[p]:
                    conn.send(("close",))
                    conn.close()
            except (OSError, EOFError, BrokenPipeError):
                pass
        self._conns.clear()


def accept_workers(listener, executor: MultiHostExecutor, timeout_s: float = 60.0):
    """Accept worker dial-ins on ``listener`` (a ``multiprocessing.
    connection.Listener``) until the executor has every process attached.
    Each worker announces ``("hello", process_id)`` on connect.

    The deadline bounds the whole wait, including the blocking accept: a
    worker that never dials in (crashed during startup) raises TimeoutError
    instead of hanging the coordinator, and a connection that never
    completes its hello (stray client, worker killed mid-handshake) is
    dropped rather than wedging the loop."""
    import multiprocessing.connection as mpc
    import select

    deadline = time.monotonic() + timeout_s
    # the stdlib socket Listener exposes its socket; without one (e.g. a
    # test double) fall back to blocking accepts with between-accept checks
    sock = getattr(getattr(listener, "_listener", None), "_socket", None)
    while not executor.connected:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(
                f"workers missing: have {len(executor._conns)} of "
                f"{executor.num_processes - 1}"
            )
        if sock is not None:
            ready, _, _ = select.select([sock], [], [], min(remaining, 1.0))
            if not ready:
                continue
        try:
            conn = listener.accept()
        except (mpc.AuthenticationError, EOFError, OSError):
            continue  # stray/dead client: keep waiting for real workers
        if not conn.poll(max(deadline - time.monotonic(), 0.1)):
            conn.close()  # connected but silent: never sent its hello
            continue
        try:
            tag, pid = conn.recv()
        except (EOFError, OSError):
            conn.close()
            continue
        if tag != "hello":
            conn.close()
            raise RuntimeError(f"unexpected first message {tag!r} from a worker")
        executor.attach(int(pid), conn)
    return executor


class ShardServer:
    """Worker-process side: executes this process's row block of every
    routed batch.

    Models are normalised through the registry's ``_normalize`` — the very
    code path a single-process gateway serves through — so a FusedModel
    worker executes via ``jit_for`` with its compile probe intact.

    Args:
      process_mesh: this worker's topology (process id >= 1).
      models: ``{name: model}`` — FusedModel / PreprocessModel / callable,
        under the same names the coordinator registers.
      sharding: optional staging sharding for the worker's block.
    """

    def __init__(self, process_mesh, models: Dict[str, Any], sharding=None):
        from .registry import _normalize

        if process_mesh.process_id == 0:
            raise ValueError("process 0 is the coordinator, not a shard worker")
        self.pm = process_mesh
        self._sharding = sharding
        self._fns: Dict[str, Tuple[Any, Any]] = {}
        for name, model in models.items():
            fn, traces = _normalize(name, model, sharding, donate=None)
            self._fns[name] = (fn, traces)

    def connect_and_serve(self, address, authkey: bytes, timeout_s: float = 60.0) -> int:
        """Dial the coordinator (retrying until its listener is up — workers
        routinely boot faster than a coordinator that compiles models
        first), announce this process, serve until told to close.  Returns
        the number of batches executed."""
        import time as _time
        from multiprocessing.connection import Client

        deadline = _time.monotonic() + timeout_s
        while True:
            try:
                conn = Client(address, authkey=authkey)
                break
            except (ConnectionRefusedError, FileNotFoundError, OSError):
                if _time.monotonic() > deadline:
                    raise
                _time.sleep(0.05)
        conn.send(("hello", self.pm.process_id))
        try:
            return self.serve(conn)
        finally:
            conn.close()

    def serve(self, conn) -> int:
        batches = 0
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return batches
            if msg[0] == "close":
                return batches
            if msg[0] == "traces":
                _, traces = self._fns.get(msg[1], (None, None))
                conn.send(("ok", traces() if traces is not None else -1))
                continue
            if msg[0] != "execute":
                conn.send(("error", f"unknown message {msg[0]!r}"))
                continue
            _, name, block = msg
            try:
                fn, _ = self._fns[name]
                out = jax.device_get(fn(stage_batch(block, self._sharding)))
                conn.send(("ok", out))
                batches += 1
            except BaseException as e:  # the reply slot must always be filled
                conn.send(("error", f"{type(e).__name__}: {e}"))
