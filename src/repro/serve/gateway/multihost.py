"""Cross-process batch routing for the serving gateway, fault-tolerant.

One COORDINATOR process runs the full gateway — admission control, the
continuous batch scheduler, telemetry, the cost model — and routes each
formed batch across the processes of a :class:`~repro.launch.mesh.
ProcessMesh`: every process executes its contiguous row block of the padded
batch on its own devices, and the coordinator reassembles the outputs and
scatters replies.  The cost model keeps its per-(model, bucket) estimates,
fed from the wall time the COORDINATOR measures around the whole
scatter→execute→gather round trip — that is the cost a request actually
experiences, so it is the right number for finish-time feasibility.

Transport is ``multiprocessing.connection`` (length-prefixed pickle over a
socket, authkey-authenticated): the coordinator listens, each worker process
dials in and announces its process id, and the executor then speaks a strict
request/reply protocol per connection.  A connection carries one in-flight
batch at a time (guarded by a per-connection lock); batches for different
models serialise on the wire but their device execution still overlaps with
the coordinator's own shard.

**Fault tolerance.**  The executor wires the seed's ``repro.ft`` substrate
into this tier, so the SAME preprocessing artifact keeps answering — with
bit-identical features — while workers die, stall and come back:

* *Health* — every shard reply (and every answered idle ping) beats a
  per-worker :class:`~repro.ft.Liveness` tracker (the socket-tier analogue
  of the supervisor's file heartbeats); a background sweep pings workers
  that have been silent past ``REPRO_FT_HEARTBEAT_S`` and walks them
  ``healthy → suspect → dead`` on staleness.  A ping (or trace probe)
  whose reply misses its poll window on a still-live socket is recorded as
  an outstanding reply and drained before the connection carries another
  batch — the strict request/reply protocol means an untracked late pong
  would be consumed as the NEXT batch's reply and desync every reply after
  it.
* *Hedged dispatch* — per-shard round-trip times feed a
  :class:`~repro.ft.StragglerMonitor`; once a worker is flagged, the
  coordinator races each of its row blocks with a local re-execution
  (first answer wins; the duplicate is discarded deterministically — the
  original wins ties — and drained off the socket before its next use).
* *Degraded-mesh resharding* — on worker death the row-block table is
  rebuilt over the survivors via :meth:`ProcessMesh.degraded` (orphan
  shards fall to the nearest preceding survivor, the coordinator as the
  fallback), the dead worker's block of any in-flight batch is re-executed
  locally instead of failing the batch, and the gateway re-admits retried
  requests against their remaining deadline budget through
  :meth:`ExecuteCostModel.feasible`.  ``REPRO_FT_MAX_RESHARDS`` bounds how
  much death the mesh absorbs before batches fail loudly.
* *Rejoin* — :func:`accept_workers` keeps a live accept loop: a
  supervisor-restarted ShardServer dials back in, re-answers the trace
  probe, is warmed with its row block of the registered example, and only
  then re-enters rotation (the straggler statistics of its previous life
  are forgotten — a restart is a new population).

A batch that experienced a hedge or a reshard is flagged to the gateway
(:meth:`MultiHostServable.take_batch_events`), which records its duration
into the separate ``execute_hedge`` / ``execute_reshard`` telemetry stages
and keeps it out of the cost model — failure-path timings must never
pollute the estimates healthy batches are scheduled by.

Fidelity note: each worker executes through the SAME servable normalisation
as a single-process gateway (``registry._normalize``), i.e. a FusedModel
worker runs ``FusedModel.jit_for`` — on a real multi-host TPU runtime the
identical code path lowers against the global mesh; on the fake-device CPU
harness it lowers on the worker's local devices, which is exact for the
row-wise programs this repo serves (asserted bit-identical by
``tests/test_multihost.py`` and, under fault schedules, ``tests/
test_chaos.py``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.runner import stage_batch
from repro.ft import DeathReclaimer, Liveness, StragglerMonitor
from repro.obs import flight as obs_flight
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.envknobs import env_flag as _env_flag, env_float as _env_float
from repro.transport import (
    PickleTransport,
    SharedMemoryTransport,
    WireSpans,
    ascontiguous,
    transport_kind,
)

from .telemetry import CounterSet, LatencySketch


def _ft_debug(msg: str) -> None:
    """Fault-path tracing (``REPRO_FT_DEBUG=1``): failure handling here is
    deliberately silent toward clients, so debugging a schedule that did NOT
    recover needs a side channel — structured obs.log lines (level +
    component + monotonic timestamp, one atomic line per record) instead of
    bare prints that interleave mid-line from N subprocesses."""
    obs_log.debug("ft", msg)


# the span-piggyback reply wrapper moved into the transport layer (it is
# wire format, not routing); the old name stays importable — workers pickle
# instances across the socket, so both sides must agree on the class
_WireSpans = WireSpans


def _part_rows(part) -> int:
    """Batch-axis length of one output pytree (its first array leaf)."""
    for leaf in jax.tree.leaves(part):
        return int(np.shape(leaf)[0]) if np.ndim(leaf) else 1
    return 0


def _concat_outputs(parts: List[Any]):
    """Concatenate per-process output pytrees along the batch axis.

    Zero-row parts are elided before concatenating: a degraded mesh with
    fewer rows than shards produces empty row blocks, and while dispatch
    skips them, a defensively-executed empty block (or an all-empty batch)
    must reassemble without np.concatenate ever seeing a 0-row frame —
    empty parts can disagree on dtype promotion and, for object columns,
    crash outright.  When EVERY part is empty the first is returned as the
    canonical empty output (right structure, right dtypes, zero rows)."""
    parts = [p for p in parts if p is not None]
    if len(parts) > 1:
        nonempty = [p for p in parts if _part_rows(p)]
        parts = nonempty or parts[:1]
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *parts)


class WorkerFailedError(RuntimeError):
    """A shard worker reported an exception while executing its block, or
    the mesh has degraded past ``REPRO_FT_MAX_RESHARDS``."""


class _Worker:
    """Coordinator-side state of one shard worker connection."""

    __slots__ = (
        "conn", "lock", "liveness", "alive", "batches", "pending",
        "clock_offset", "transport",
    )

    def __init__(self, conn, liveness: Liveness):
        self.conn = conn
        self.lock = threading.Lock()
        self.liveness = liveness
        self.alive = True
        self.batches = 0
        # coordinator_clock - worker_clock, estimated at attach/rejoin from a
        # clock probe (RTT-midpoint): worker span timestamps are shifted by
        # this before ingestion so a stitched trace has one time base
        self.clock_offset = 0.0
        # data-plane codec for this pair; starts on the always-correct
        # pickle path and is upgraded per worker by shm negotiation
        self.transport = PickleTransport()
        # (t_send, model_or_None, slot_token) of requests SENT whose replies
        # were not consumed — a hedge won the race, or a ping/trace probe
        # missed its poll window (name None); strict request/reply order
        # means they are drained FIFO before the connection carries anything
        # else, or the next execute's recv would consume a stale reply as
        # its own.  The token is the request's shm slot (None on the inline
        # paths): released when its reply is consumed OR drained, so a won
        # hedge can never leak ring capacity
        self.pending: List[Tuple[float, Optional[str], Optional[int]]] = []


class MultiHostServable:
    """A gateway servable that fans each batch out across processes.

    Registered like any callable model; the registry recognises
    ``self_staging`` and hands it HOST columns (no coordinator-side
    device staging) — each process stages exactly its own rows, which is the
    per-host shard feeding contract of the serve path.
    """

    self_staging = True

    def __init__(self, executor: "MultiHostExecutor", name: str):
        self._ex = executor
        self.name = name

    @property
    def num_processes(self) -> int:
        return self._ex.num_processes

    @property
    def num_data_shards(self) -> int:
        """Row blocks are carved per data shard — the registry floors
        bucket sizes here so no shard's block ever routes empty."""
        return self._ex.pm.num_data_shards

    def __call__(self, host_cols: Dict[str, np.ndarray]):
        return self._ex.execute(self.name, host_cols)

    def register_example(self, example: Dict[str, np.ndarray], buckets) -> None:
        """Registry hook: remember the request-row template and bucket set,
        so a rejoining worker can be warmed with ITS row block of the
        largest bucket before re-entering rotation."""
        self._ex.set_example(self.name, example, buckets)

    def take_batch_events(self) -> Optional[dict]:
        """Pop this thread's last-batch fault events (``hedged`` /
        ``resharded`` counts) — the gateway tags the batch's telemetry
        stage with these and keeps failure-path timings out of the cost
        model."""
        return self._ex.take_batch_events()

    def trace_count(self) -> int:
        """Job-wide compile probe: coordinator + every live worker (the
        gateway's zero-trace-after-warmup assertion covers all processes)."""
        return self._ex.trace_count(self.name)

    def shard_snapshot(self) -> Dict[str, dict]:
        """Per-process round-trip latency quantiles (coordinator-measured)."""
        return self._ex.shard_snapshot(self.name)

    def ft_snapshot(self) -> dict:
        """Per-worker health plus hedge/reshard/rejoin counters."""
        return self._ex.ft_snapshot()


class MultiHostExecutor:
    """Coordinator-side router: splits a batch into per-process row blocks,
    executes the local block in-process, the rest over worker connections;
    absorbs worker loss, stalls and rejoins (see module docstring).

    Args:
      process_mesh: topology (this process must be process 0).
      sharding: optional sharding for the coordinator's local staging.
      hedge: race flagged stragglers' blocks with a local re-execute
        (``REPRO_FT_HEDGE``, default on).
      heartbeat_s: liveness window — suspect after one silent window, dead
        after two (``REPRO_FT_HEARTBEAT_S``, default 5.0).
      max_reshards: worker deaths absorbed before batches fail loudly
        (``REPRO_FT_MAX_RESHARDS``, default = every worker may die and the
        coordinator serves alone).
      monitor: straggler statistics (default: EWMA alpha 0.3, flag at 1.5x
        the warm-fleet median after 3 warm steps).
      clock: time source for liveness/timing bookkeeping (injectable).
      transport: data-plane wire format, ``"pickle"`` or ``"shm"``
        (``REPRO_MH_TRANSPORT``, default pickle).  ``shm`` is negotiated
        per worker at attach/rejoin; a worker that cannot map the segment
        stays on pickle — mixed fleets serve bit-identically.
    """

    def __init__(
        self,
        process_mesh,
        sharding=None,
        hedge: Optional[bool] = None,
        heartbeat_s: Optional[float] = None,
        max_reshards: Optional[int] = None,
        monitor: Optional[StragglerMonitor] = None,
        clock=time.perf_counter,
        transport: Optional[str] = None,
    ):
        if process_mesh.process_id != 0:
            raise ValueError("the gateway coordinator must be process 0")
        self.pm = process_mesh
        self.num_processes = process_mesh.num_processes
        self.hedge = hedge if hedge is not None else _env_flag("REPRO_FT_HEDGE", True)
        self.heartbeat_s = (
            heartbeat_s
            if heartbeat_s is not None
            else _env_float("REPRO_FT_HEARTBEAT_S", 5.0)
        )
        self.max_reshards = int(
            max_reshards
            if max_reshards is not None
            else _env_float("REPRO_FT_MAX_RESHARDS", self.num_processes - 1)
        )
        # generous window for trace/rejoin probes (workers may be compiling)
        self.probe_poll_s = max(self.heartbeat_s, 5.0)
        self.monitor = monitor or StragglerMonitor(
            alpha=0.3, threshold=1.5, warmup_steps=3
        )
        self._clock = clock
        self.transport_kind = transport_kind(transport)
        # death-time transport teardown (slot reclaim + segment unlink) runs
        # through one registry so every death path — ping timeout, send
        # failure, EOF mid-gather, rejoin replacement, close — frees a dead
        # worker's in-flight slots exactly once
        self._reclaimer = DeathReclaimer()
        self._local: Dict[str, Tuple[Any, Any]] = {}
        self._examples: Dict[str, Tuple[Dict[str, np.ndarray], Tuple[int, ...]]] = {}
        # rejoin warm frames, keyed (model, start, stop): the example block
        # and its pickled wire frame are invariant per (model, row block),
        # so re-encoding them on every rejoin was pure waste — invalidated
        # by set_example
        self._warm_blocks: Dict[Tuple[str, int, int], Dict[str, np.ndarray]] = {}
        self._warm_wire: Dict[Tuple[str, int, int], bytes] = {}
        self._sharding = sharding
        self._workers: Dict[int, _Worker] = {}
        self._dead: set = set()
        self._death_reasons: Dict[int, str] = {}  # pid -> last cause of death
        self._degraded_pm = None  # cache, invalidated on membership change
        self._mlock = threading.Lock()  # membership: _workers/_dead/_degraded_pm
        self._shard_lat: Dict[Tuple[str, int], LatencySketch] = {}
        self._lock = threading.Lock()
        self._events = threading.local()
        self._ft = CounterSet()
        self._started = False  # full initial attach done (rejoin vs duplicate)
        self._closed = False
        # ft counters/health re-register into the one obs snapshot (weakly:
        # a collected executor drops out of the poll)
        obs_metrics.get_registry().register_source("multihost.ft", self.ft_snapshot)
        self._sweeper = threading.Thread(
            target=self._sweep_loop, daemon=True, name="mh-ft-sweep"
        )
        self._sweeper.start()

    # -- wiring ------------------------------------------------------------

    def add_model(self, name: str, model, donate=None) -> MultiHostServable:
        """Normalise ``model`` (FusedModel / PreprocessModel / callable —
        the registry's own normaliser) as the coordinator-side shard
        executor for ``name``; workers must serve the same name.  Returns
        the servable to ``gateway.register``."""
        from .registry import _normalize

        fn, traces = _normalize(name, model, self._sharding, donate)
        self._local[name] = (fn, traces)
        return MultiHostServable(self, name)

    def servable(self, name: str) -> MultiHostServable:
        if name not in self._local:
            raise KeyError(f"no local shard executor for {name!r}")
        return MultiHostServable(self, name)

    def set_example(self, name: str, example: Dict[str, Any], buckets) -> None:
        self._examples[name] = (
            {k: np.asarray(v) for k, v in example.items()},
            tuple(int(b) for b in buckets),
        )
        # the cached warm frames were built from the previous example
        for key in [k for k in self._warm_blocks if k[0] == name]:
            del self._warm_blocks[key]
        for key in [k for k in self._warm_wire if k[0] == name]:
            del self._warm_wire[key]

    def attach(self, process_id: int, conn) -> None:
        """Adopt an accepted worker connection.  Before the initial roster is
        complete a duplicate process id is a hard misconfiguration (a silent
        overwrite would strand the displaced worker forever); afterwards a
        second hello for an attached id is a REJOIN — the old connection is
        probed, and a worker that really went away is replaced, warmed and
        returned to rotation."""
        pid = int(process_id)
        if not 0 < pid < self.num_processes:
            raise ValueError(f"worker process id {pid} out of range")
        with self._mlock:
            existing = self._workers.get(pid)
            if existing is None:
                w = _Worker(conn, Liveness(self.heartbeat_s, self._clock))
                self._workers[pid] = w
                if len(self._workers) == self.num_processes - 1:
                    self._started = True
            elif not self._started:
                raise ValueError(f"worker process {pid} already attached")
        if existing is None:
            with w.lock:
                self._probe_clock_locked(w)
                self._negotiate_transport_locked(pid, w)
            return
        self._maybe_rejoin(pid, conn)

    def _negotiate_transport_locked(self, pid: int, w: _Worker) -> None:  # analyze: allow(lock-blocking-call,lock-unguarded-mutation) attach/rejoin negotiation: caller holds w.lock for the whole request/reply pair and the transport swap
        """Upgrade this pair to the shm data plane when configured.  The
        coordinator creates the segment and offers it; a worker that cannot
        map it (cross-machine, exhausted /dev/shm) declines and the pair
        stays on pickle — per-worker, silently, correctly.  Caller holds
        ``w.lock``; the connection must be idle (any outstanding probe reply
        is drained first, or the attach ack would be misread as it)."""
        if self.transport_kind != "shm":
            return
        if w.pending and not self._drain_stale(pid, w):
            _ft_debug(
                f"process {pid}: connection busy at shm negotiation; staying on pickle"
            )
            return
        if not w.alive:
            return
        try:
            t = SharedMemoryTransport.create()
        except (OSError, ValueError) as e:
            _ft_debug(f"shm segment creation failed ({e}); staying on pickle")
            return
        try:
            w.conn.send(("shm_attach", t.handshake()))
            if not w.conn.poll(self.probe_poll_s):
                # a fresh, idle worker that cannot ack a tiny control frame
                # within the probe window is not a worker to route to — and
                # its late ack would desync which transport each side thinks
                # is active, so death (rejoinable) beats limping on
                raise OSError("no shm_attach ack within the probe window")
            status, payload = w.conn.recv()
            w.liveness.beat()
        except (OSError, EOFError, BrokenPipeError, ValueError) as e:
            t.close(unlink=True)
            self._mark_dead(pid, f"shm negotiation failed: {e}")
            return
        if status != "ok":
            t.close(unlink=True)
            _ft_debug(f"process {pid} declined shm ({payload}); staying on pickle")
            return
        w.transport = t
        self._reclaimer.register(pid, self._transport_reaper(t))
        _ft_debug(f"process {pid} attached shm segment {t.name}")

    @staticmethod
    def _transport_reaper(t):
        """Death hook for one worker's shm transport: free its in-flight
        slots (a wedged ring must never block a rejoin) and unlink the
        segment (the dead peer cannot)."""

        def _reap():
            stuck = t.reclaim()
            t.close(unlink=True)
            return stuck

        return _reap

    def _probe_clock_locked(self, w: _Worker) -> None:  # analyze: allow(lock-unguarded-mutation) caller holds w.lock for the whole clock exchange
        """Estimate the worker's monotonic-clock offset (coordinator minus
        worker) from one round trip, taking the RTT midpoint as the exchange
        instant — worker-side span timestamps are shifted by this before
        ingestion, so a stitched trace renders on ONE time base with
        non-negative durations.  Caller holds ``w.lock``.  A reply that
        misses the poll window is tracked as pending (an untracked late
        reply would desync the strict request/reply socket); a worker that
        answers ``("error", ...)`` leaves the offset at 0."""
        try:
            t0 = self._clock()
            w.conn.send(("clock",))
            # short window, like the ping sweep: the worker just said hello
            # so it is serving; on a miss the offset stays 0 (spans merely
            # unaligned) rather than stalling attach for the probe window
            if not w.conn.poll(min(self.heartbeat_s, 1.0)):
                w.pending.append((t0, None, None))
                return
            status, payload = w.conn.recv()
            t1 = self._clock()
        except (OSError, EOFError, BrokenPipeError, ValueError):
            return  # the liveness machinery will judge this socket
        w.liveness.beat()
        if status == "ok":
            w.clock_offset = (t0 + t1) / 2.0 - float(payload)

    def _maybe_rejoin(self, pid: int, conn) -> None:  # analyze: allow(lock-blocking-call) liveness probe of an idle socket; w.lock exists to serialize exactly this request/reply protocol
        w = self._workers[pid]
        if w.alive:
            # the old socket may be silently dead (dropped connection the
            # coordinator has not touched since) — probe it before deciding
            if w.lock.acquire(blocking=False):
                try:
                    if self._drain_stale(pid, w) and w.alive:
                        try:
                            w.conn.send(("ping",))
                            if w.conn.poll(self.heartbeat_s):
                                w.conn.recv()
                                w.liveness.beat()
                            else:
                                self._mark_dead(pid, "silent under rejoin probe")
                        except (OSError, EOFError, BrokenPipeError, ValueError):
                            self._mark_dead(pid, "probe failed")
                finally:
                    w.lock.release()
            if w.alive:
                raise ValueError(
                    f"worker process {pid} already attached and responsive"
                )
        self._rejoin(pid, conn)

    def _rejoin(self, pid: int, conn) -> None:  # analyze: allow(lock-blocking-call,lock-unguarded-mutation) rejoin swap/warm protocol: w.lock is held for the whole exchange, so the transport/pending swaps are serialized
        """Re-adopt a returned worker: swap the connection, re-answer the
        trace probe, warm it with its block of each registered example, and
        only then mark it live (never route to a cold restart)."""
        w = self._workers[pid]
        with w.lock:
            try:
                w.conn.close()
            except (OSError, ValueError):
                pass
            # the previous incarnation's transport is dead with it: free any
            # slots its in-flight frames held and unlink its segment (a
            # rejoin that replaced a silently-dead connection is a death
            # path too — _mark_dead may never have run)
            self._reclaimer.reclaim(pid)
            w.transport = PickleTransport()
            w.conn = conn
            w.pending.clear()
            try:
                for name in sorted(self._local):
                    conn.send(("traces", name))
                    if not conn.poll(self.probe_poll_s):
                        raise OSError("no trace-probe reply from rejoined worker")
                    conn.recv()
                    wire = self._warm_wire_frame(name, pid)
                    if wire is not None:
                        # the pre-pickled frame: warm bytes are invariant
                        # per (model, block), so rejoin N re-sends the bytes
                        # rejoin 1 encoded instead of re-pickling the full
                        # example block every time
                        conn.send_bytes(wire)
                        if not conn.poll(max(4 * self.heartbeat_s, 30.0)):
                            raise OSError("no warmup reply from rejoined worker")
                        status, payload = conn.recv()
                        if status != "ok":
                            raise OSError(f"rejoin warmup failed: {payload}")
            except (OSError, EOFError, BrokenPipeError, ValueError) as e:
                _ft_debug(f"rejoin of process {pid} failed: {type(e).__name__}: {e}")
                try:
                    conn.close()
                except (OSError, ValueError):
                    pass
                return  # stays dead; a later dial-in may try again
            self._probe_clock_locked(w)  # a restarted process is a new clock
            w.alive = True
            w.batches = 0
            w.liveness = Liveness(self.heartbeat_s, self._clock)
            self._negotiate_transport_locked(pid, w)
            if not w.alive:
                return  # negotiation declared it dead; a later dial-in may retry
        with self._mlock:
            self._dead.discard(pid)
            self._death_reasons.pop(pid, None)
            self._degraded_pm = None
        # a restarted worker is a new population: forget the old statistics
        self.monitor.forget(f"process{pid}")
        self._ft.inc("worker_rejoins")

    def _warm_block(self, name: str, pid: int) -> Optional[Dict[str, np.ndarray]]:
        """This worker's row block of the largest registered bucket, built
        from the example row — the shape rotation will actually route to it
        under the healthy mesh.  Cached per (model, block): every rejoin of
        any worker owning the same block reuses one materialisation."""
        ex = self._examples.get(name)
        if ex is None:
            return None
        example, buckets = ex
        blocks = self._blocks_for(self.pm, max(buckets))
        s, e = blocks[pid]
        if e <= s:
            return None
        key = (name, s, e)
        block = self._warm_blocks.get(key)
        if block is None:
            block = self._warm_blocks.setdefault(
                key, {k: np.repeat(v[None], e - s, axis=0) for k, v in example.items()}
            )
        return block

    def _warm_wire_frame(self, name: str, pid: int) -> Optional[bytes]:
        """The PICKLED warm execute frame for this worker's block, cached
        per (model, block) and invalidated by :meth:`set_example` — rejoin
        warms always travel inline (the rejoining pair is on the pickle
        transport until shm is renegotiated afterwards)."""
        block = self._warm_block(name, pid)
        if block is None:
            return None
        blocks = self._blocks_for(self.pm, max(self._examples[name][1]))
        key = (name,) + blocks[pid]
        wire = self._warm_wire.get(key)
        if wire is None:
            from multiprocessing.reduction import ForkingPickler

            wire = self._warm_wire.setdefault(
                key, bytes(ForkingPickler.dumps(("execute", name, block)))
            )
        return wire

    @property
    def connected(self) -> bool:
        return len(self._workers) == self.num_processes - 1

    @property
    def live_workers(self) -> List[int]:
        with self._mlock:
            return sorted(p for p, w in self._workers.items() if w.alive)

    # -- execution ---------------------------------------------------------

    def _current_pm(self):
        """The mesh batches are carved over right now: the full topology, or
        the degraded derivation over survivors after worker death."""
        with self._mlock:
            if not self._dead:
                return self.pm
            if self._degraded_pm is None:
                self._degraded_pm = self.pm.degraded(frozenset(self._dead))
            return self._degraded_pm

    @staticmethod
    def _blocks_for(pm, n: int) -> List[Tuple[int, int]]:
        """Contiguous (start, stop) row block per process for an n-row
        padded batch (shard blocks merged by owning process; dead processes
        own nothing and get an empty block)."""
        shard_blocks = pm.shard_row_blocks(n)
        out: List[Tuple[int, int]] = []
        for p in range(pm.num_processes):
            mine = [
                shard_blocks[i]
                for i, owner in enumerate(pm.shard_process)
                if owner == p
            ]
            out.append((mine[0][0], mine[-1][1]) if mine else (0, 0))
        return out

    def _process_blocks(self, n: int) -> List[Tuple[int, int]]:
        return self._blocks_for(self._current_pm(), n)

    def _run_local(self, name: str, block: Dict[str, np.ndarray], rank=None):
        fn, _ = self._local[name]
        t0 = self._clock()
        out = jax.device_get(fn(stage_batch(block, self._sharding)))
        if rank is not None:
            # the coordinator's own shard time anchors the fleet median the
            # straggler monitor flags against
            self.monitor.report(rank, self._clock() - t0)
        return out

    def execute(self, name: str, host_cols: Dict[str, np.ndarray]):  # analyze: allow(lock-unguarded-mutation) every w.pending touch is under that worker's w.lock; branch-local releases defeat the lint's linear model
        """One routed batch: scatter row blocks, run the local shard while
        workers run theirs, gather and reassemble in row order.  Worker
        loss and stalls are absorbed (hedge / reshard); only worker-REPORTED
        execution errors — a poisoned block fails everywhere — surface as
        :class:`WorkerFailedError`."""
        if not self.connected:
            raise RuntimeError(
                f"executor has {len(self._workers)}/{self.num_processes - 1} workers"
            )
        self._check_reshard_budget()
        ev = {"hedged": 0, "resharded": 0}
        self._events.last = ev
        n = int(next(iter(host_cols.values())).shape[0])
        blocks = self._process_blocks(n)
        # normalise every block to C-contiguous ONCE at slicing time: a row
        # slice of a padded superbatch can be a strided view, which pickle
        # serialises via a gather and the shm writer would have to copy per
        # leaf anyway — both transports now see one layout, and an
        # already-contiguous slice passes through untouched (no copy)
        host_blocks = {
            p: {k: ascontiguous(v[s:e]) for k, v in host_cols.items()}
            for p, (s, e) in enumerate(blocks)
            if e > s
        }
        if not host_blocks:
            # an all-empty batch (zero rows) carves no blocks anywhere:
            # execute the empty frame locally so output structure/dtypes
            # are preserved without touching the wire
            return self._run_local(name, host_cols)
        parts: Dict[int, Any] = {}
        routed: List[int] = []
        absorbed: List[int] = []
        held: List[int] = []
        t_send: Dict[int, float] = {}
        err: Optional[BaseException] = None
        rec = obs_trace.get_recorder()
        shard_spans: Dict[int, Any] = {}
        try:
            for p in sorted(host_blocks):
                if p == 0:
                    continue
                w = self._workers.get(p)
                if w is None or not w.alive:
                    absorbed.append(p)  # died since blocks were carved
                    ev["resharded"] += 1
                    continue
                w.lock.acquire()
                held.append(p)
                if not w.alive:
                    held.remove(p)
                    w.lock.release()
                    absorbed.append(p)
                    ev["resharded"] += 1
                    continue
                if not self._drain_stale(p, w):
                    # a hedged reply is still outstanding (or the drain found
                    # the socket dead): don't queue behind a straggler —
                    # absorb its block locally this batch
                    held.remove(p)
                    w.lock.release()
                    absorbed.append(p)
                    if w.alive:
                        ev["hedged"] += 1
                        self._ft.inc("busy_skips")
                    else:
                        ev["resharded"] += 1
                    continue
                # the per-worker span starts at send and ends when the reply
                # is consumed; its (trace_id, span_id) rides the frame so the
                # worker's own spans stitch under it
                sp = rec.span(
                    "mh.shard",
                    component="mh",
                    attrs={"process": p, "rows": blocks[p][1] - blocks[p][0]},
                )
                try:
                    t_send[p] = self._clock()
                    payload, token = w.transport.encode_request(host_blocks[p])
                    frame = ("execute", name, payload)
                    if sp.sampled:
                        frame = frame + ((sp.trace_id, sp.span_id),)
                    w.conn.send(frame)
                    w.pending.append((t_send[p], name, token))
                    shard_spans[p] = sp
                    routed.append(p)
                except (OSError, BrokenPipeError, ValueError):
                    sp.end(error="send failed")
                    held.remove(p)
                    w.lock.release()
                    self._mark_dead(p, "send failed")
                    absorbed.append(p)
                    ev["resharded"] += 1
            # the coordinator's own shard overlaps with the workers'
            if 0 in host_blocks:
                with rec.span(
                    "mh.local", component="mh",
                    attrs={"rows": blocks[0][1] - blocks[0][0]},
                ):
                    parts[0] = self._run_local(name, host_blocks[0], rank="process0")
            for p in absorbed:
                with rec.span("mh.reshard", component="mh", attrs={"process": p}):
                    parts[p] = self._run_local(name, host_blocks[p])
                self._ft.inc("recovered_blocks")
            for p in routed:
                w = self._workers[p]
                out, werr = self._gather(
                    p, w, name, host_blocks[p], t_send[p], ev,
                    sp=shard_spans.get(p, obs_trace.NULL),
                )
                shard_spans.pop(p, obs_trace.NULL).end(
                    error=str(werr) if werr is not None else None
                )
                parts[p] = out
                err = err or werr
                held.remove(p)
                w.lock.release()
        finally:
            for p in held:
                self._workers[p].lock.release()
            for sp in shard_spans.values():
                sp.end(error="batch aborted" if err is None else str(err))
        if err is not None:
            obs_flight.get_flight().trigger(
                "worker_failed", component="mh",
                attrs={"model": name, "error": str(err)},
            )
            raise err
        if ev["resharded"]:
            with self._mlock:
                dead = sorted(self._dead)
            obs_flight.get_flight().trigger(
                "reshard", component="mh",
                attrs={"model": name, "events": dict(ev), "dead": dead},
            )
        self._check_reshard_budget()
        last_death = self._ft.get("last_death_t", 0.0)
        if last_death and not self._ft.get("kill_recover_ms", 0.0):
            # first completed batch under the degraded mesh: the recovery
            # latency the benchmarks record
            self._ft.set(
                "kill_recover_ms", round((self._clock() - last_death) * 1e3, 3)
            )
        ordered = [parts[p] for p in sorted(parts, key=lambda q: blocks[q][0])]
        return _concat_outputs(ordered)

    def _gather(self, p, w, name, block, t0, ev, sp=obs_trace.NULL):
        """Consume worker ``p``'s reply for the in-flight block — hedging a
        flagged straggler, declaring death on staleness/EOF and recovering
        the block locally.  Returns ``(output_or_None, error_or_None)``.
        ``sp`` is the dispatch span opened at send time: the hedge /
        reshard-recovery spans nest under it."""
        rec = obs_trace.get_recorder()
        rank = f"process{p}"
        flagged = rank in self.monitor.flagged
        try:
            if self.hedge and flagged and not w.conn.poll(0):
                # race: local re-execute vs the straggler's in-flight reply
                self._ft.inc("hedges")
                ev["hedged"] += 1
                with rec.span("mh.hedge", component="mh", parent=sp,
                              attrs={"process": p}):
                    hedge_out = self._run_local(name, block)
                if not w.conn.poll(0):
                    # hedge won; the reply stays outstanding and is drained
                    # before this connection's next use
                    self._ft.inc("hedge_wins")
                    return hedge_out, None
                # both finished: the ORIGINAL wins ties (deterministic
                # discard; outputs are bit-identical either way) and the
                # socket stays clean
                self._ft.inc("hedge_losses")
                out, werr = self._consume_reply(p, w, name, t0)
                # the original beat the hedge: the worker caught up — un-flag
                # it (after the reply's own report, so this batch's verdict
                # stands), or one transient slowdown would duplicate-execute
                # its rows on every later batch; a still-slow worker re-flags
                # on its next report
                self.monitor.clear(rank)
                return out, werr
            # a slow reply is NOT death: first batches compile, stragglers
            # straggle — both are correct, just late (hedging's job, not
            # resharding's).  Death mid-wait surfaces instantly as EOF when
            # the peer closes; this bound only catches a truly hung process
            # that keeps its socket open without ever answering.
            deadline = t0 + max(8 * self.heartbeat_s, 5.0)
            while not w.conn.poll(0.05):
                if self._clock() > deadline:
                    raise OSError(
                        f"no reply within {max(8 * self.heartbeat_s, 5.0):.1f}s"
                    )
            return self._consume_reply(p, w, name, t0)
        except (OSError, EOFError, BrokenPipeError) as e:
            self._mark_dead(p, f"{type(e).__name__}: {e}")
            ev["resharded"] += 1
            self._ft.inc("recovered_blocks")
            with rec.span("mh.reshard", component="mh", parent=sp,
                          attrs={"process": p, "cause": type(e).__name__}):
                out = self._run_local(name, block)
            return out, None

    def _consume_reply(self, p, w, name, t0):  # analyze: allow(lock-unguarded-mutation) caller holds w.lock (dispatch/gather path)
        status, payload = w.conn.recv()
        if w.pending:
            # the reply is consumed: its request slot is provably done (the
            # worker read the request before it could answer) — release it
            w.transport.release(w.pending.pop(0)[2])
        dt = self._clock() - t0
        self._shard_sketch(name, p).record(dt)
        self.monitor.report(f"process{p}", dt)
        w.liveness.beat()
        if status != "ok":
            return None, WorkerFailedError(
                f"worker process {p} failed on model {name!r}: {payload}"
            )
        w.batches += 1
        # decode under w.lock: a shm reply slot may be overwritten once the
        # connection carries the next frame, so the output must own its
        # memory before the lock is released
        out, spans = w.transport.decode_reply(payload)
        if spans:
            # worker-side spans, re-based onto the coordinator's clock by
            # the offset estimated at attach — the stitched half of the tree
            obs_trace.get_recorder().ingest(spans, offset=w.clock_offset)
        return out, None

    def _drain_stale(self, p, w) -> bool:  # analyze: allow(lock-unguarded-mutation) caller holds w.lock (dispatch, sweep and probe paths)
        """Consume replies left over from won hedges and from ping/trace
        probes that missed their poll window (FIFO, timed from their
        original send).  True when the connection is idle and usable."""
        while w.pending:
            try:
                if not w.conn.poll(0):
                    return False
                t0, name, token = w.pending[0]
                status, payload = w.conn.recv()
            except (OSError, EOFError, BrokenPipeError):
                self._mark_dead(p, "connection lost draining stale replies")
                return False
            w.pending.pop(0)
            # a drained reply is never decoded (its slot bytes are never
            # mapped), but its REQUEST slot must go back to the ring or a
            # few won hedges would exhaust it
            w.transport.release(token)
            w.liveness.beat()
            if name is None:
                continue  # late probe reply: consume only, no shard stats
            dt = self._clock() - t0
            self._shard_sketch(name, p).record(dt)
            self.monitor.report(f"process{p}", dt)
        return True

    def _check_reshard_budget(self) -> None:
        """Fail LOUDLY once the mesh has degraded past budget.  Checked on
        every batch, entering AND leaving :meth:`execute` — once the
        degraded mesh is in place, later batches carve around the dead
        workers without recording any reshard event, and the gateway's
        per-request retry re-executes on the degraded mesh; an event-gated
        check would let over-budget serving succeed silently forever."""
        with self._mlock:
            dead = len(self._dead)
        if dead > self.max_reshards:
            obs_flight.get_flight().trigger(
                "reshard_budget_exhausted", component="mh",
                attrs={"dead": dead, "budget": self.max_reshards},
            )
            raise WorkerFailedError(
                f"mesh degraded beyond budget: {dead} dead workers > "
                f"REPRO_FT_MAX_RESHARDS={self.max_reshards}"
            )

    def _mark_dead(self, p: int, why: str = "") -> None:
        with self._mlock:
            w = self._workers.get(p)
            if w is None or not w.alive:
                return
            w.alive = False
            w.pending.clear()
            self._dead.add(p)
            self._death_reasons[p] = why
            self._degraded_pm = None
            conn = w.conn
        # close OUTSIDE the membership lock: close can block on linger/flush,
        # and every membership read (live_workers, snapshots, budget checks)
        # would stall behind a wedged socket teardown
        try:
            conn.close()
        except (OSError, ValueError):
            pass
        # transport teardown rides the same outside-the-lock rule: reclaim
        # frees the dead pair's in-flight slots and unlinks its segment —
        # run once per death, whichever path got here first
        stuck = self._reclaimer.reclaim(p)
        if stuck:
            self._ft.inc("slots_reclaimed", stuck)
        self._ft.inc("worker_deaths")
        self._ft.inc("reshards")
        self._ft.set("last_death_t", self._clock())
        self._ft.set("kill_recover_ms", 0.0)  # re-arm the recovery gauge
        self.monitor.forget(f"process{p}")
        _ft_debug(f"worker process {p} marked dead: {why}")
        obs_trace.get_recorder().event(
            "mh.worker_death", component="mh", parent=None,
            attrs={"process": p, "why": why},
        )
        # dump asynchronously: callers may hold a worker's connection lock,
        # and the flight snapshot polls sources (gateway.snapshot -> trace
        # probes) that contend on those locks — the recovery path must not
        # wait on a post-mortem
        threading.Thread(
            target=obs_flight.get_flight().trigger,
            args=("worker_death", "mh"),
            kwargs={"attrs": {"process": p, "why": why}},
            daemon=True,
            name="obs-flight",
        ).start()

    # -- health sweep ------------------------------------------------------

    def _sweep_loop(self) -> None:
        interval = max(0.05, self.heartbeat_s / 4)
        while not self._closed:
            time.sleep(interval)
            try:
                self._sweep_once()
            except Exception:  # the sweeper must outlive any single fault
                pass

    def _sweep_once(self) -> None:  # analyze: allow(lock-blocking-call) idle-socket ping under a 50ms micro-poll; w.lock serializes the request/reply pair
        for p in self.live_workers:
            w = self._workers.get(p)
            if w is None or not w.alive or w.liveness.age() <= self.heartbeat_s:
                continue
            if not w.lock.acquire(blocking=False):
                continue  # mid-batch: its reply is the heartbeat
            try:
                if not self._drain_stale(p, w):
                    # outstanding hedged reply: judge by staleness alone
                    if w.alive and w.liveness.state() == "dead":
                        self._mark_dead(p, "stale with outstanding reply")
                    continue
                if not w.alive:
                    continue
                self._ft.inc("pings")
                try:
                    t_ping = self._clock()
                    w.conn.send(("ping",))
                    # micro-poll only: this thread holds w.lock, and every
                    # batch dispatched to this worker queues behind it — a
                    # heartbeat-length poll here stalled dispatch for up to
                    # 1s per suspect worker (the sweeper-vs-dispatch bug)
                    if w.conn.poll(0.05):
                        w.conn.recv()
                        w.liveness.beat()
                    else:
                        # the pong may still arrive: it MUST be drained
                        # before this socket carries a batch, or every
                        # later reply on it is off-by-one — track it so
                        # _drain_stale consumes it first (a suspect worker
                        # keeps its socket; _mark_dead clears pending)
                        w.pending.append((t_ping, None, None))
                        if w.liveness.state() == "dead":
                            self._mark_dead(p, "unanswered ping")
                except (OSError, EOFError, BrokenPipeError, ValueError):
                    self._mark_dead(p, "ping failed")
            finally:
                w.lock.release()

    # -- introspection -----------------------------------------------------

    def take_batch_events(self) -> Optional[dict]:
        ev = getattr(self._events, "last", None)
        self._events.last = None
        return ev

    def _shard_sketch(self, name: str, p: int) -> LatencySketch:
        key = (name, p)
        sk = self._shard_lat.get(key)
        if sk is None:
            with self._lock:
                sk = self._shard_lat.setdefault(key, LatencySketch())
        return sk

    def shard_snapshot(self, name: str) -> Dict[str, dict]:
        return {
            f"process{p}": sk.snapshot_us()
            for (n, p), sk in sorted(self._shard_lat.items())
            if n == name
        }

    def ft_snapshot(self) -> dict:
        """Per-worker health states plus the executor's fault counters —
        surfaced by ``gateway.snapshot()`` under ``models[name]["ft"]``."""
        with self._mlock:
            workers = {
                f"process{p}": {
                    "state": w.liveness.state() if w.alive else "dead",
                    "age_ms": round(w.liveness.age() * 1e3, 1),
                    "batches": w.batches,
                    "outstanding": len(w.pending),
                    "transport": w.transport.stats(),
                }
                for p, w in sorted(self._workers.items())
            }
            dead = sorted(self._dead)
            reasons = {f"process{p}": r for p, r in sorted(self._death_reasons.items())}
        out = {
            "workers": workers,
            "dead": dead,
            "death_reasons": reasons,
            "flagged": list(self.monitor.flagged),
            "transport": {
                "configured": self.transport_kind,
                "reclaimer": self._reclaimer.snapshot(),
            },
        }
        out.update(self._ft.snapshot())
        return out

    def trace_count(self, name: str) -> int:  # analyze: allow(lock-blocking-call) introspection probe: w.lock serializes the request/reply pair, bounded by probe_poll_s
        _, traces = self._local[name]
        total = traces() if traces is not None else 0
        for p in self.live_workers:
            w = self._workers[p]
            with w.lock:
                if not w.alive or not self._drain_stale(p, w):
                    continue
                try:
                    t_probe = self._clock()
                    w.conn.send(("traces", name))
                    if not w.conn.poll(self.probe_poll_s):
                        # reply still owed on a live socket: track it so
                        # _drain_stale consumes it before the next batch
                        # (untracked, it would be read as that batch's
                        # reply and desync the connection)
                        w.pending.append((t_probe, None, None))
                        continue
                    status, payload = w.conn.recv()
                except (OSError, EOFError, BrokenPipeError, ValueError):
                    self._mark_dead(p, "trace probe failed")
                    continue
            if status == "ok" and payload >= 0:
                total += payload
        return total

    def close(self, timeout_s: float = 5.0) -> None:  # analyze: allow(lock-blocking-call) orderly shutdown drain: bounded by timeout_s, nothing races a closing coordinator
        """Orderly shutdown: stop the sweep/accept loops, then per worker —
        drain any outstanding hedged replies, send an explicit ``shutdown``
        frame and consume its ack — so a reply in flight is drained, never
        raised into (closing the coordinator mid-reply used to error the
        worker's serve loop instead of draining it)."""
        self._closed = True
        for p, w in sorted(self._workers.items()):
            if not w.alive:
                continue
            got = w.lock.acquire(timeout=timeout_s)
            try:
                deadline = self._clock() + timeout_s
                while w.pending and self._clock() < deadline:
                    try:
                        if w.conn.poll(0.05):
                            w.conn.recv()
                            w.transport.release(w.pending.pop(0)[2])
                    except (OSError, EOFError, BrokenPipeError):
                        w.pending.clear()
                        break
                w.conn.send(("shutdown",))
                if w.conn.poll(timeout_s):
                    w.conn.recv()  # ("ok", {"batches": n}) ack — drained
                w.conn.close()
            except (OSError, EOFError, BrokenPipeError, ValueError):
                pass
            finally:
                # orderly teardown owns the segment directly (the worker has
                # acked the drain, or had its chance): unlink here and drop
                # the death hook so nothing double-reclaims
                self._reclaimer.forget(p)
                w.transport.close(unlink=True)
                if got:
                    w.lock.release()
        # backstop: anything still registered (workers that died before
        # close, races with the accept loop) is reclaimed now — no segment
        # may outlive the executor
        self._reclaimer.reclaim_all()
        with self._mlock:
            self._workers.clear()
            self._dead.clear()
            self._degraded_pm = None
        if self._sweeper.is_alive():
            self._sweeper.join(timeout=1.0)


def accept_workers(
    listener, executor: MultiHostExecutor, timeout_s: float = 60.0, live: bool = True
):
    """Accept worker dial-ins on ``listener`` (a ``multiprocessing.
    connection.Listener``) until the executor has every process attached.
    Each worker announces ``("hello", process_id)`` on connect.

    The deadline bounds the whole initial wait, including the blocking
    accept: a worker that never dials in (crashed during startup) raises
    TimeoutError instead of hanging the coordinator, and a connection that
    never completes its hello (stray client, worker killed mid-handshake) is
    dropped rather than wedging the loop.

    With ``live=True`` (default) the loop then continues in a daemon thread
    until ``executor.close()``: a supervisor-restarted ShardServer that
    dials the same listener is re-attached, re-probed, warmed and returned
    to rotation (see :meth:`MultiHostExecutor.attach`).  Keep the listener
    open for the executor's lifetime when using rejoin."""
    import multiprocessing.connection as mpc
    import select

    deadline = time.monotonic() + timeout_s
    # the stdlib socket Listener exposes its socket; without one (e.g. a
    # test double) fall back to blocking accepts with between-accept checks
    sock = getattr(getattr(listener, "_listener", None), "_socket", None)
    while not executor.connected:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(
                f"workers missing: have {len(executor._workers)} of "
                f"{executor.num_processes - 1}"
            )
        if sock is not None:
            ready, _, _ = select.select([sock], [], [], min(remaining, 1.0))
            if not ready:
                continue
        try:
            conn = listener.accept()
        except (mpc.AuthenticationError, EOFError, OSError):
            continue  # stray/dead client: keep waiting for real workers
        if not conn.poll(max(deadline - time.monotonic(), 0.1)):
            conn.close()  # connected but silent: never sent its hello
            continue
        try:
            tag, pid = conn.recv()
        except (EOFError, OSError):
            conn.close()
            continue
        if tag != "hello":
            conn.close()
            raise RuntimeError(f"unexpected first message {tag!r} from a worker")
        executor.attach(int(pid), conn)
    with executor._mlock:  # _started is read by other threads' membership ops
        executor._started = True
    if live:
        t = threading.Thread(
            target=_accept_loop, args=(listener, executor), daemon=True,
            name="mh-accept",
        )
        t.start()
        executor._accept_thread = t
    return executor


def _accept_loop(listener, executor: MultiHostExecutor) -> None:
    """Live rejoin service: keep accepting hellos until the executor closes.
    Every fault here is contained — a stray dial-in, a half-handshake or a
    failed rejoin must never take down the coordinator."""
    import multiprocessing.connection as mpc
    import select

    sock = getattr(getattr(listener, "_listener", None), "_socket", None)
    while not executor._closed:
        try:
            if sock is not None:
                ready, _, _ = select.select([sock], [], [], 0.25)
                if not ready:
                    continue
            conn = listener.accept()
        except (OSError, ValueError, mpc.AuthenticationError, EOFError):
            if sock is None or executor._closed:
                return
            # a closed listener raises on select/accept forever: stop
            try:
                select.select([sock], [], [], 0)
            except (OSError, ValueError):
                return
            continue
        try:
            if not conn.poll(5.0):
                conn.close()
                continue
            tag, pid = conn.recv()
            if tag != "hello":
                conn.close()
                continue
            executor.attach(int(pid), conn)
        except (OSError, EOFError, ValueError, RuntimeError) as e:
            _ft_debug(f"live accept rejected a dial-in: {type(e).__name__}: {e}")
            try:
                conn.close()
            except (OSError, ValueError):
                pass


class _DropConnection(Exception):
    """Fault-injection signal: sever this worker's connection mid-stream
    (the chaos harness's stand-in for a network partition)."""


class ShardServer:
    """Worker-process side: executes this process's row block of every
    routed batch.

    Models are normalised through the registry's ``_normalize`` — the very
    code path a single-process gateway serves through — so a FusedModel
    worker executes via ``jit_for`` with its compile probe intact.

    The serve loop answers ``ping`` (idle health probes) and ``shutdown``
    (acked drain) frames alongside ``execute``/``traces``, and treats a
    coordinator that vanished mid-reply as a drain, not an error — the
    reply has no reader, so the loop returns instead of raising into the
    supervisor.  ``fault_hook`` is the chaos harness's injection point (it
    runs after the block executes, before the reply is sent).

    Args:
      process_mesh: this worker's topology (process id >= 1).
      models: ``{name: model}`` — FusedModel / PreprocessModel / callable,
        under the same names the coordinator registers.
      sharding: optional staging sharding for the worker's block.
    """

    Drop = _DropConnection

    def __init__(self, process_mesh, models: Dict[str, Any], sharding=None):
        from .registry import _normalize

        if process_mesh.process_id == 0:
            raise ValueError("process 0 is the coordinator, not a shard worker")
        self.pm = process_mesh
        self._sharding = sharding
        self._fns: Dict[str, Tuple[Any, Any]] = {}
        self.shutdown_received = False
        # data-plane codec: every connection starts on pickle and may be
        # upgraded by the coordinator's shm_attach negotiation
        self.transport = PickleTransport()
        # spans this worker records carry its mesh process id, so the
        # coordinator's stitched tree attributes work to the right process
        obs_trace.get_recorder().process = process_mesh.process_id
        for name, model in models.items():
            fn, traces = _normalize(name, model, sharding, donate=None)
            self._fns[name] = (fn, traces)

    def connect_and_serve(self, address, authkey: bytes, timeout_s: float = 60.0) -> int:
        """Dial the coordinator (retrying until its listener is up — workers
        routinely boot faster than a coordinator that compiles models
        first), announce this process, serve until told to close.  Returns
        the number of batches executed."""
        import time as _time
        from multiprocessing.connection import Client

        deadline = _time.monotonic() + timeout_s
        while True:
            try:
                conn = Client(address, authkey=authkey)
                break
            except (ConnectionRefusedError, FileNotFoundError, OSError):
                if _time.monotonic() > deadline:
                    raise
                _time.sleep(0.05)
        conn.send(("hello", self.pm.process_id))
        try:
            return self.serve(conn)
        finally:
            conn.close()

    def fault_hook(self, name: str, batches_done: int) -> None:
        """Chaos-harness injection point: runs after a block executes and
        before its reply is sent.  May sleep (straggler), raise
        :class:`ShardServer.Drop` (severed connection) or kill the process
        outright.  No-op in production."""

    @staticmethod
    def _safe_send(conn, msg) -> bool:
        """Reply, tolerating a coordinator that went away mid-flight: a dead
        socket means nobody is waiting for this reply, so the serve loop
        drains out instead of raising (the old behaviour crashed a worker
        whose coordinator closed while a reply was in flight)."""
        try:
            conn.send(msg)
            return True
        except (OSError, EOFError, BrokenPipeError, ValueError):
            return False

    def serve(self, conn) -> int:  # analyze: allow(lock-unguarded-mutation) worker side is single-threaded per connection; 'transport' is lock-guarded only on the coordinator
        # each connection negotiates its transport from scratch: a re-dial
        # after a severed connection must not reply through a stale shm
        # segment the coordinator has already reclaimed
        self.transport.close()
        self.transport = PickleTransport()
        try:
            return self._serve_loop(conn)
        finally:
            # drop the mapping (never the name: the coordinator owns the
            # unlink) so a supervised restart leaks nothing
            self.transport.close()
            self.transport = PickleTransport()

    def _serve_loop(self, conn) -> int:  # analyze: allow(lock-unguarded-mutation) worker side is single-threaded per connection; 'transport' is lock-guarded only on the coordinator
        batches = 0
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return batches
            # ANY frame arriving proves the coordinator consumed (or
            # deliberately dropped) the previous reply: its slot is free
            self.transport.note_incoming()
            if msg[0] in ("close", "shutdown"):
                self.shutdown_received = True
                if msg[0] == "shutdown":
                    # acked drain: the coordinator consumes this before
                    # closing, so no reply is ever abandoned on the wire
                    self._safe_send(conn, ("ok", {"batches": batches}))
                return batches
            if msg[0] == "ping":
                if not self._safe_send(conn, ("ok", "pong")):
                    return batches
                continue
            if msg[0] == "clock":
                # clock-offset probe: answer with this process's monotonic
                # now (the recorder's clock — the same source that stamps
                # this worker's spans, which is what the offset aligns)
                if not self._safe_send(
                    conn, ("ok", float(obs_trace.get_recorder().clock()))
                ):
                    return batches
                continue
            if msg[0] == "shm_attach":
                # transport negotiation: map the offered segment, or decline
                # and stay on pickle (the coordinator treats a decline as
                # per-worker fallback, not an error)
                try:
                    t = SharedMemoryTransport.attach(**msg[1])
                except Exception as e:
                    if not self._safe_send(
                        conn, ("error", f"{type(e).__name__}: {e}")
                    ):
                        return batches
                    continue
                self.transport.close()
                self.transport = t
                if not self._safe_send(conn, ("ok", "shm")):
                    return batches
                continue
            if msg[0] == "traces":
                _, traces = self._fns.get(msg[1], (None, None))
                if not self._safe_send(
                    conn, ("ok", traces() if traces is not None else -1)
                ):
                    return batches
                continue
            if msg[0] != "execute":
                if not self._safe_send(conn, ("error", f"unknown message {msg[0]!r}")):
                    return batches
                continue
            name = msg[1]
            # optional 4th element: the coordinator's (trace_id, span_id) —
            # absent when tracing is off/unsampled (and from old coordinators)
            ctx = msg[3] if len(msg) > 3 else None
            try:
                block = self.transport.decode_request(msg[2])
                fn, _ = self._fns[name]
                rec = obs_trace.get_recorder()
                spans = None
                if ctx is not None and rec.enabled:
                    with rec.capture() as cap:
                        with rec.span(
                            "shard.execute", component="shard", ctx=ctx,
                            attrs={"process": self.pm.process_id},
                        ):
                            out = jax.device_get(
                                fn(stage_batch(block, self._sharding))
                            )
                    self.fault_hook(name, batches)
                    # piggyback this batch's worker spans on the reply
                    spans = [s.as_tuple() for s in cap]
                else:
                    out = jax.device_get(fn(stage_batch(block, self._sharding)))
                    self.fault_hook(name, batches)
                if not self._safe_send(
                    conn, ("ok", self.transport.encode_reply(out, spans))
                ):
                    return batches
                batches += 1
            except _DropConnection:
                return batches
            except BaseException as e:  # the reply slot must always be filled
                if not self._safe_send(conn, ("error", f"{type(e).__name__}: {e}")):
                    return batches
