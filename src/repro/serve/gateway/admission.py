"""Admission control for the serving gateway.

A production serving tier (the paper's ~200 req/s chassis) must fail FAST
and PREDICTABLY when offered load exceeds capacity: an unbounded queue turns
overload into unbounded latency for every request, while bounding occupancy
turns it into immediate, cheap rejections for the excess — the client can
retry elsewhere.  Two mechanisms, two distinct errors:

* **Backpressure** — at most ``max_pending`` requests may be in flight
  (queued or executing) across the whole gateway; request ``max_pending+1``
  is rejected at the front door with :class:`QueueFullError`.
* **Load shedding** — a request carrying a deadline that has already expired
  (at the door) or that expires while queued (at batch formation, see
  :mod:`.scheduler`) is dropped with :class:`DeadlineExceededError` instead
  of wasting an executable slot computing an answer nobody is waiting for.

With a cost model wired in (see :mod:`.costmodel`), the deadline becomes a
*finish*-time bound: a request is shed not only once its deadline has
passed, but as soon as the gateway can tell it cannot finish in time —
at the door when the queue's estimated drain time already exceeds the
request's budget, and at batch formation when ``now + est_execute`` lands
past the deadline (:class:`InfeasibleDeadlineError`, a distinct subclass so
clients can tell "you asked too late" from "your deadline expired").
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class GatewayError(RuntimeError):
    """Base class for gateway-side request failures."""


class QueueFullError(GatewayError):
    """Rejected at admission: the gateway's bounded queue is full."""


class DeadlineExceededError(GatewayError):
    """Shed: the request's deadline expired before it could be launched."""


class InfeasibleDeadlineError(DeadlineExceededError):
    """Shed early: the deadline has NOT passed yet, but the cost model says
    the request cannot finish by it (queue drain or execute estimate exceeds
    the remaining budget) — shedding now is cheaper than a late answer."""


class GatewayClosedError(GatewayError):
    """The gateway shut down before this request could run."""


class UnknownModelError(GatewayError):
    """No model registered under the requested name."""


class AdmissionController:
    """Bounded-occupancy admission with deadline shedding at the door.

    Args:
      max_pending: cap on requests admitted but not yet finished.
      clock: monotonic time source (injectable for tests).
      drain_estimator: optional ``(model, priority, deadline) -> seconds``
        callable estimating how long already-queued work ahead of a new
        request will take (the gateway wires this to urgency-aware scheduler
        depth x cost-model estimates).  When the drain alone exceeds a
        request's remaining budget, the request is shed at the door with
        :class:`InfeasibleDeadlineError` instead of occupying a slot it
        cannot use.
    """

    def __init__(
        self,
        max_pending: int = 256,
        clock=time.perf_counter,
        drain_estimator: Optional[Callable[..., float]] = None,
    ):
        self.max_pending = int(max_pending)
        self._clock = clock
        self.drain_estimator = drain_estimator
        self._lock = threading.Lock()
        self._pending = 0
        self.stats = {
            "admitted": 0,
            "rejected_full": 0,
            "shed_at_door": 0,
            "shed_infeasible_door": 0,
        }

    def admit(
        self,
        deadline=None,
        model: Optional[str] = None,
        priority: int = 0,
    ) -> None:
        """Take one occupancy slot or raise; every successful admit must be
        paired with exactly one :meth:`release` when the request finishes
        (result, error, or shed)."""
        # the drain estimate is computed OUTSIDE the admission lock: it is
        # approximate by design, and the estimator takes the scheduler's and
        # cost model's own locks — holding _lock across it would serialize
        # every submit (all models, deadline or not) behind batch formation
        drain = 0.0
        if deadline is not None and self.drain_estimator is not None:
            drain = self.drain_estimator(model, priority, deadline)
        with self._lock:
            now = self._clock()
            if deadline is not None and deadline <= now:
                self.stats["shed_at_door"] += 1
                raise DeadlineExceededError(
                    "deadline expired before admission (shed)"
                )
            if drain > 0 and now + drain > deadline:
                self.stats["shed_infeasible_door"] += 1
                raise InfeasibleDeadlineError(
                    f"estimated queue drain {drain * 1e3:.1f}ms exceeds "
                    f"the request's {(deadline - now) * 1e3:.1f}ms budget "
                    "(shed at the door)"
                )
            if self._pending >= self.max_pending:
                self.stats["rejected_full"] += 1
                raise QueueFullError(
                    f"gateway queue full ({self.max_pending} pending)"
                )
            self._pending += 1
            self.stats["admitted"] += 1

    def release(self) -> None:
        with self._lock:
            self._pending -= 1

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending
