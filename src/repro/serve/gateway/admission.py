"""Admission control for the serving gateway.

A production serving tier (the paper's ~200 req/s chassis) must fail FAST
and PREDICTABLY when offered load exceeds capacity: an unbounded queue turns
overload into unbounded latency for every request, while bounding occupancy
turns it into immediate, cheap rejections for the excess — the client can
retry elsewhere.  Two mechanisms, two distinct errors:

* **Backpressure** — at most ``max_pending`` requests may be in flight
  (queued or executing) across the whole gateway; request ``max_pending+1``
  is rejected at the front door with :class:`QueueFullError`.
* **Load shedding** — a request carrying a deadline that has already expired
  (at the door) or that expires while queued (at batch formation, see
  :mod:`.scheduler`) is dropped with :class:`DeadlineExceededError` instead
  of wasting an executable slot computing an answer nobody is waiting for.

The deadline is the latest acceptable *launch* time: a request launched at
or before its deadline is served; one still queued past it is shed.
"""
from __future__ import annotations

import threading
import time


class GatewayError(RuntimeError):
    """Base class for gateway-side request failures."""


class QueueFullError(GatewayError):
    """Rejected at admission: the gateway's bounded queue is full."""


class DeadlineExceededError(GatewayError):
    """Shed: the request's deadline expired before it could be launched."""


class GatewayClosedError(GatewayError):
    """The gateway shut down before this request could run."""


class UnknownModelError(GatewayError):
    """No model registered under the requested name."""


class AdmissionController:
    """Bounded-occupancy admission with deadline shedding at the door.

    Args:
      max_pending: cap on requests admitted but not yet finished.
      clock: monotonic time source (injectable for tests).
    """

    def __init__(self, max_pending: int = 256, clock=time.perf_counter):
        self.max_pending = int(max_pending)
        self._clock = clock
        self._lock = threading.Lock()
        self._pending = 0
        self.stats = {"admitted": 0, "rejected_full": 0, "shed_at_door": 0}

    def admit(self, deadline=None) -> None:
        """Take one occupancy slot or raise; every successful admit must be
        paired with exactly one :meth:`release` when the request finishes
        (result, error, or shed)."""
        with self._lock:
            if deadline is not None and deadline <= self._clock():
                self.stats["shed_at_door"] += 1
                raise DeadlineExceededError(
                    "deadline expired before admission (shed)"
                )
            if self._pending >= self.max_pending:
                self.stats["rejected_full"] += 1
                raise QueueFullError(
                    f"gateway queue full ({self.max_pending} pending)"
                )
            self._pending += 1
            self.stats["admitted"] += 1

    def release(self) -> None:
        with self._lock:
            self._pending -= 1

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending
