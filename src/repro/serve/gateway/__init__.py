"""repro.serve.gateway — the online serving tier.

One :class:`ServingGateway` front door over many named fused models:
admission control (bounded queue, backpressure, deadline shedding),
continuous shape-bucketed batch scheduling with priority + deadline
awareness, warmup AOT precompilation of every (model, bucket) shape, and
per-request DDSketch latency telemetry.  See README "Serving tier".
"""
from .admission import (
    AdmissionController,
    DeadlineExceededError,
    GatewayClosedError,
    GatewayError,
    InfeasibleDeadlineError,
    QueueFullError,
    UnknownModelError,
)
from .costmodel import ExecuteCostModel
from .gateway import ServingGateway
from .multihost import (
    MultiHostExecutor,
    MultiHostServable,
    ShardServer,
    WorkerFailedError,
    accept_workers,
)
from .registry import ModelEntry, ModelRegistry
from .scheduler import BatchScheduler, Request
from .telemetry import LatencySketch

__all__ = [
    "ServingGateway",
    "ModelRegistry",
    "ModelEntry",
    "BatchScheduler",
    "Request",
    "LatencySketch",
    "ExecuteCostModel",
    "MultiHostExecutor",
    "MultiHostServable",
    "ShardServer",
    "WorkerFailedError",
    "accept_workers",
    "AdmissionController",
    "GatewayError",
    "QueueFullError",
    "DeadlineExceededError",
    "InfeasibleDeadlineError",
    "GatewayClosedError",
    "UnknownModelError",
]
