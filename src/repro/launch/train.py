"""Training CLI: mesh setup, synthetic data, checkpoint/restart, heartbeat,
straggler stats.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ck --ckpt-every 20

Designed so the FT supervisor can kill it at any step and a relaunch resumes
from the newest committed checkpoint.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import CheckpointManager
from repro.ckpt.checkpoint import latest_step
from repro.data import lm_token_batches
from repro.ft import Heartbeat, StragglerMonitor
from repro.launch import mesh as meshlib
from repro.models import common as C
from repro.models import registry
from repro.train import AdamWConfig, make_train_step
from repro.train.step import train_state_init, train_state_pspecs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="failure injection (FT tests)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = registry.build(cfg)

    mesh = meshlib.make_host_mesh(args.data_mesh, args.model_mesh)
    C.set_batch_axes(meshlib.data_axes(mesh))

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps)
    step_fn = make_train_step(model, ocfg, accum=args.accum)

    state = train_state_init(model, args.seed)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore_latest(state)
        start_step = int(np.asarray(state["opt"]["step"]))
        print(f"[train] resumed from checkpoint at step {start_step}", flush=True)

    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    mon = StragglerMonitor()
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    data = lm_token_batches(args.batch, args.seq, cfg.vocab, args.steps * 2, seed=args.seed)
    losses = []
    with meshlib.use_mesh(mesh):
        for i, batch in enumerate(data):
            step_i = start_step + i
            if step_i >= args.steps:
                break
            if cfg.family == "whisper":
                batch = dict(batch)
                rng = np.random.default_rng(step_i)
                batch["frames"] = jnp.asarray(
                    rng.normal(0, 1, (args.batch, cfg.enc_seq, cfg.d_model)), jnp.float32
                )
            if cfg.family == "vlm":
                batch = dict(batch)
                rng = np.random.default_rng(step_i)
                batch["patch_embeds"] = jnp.asarray(
                    rng.normal(0, 1, (args.batch, cfg.num_patches, cfg.d_model)), jnp.float32
                )
            mon.step_start()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            mon.step_end()
            losses.append(loss)
            if hb:
                hb.beat(step_i, loss=loss)
            if args.crash_at_step is not None and step_i == args.crash_at_step:
                # failure injection: crash once per sentinel (so the restarted
                # process makes progress, as a replaced node would)
                import os

                sentinel = os.environ.get("CRASH_SENTINEL")
                if sentinel and not os.path.exists(sentinel):
                    open(sentinel, "w").write("crashed")
                    print(f"[train] injected crash at step {step_i}", flush=True)
                    os._exit(42)
            if ckpt is not None and (step_i + 1) % args.ckpt_every == 0:
                ckpt.save_async(step_i + 1, state)
            if step_i % args.log_every == 0:
                print(
                    f"[train] step={step_i} loss={loss:.4f} "
                    f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.3f}",
                    flush=True,
                )
    if ckpt is not None:
        ckpt.wait()
        ckpt.save_async(start_step + len(losses), state)
        ckpt.wait()
    print(
        f"[train] done: {len(losses)} steps, loss {losses[0]:.4f} -> {losses[-1]:.4f} "
        f"straggler={mon.summary()['median']:.3f}s/step",
        flush=True,
    )
    return losses


if __name__ == "__main__":
    main()
