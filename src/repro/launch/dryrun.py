import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell against the production mesh, prove it fits, and extract the
roofline terms (compute / memory / collective) from the compiled artifact.

The two XLA_FLAGS lines above MUST precede every other import: jax locks the
device count at first initialisation.  Smoke tests and benchmarks never
import this module, so they keep seeing 1 device.

Usage:
    python -m repro.launch.dryrun --arch codeqwen1.5-7b --shape train_4k
    python -m repro.launch.dryrun --all                  # every cell, 1 pod
    python -m repro.launch.dryrun --all --multi-pod      # every cell, 2 pods

Each cell writes benchmarks/artifacts/dryrun/<arch>_<shape>_<mesh>.json —
re-runs skip existing artifacts (resumable), --force overwrites.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import mesh as meshlib
from repro.launch import specs as S
from repro.models import common as C
from repro.models import registry
from repro.train import AdamWConfig, make_train_step
from repro.train.step import train_state_abstract, train_state_pspecs

# --- TPU v5e hardware model (per chip) ---------------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(.*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")


def parse_collectives(hlo_text: str):
    """Sum per-device communicated bytes (result shapes) per collective kind."""
    by_kind = {}
    count = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m:
            result_part, kind = m.group(1), m.group(2)
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(result_part):
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            by_kind[kind] = by_kind.get(kind, 0) + nbytes
            count[kind] = count.get(kind, 0) + 1
    return by_kind, count


def count_params(defs) -> int:
    return int(sum(np.prod(d.shape) for d in defs.values()))


def count_active_params(cfg, defs) -> int:
    """Active params for MoE archs: routed experts scaled by top_k / E."""
    total = 0
    for path, d in defs.items():
        n = int(np.prod(d.shape))
        if cfg.n_routed_experts and re.search(r"/moe/w[gud]$", path):
            n = int(n * cfg.moe_top_k / cfg.n_routed_experts)
        total += n
    return total


def _serving_params(model, cfg):
    """Serving weights: compute-dtype (bf16) ShapeDtypeStructs — deployments
    serve quantised checkpoints, and fp32 weight gathers were the dominant
    decode collective (§Perf decode iter-5)."""
    import jax as _jax

    out = {}
    for p, d in model.defs().items():
        dt = cfg.compute_dtype if (len(d.shape) >= 2 and d.dtype == jnp.float32) else d.dtype
        out[p] = _jax.ShapeDtypeStruct(d.shape, dt)
    return out


def _serving_pspecs(model):
    """Serving layout: TP over "model" only; REPLICATED over the data axes
    (no optimizer state to justify FSDP — replication removes every per-step
    weight all-gather from the decode path)."""
    return model.pspecs(rules={"embed": None})


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp: bool = False, unroll_decode: bool = False, accum: int = 1):
    import dataclasses

    cfg = configs.get(arch)
    kind = S.SHAPES[shape_name]["kind"]
    if unroll_decode and kind == "decode":
        # §Perf: unrolled decode lets XLA alias each layer's cache update
        # in place; the layer-scan double-buffers the whole KV cache
        cfg = dataclasses.replace(cfg, scan_layers=False)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    data_ax = meshlib.data_axes(mesh)
    if fsdp:
        # §Perf: pure-FSDP layout — the "model" axis joins the batch axes;
        # no TP activation collectives, weights gathered per layer instead
        C.set_batch_axes(data_ax + ("model",))
        C.ACT_RULES["act_model"] = None
        data_ax = data_ax + ("model",)
    else:
        C.set_batch_axes(data_ax)
        C.ACT_RULES["act_model"] = "model"
    model = registry.build(cfg)
    bspec = P(data_ax)

    with meshlib.use_mesh(mesh):
        if kind == "train":
            step = make_train_step(model, AdamWConfig(), accum=accum)
            state = train_state_abstract(model)
            sspec = C.legalize_tree(state, train_state_pspecs(model), mesh)
            state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec)
            ins = S.input_specs(cfg, shape_name)
            in_sh = {
                k: NamedSharding(mesh, C.legalize_pspec(v.shape, P(data_ax, *([None] * (len(v.shape) - 1))), mesh))
                for k, v in ins.items()
            }
            metric_sh = {k: NamedSharding(mesh, P()) for k in ("grad_norm", "lr", "loss")}
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, in_sh),
                out_shardings=(state_sh, metric_sh),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, ins)
        elif kind == "prefill":
            params = _serving_params(model, cfg)
            pspec = C.legalize_tree(params, _serving_pspecs(model), mesh)
            p_sh = {k: NamedSharding(mesh, s) for k, s in pspec.items()}
            ins = S.input_specs(cfg, shape_name)
            in_sh = {
                k: NamedSharding(mesh, C.legalize_pspec(v.shape, P(data_ax, *([None] * (len(v.shape) - 1))), mesh))
                for k, v in ins.items()
            }

            def prefill(params, batch):
                if cfg.family == "whisper":
                    logits, _ = model.logits(params, batch["tokens"], batch["frames"])
                elif cfg.family == "vlm":
                    logits, _ = model.logits(params, batch["tokens"], batch["patch_embeds"])
                else:
                    logits, _ = model.logits(params, batch["tokens"])
                return logits

            jitted = jax.jit(prefill, in_shardings=(p_sh, in_sh), out_shardings=None)
            lowered = jitted.lower(params, ins)
        else:  # decode
            params = _serving_params(model, cfg)
            pspec = C.legalize_tree(params, _serving_pspecs(model), mesh)
            p_sh = {k: NamedSharding(mesh, s) for k, s in pspec.items()}
            cache = S.cache_abstract(model, cfg, shape_name)
            c_spec = C.legalize_tree(cache, S.cache_pspecs(cache, mesh), mesh)
            c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec)
            ins = S.input_specs(cfg, shape_name)
            in_sh = {
                k: NamedSharding(mesh, C.legalize_pspec(v.shape, P(data_ax, None), mesh))
                for k, v in ins.items()
            }

            def serve_step(params, cache, batch):
                return model.decode_step(params, cache, batch["tokens"])

            # out_shardings must match the donated cache's shardings or XLA
            # silently drops the donation and double-buffers the KV cache
            jitted = jax.jit(
                serve_step,
                in_shardings=(p_sh, c_sh, in_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, cache, ins)
        compiled = lowered.compile()
    return cfg, model, lowered, compiled, mesh


def analyse(arch, shape_name, multi_pod, cfg, model, lowered, compiled, mesh, t_compile):
    n_dev = int(np.prod(list(mesh.shape.values())))
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware static analysis (lax.scan bodies x trip_count); the raw
    # cost_analysis numbers count scan bodies once and are kept for reference
    from repro.launch import hloanalysis

    static = hloanalysis.analyse_hlo(hlo)
    coll_bytes = {k: float(v) for k, v in static["coll_bytes"].items()}
    coll_count = {k: float(v) for k, v in static["coll_count"].items()}
    total_coll = sum(coll_bytes.values())

    flops_dev = float(static["flops"])
    flops_body_once = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    kind = S.SHAPES[shape_name]["kind"]
    B, seq = S.SHAPES[shape_name]["batch"], S.SHAPES[shape_name]["seq"]
    defs = model.defs()
    n_params = count_params(defs)
    n_active = count_active_params(cfg, defs)
    if kind == "train":
        tokens = B * seq
        model_flops_total = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = B * seq
        model_flops_total = 2.0 * n_active * tokens
    else:
        tokens = B  # one token per sequence
        model_flops_total = 2.0 * n_active * tokens
    model_flops_dev = model_flops_total / n_dev

    compute_s = flops_dev / PEAK_FLOPS
    # Two memory models: the raw HLO bytes-accessed (CPU-backend fusion makes
    # this a loose UPPER bound for TPU) and a resident-traffic LOWER bound
    # (every resident byte — args, temps, outputs — touched once per step).
    # The roofline uses the resident-traffic term; both are recorded.
    memory_s_hlo = bytes_dev / HBM_BW
    resident = mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes
    memory_s = resident / HBM_BW
    collective_s = total_coll / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": kind,
        "compile_s": t_compile,
        "params": n_params,
        "active_params": n_active,
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_est_bytes_per_dev": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "cost": {
            "flops_per_dev": flops_dev,
            "flops_cost_analysis_body_once": flops_body_once,
            "bytes_per_dev_hlo": bytes_dev,
        },
        "collectives": {"bytes_per_dev": coll_bytes, "count": coll_count, "total_bytes_per_dev": total_coll},
        "roofline": {
            **terms,
            "memory_s_hlo_upper": memory_s_hlo,
            "dominant": dominant,
            "model_flops_per_dev": model_flops_dev,
            "useful_flops_ratio": (model_flops_dev / flops_dev) if flops_dev else 0.0,
            "roofline_fraction": (model_flops_dev / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0
            else 0.0,
        },
    }
    return rec


def run_cell(arch, shape_name, multi_pod, outdir, force=False,
             fsdp=False, unroll_decode=False, accum=1, tag=""):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out = outdir / f"{arch.replace('/', '_')}_{shape_name}_{mesh_tag}{tag}.json"
    if out.exists() and not force:
        print(f"[skip-cached] {arch} {shape_name} {mesh_tag}")
        return json.loads(out.read_text())
    cfg = configs.get(arch)
    ok, why = S.cell_is_applicable(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "skipped": why}
        out.write_text(json.dumps(rec, indent=2))
        print(f"[skip] {arch} {shape_name}: {why}")
        return rec
    t0 = time.time()
    try:
        cfg, model, lowered, compiled, mesh = lower_cell(
            arch, shape_name, multi_pod, fsdp=fsdp,
            unroll_decode=unroll_decode, accum=accum)
    except Exception as e:
        traceback.print_exc()
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(rec, indent=2))
        return rec
    t_compile = time.time() - t0
    rec = analyse(arch, shape_name, multi_pod, cfg, model, lowered, compiled, mesh, t_compile)
    out.write_text(json.dumps(rec, indent=2))
    mem = rec["memory"]["peak_est_bytes_per_dev"] / 2**30
    r = rec["roofline"]
    print(
        f"[ok] {arch:20s} {shape_name:12s} {mesh_tag:8s} compile={t_compile:6.1f}s "
        f"peak={mem:6.2f}GiB/dev flops/dev={rec['cost']['flops_per_dev']:.3e} "
        f"coll={rec['collectives']['total_bytes_per_dev']/2**20:8.1f}MiB "
        f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}"
    )
    sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--unroll-decode", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = configs.ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for sh in shapes:
            cells.append((a, sh))
    for a, sh in cells:
        run_cell(a, sh, args.multi_pod, outdir, force=args.force,
                 fsdp=args.fsdp, unroll_decode=args.unroll_decode,
                 accum=args.accum, tag=args.tag)


if __name__ == "__main__":
    main()
