"""Production mesh definitions.

A TPU v5e pod slice of 256 chips is modelled as a (data=16, model=16) mesh;
the two-pod production job adds a leading "pod" axis: (2, 16, 16).  Data
parallelism (and FSDP param sharding) runs over ("pod", "data"); tensor /
expert parallelism over "model".  Functions, not module constants — importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where supported (jax >= 0.5);
    0.4.x has neither the kwarg nor jax.sharding.AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh``
    (jax >= 0.6) / ``jax.sharding.use_mesh`` (0.5.x) / the Mesh object's own
    context manager (0.4.x resource-env semantics)."""
    fn = getattr(jax, "set_mesh", None) or getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-carrying axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_sharding(mesh):
    """NamedSharding placing a batch dim across the mesh's data axes — the
    ``in_shardings`` a TransformPlan is lowered with on this mesh.
    Delegates to ``Engine`` so the two can never drift; use an Engine
    directly to shard over non-default data axes."""
    from repro.core.engine import Engine

    return Engine(mesh, data_axes=data_axes(mesh)).batch_sharding()


def mesh_fingerprint(mesh) -> Tuple:
    """Hashable identity of a mesh: axis names, per-axis sizes, device ids.

    Two meshes with the same fingerprint produce equal NamedShardings and
    therefore hit the same entry in a TransformPlan's executable cache; a
    differing fingerprint is a guaranteed cache miss.  Useful for logging
    which compiled variants a serving/offline host holds."""
    if mesh is None:
        return ()
    sizes = tuple(mesh.shape[a] for a in mesh.axis_names)
    devs = tuple(int(d.id) for d in mesh.devices.flat)
    return (tuple(mesh.axis_names), sizes, devs)


def sharding_fingerprint(sharding) -> Tuple:
    """Hashable identity of an (optional) sharding: the owning mesh's
    fingerprint plus the partition spec.  ``None`` (single default device)
    fingerprints to ``()``.  This is the cache key FusedModel lowers its
    fused executable under — two shardings with equal fingerprints place
    batches identically, so they may share one compiled program."""
    if sharding is None:
        return ()
    mesh = getattr(sharding, "mesh", None)
    spec = getattr(sharding, "spec", None)
    if mesh is None:  # e.g. SingleDeviceSharding / PositionalSharding
        # no mesh+spec to identify the layout, so fold in the repr: distinct
        # layouts over the same devices must NOT collide on one executable
        # (a collision silently serves the wrong placement; the worst a
        # too-fine key costs is a duplicate compile)
        devs = tuple(sorted(int(d.id) for d in getattr(sharding, "device_set", ())))
        return (type(sharding).__name__, devs, repr(sharding))
    return (mesh_fingerprint(mesh), str(spec))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return _make_mesh((data, model), ("data", "model"))
