"""Production mesh definitions.

A TPU v5e pod slice of 256 chips is modelled as a (data=16, model=16) mesh;
the two-pod production job adds a leading "pod" axis: (2, 16, 16).  Data
parallelism (and FSDP param sharding) runs over ("pod", "data"); tensor /
expert parallelism over "model".  Functions, not module constants — importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where supported (jax >= 0.5);
    0.4.x has neither the kwarg nor jax.sharding.AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh``
    (jax >= 0.6) / ``jax.sharding.use_mesh`` (0.5.x) / the Mesh object's own
    context manager (0.4.x resource-env semantics)."""
    fn = getattr(jax, "set_mesh", None) or getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-carrying axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return _make_mesh((data, model), ("data", "model"))
